#!/usr/bin/env python3
"""Validate a `fuseconv bench` report (BENCH_<n>.json).

    python3 ci/check_bench.py BENCH_7.json [--min-rps-ratio 0.9] [--min-hit-rate 0.5]

Checks, in order:

  * the report parses as JSON and carries every schema key the perf
    trajectory depends on (so later tooling can chart BENCH_*.json
    files without per-file special cases);
  * achieved RPS >= --min-rps-ratio x target RPS (default 0.9): the
    serving tier kept up with the open-loop schedule;
  * zero transport errors: no dead sockets, no undecodable frames —
    app-level errors (`busy`, `deadline`) are load-shedding and allowed,
    transport errors are always a bug;
  * nothing was left unanswered after the drain grace;
  * latency percentiles are present, finite, positive, and monotone
    (p50 <= p95 <= p99 <= p999 <= max);
  * the request ledger adds up (completed + unanswered <= sent is the
    floor; completed alone must support the achieved-RPS figure);
  * when the report carries a `server.cache` section (a run against
    `serve --cache-entries`), its counters are well-formed and its
    `hit_rate` agrees with (hits + coalesced) / (hits + coalesced +
    misses); `--min-hit-rate` additionally *requires* the section and
    enforces a floor on the rate — the warm-cache trajectory gate.

Exit code 0 on pass; 1 with a reason on the first failure.
"""

import argparse
import json
import math
import sys

SCHEMA_KEYS = [
    "bench",
    "transport",
    "target_rps",
    "achieved_rps",
    "duration_s",
    "connections",
    "peak_inflight",
    "requests",
    "latency_ms",
    "op_mix",
    "errors_by_code",
]
REQUEST_KEYS = ["sent", "completed", "app_errors", "transport_errors", "unanswered"]
LATENCY_KEYS = ["p50", "p95", "p99", "p999", "mean", "max"]
CACHE_COUNTER_KEYS = [
    "result_hits",
    "result_misses",
    "result_coalesced",
    "result_evicted",
    "result_entries",
    "result_bytes",
]


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def positive_finite(name: str, value) -> float:
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        fail(f"{name} must be a number, got {value!r}")
    value = float(value)
    if not math.isfinite(value) or value <= 0:
        fail(f"{name} must be finite and positive, got {value}")
    return value


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="path to a BENCH_<n>.json bench report")
    ap.add_argument(
        "--min-rps-ratio",
        type=float,
        default=0.9,
        help="floor on achieved_rps / target_rps (default 0.9)",
    )
    ap.add_argument(
        "--min-hit-rate",
        type=float,
        default=None,
        help=(
            "require a server.cache section and floor its hit_rate "
            "(omit to only validate the section when present)"
        ),
    )
    args = ap.parse_args()

    try:
        with open(args.report, encoding="utf-8") as fh:
            report = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {args.report}: {e}")

    for key in SCHEMA_KEYS:
        if key not in report:
            fail(f"missing schema key {key!r}")
    requests = report["requests"]
    for key in REQUEST_KEYS:
        if key not in requests:
            fail(f"missing requests.{key}")
    latency = report["latency_ms"]
    for key in LATENCY_KEYS:
        if key not in latency:
            fail(f"missing latency_ms.{key}")

    target = positive_finite("target_rps", report["target_rps"])
    achieved = positive_finite("achieved_rps", report["achieved_rps"])
    positive_finite("duration_s", report["duration_s"])
    if report["connections"] < 1:
        fail("connections must be >= 1")

    ratio = achieved / target
    if ratio < args.min_rps_ratio:
        fail(
            f"achieved {achieved:.1f} rps is {ratio:.1%} of the {target:.0f} rps "
            f"target (floor {args.min_rps_ratio:.0%})"
        )

    if requests["transport_errors"] != 0:
        fail(f"{requests['transport_errors']} transport error(s); the floor is zero")
    if requests["unanswered"] != 0:
        fail(f"{requests['unanswered']} request(s) never answered within the drain grace")
    if requests["completed"] > requests["sent"]:
        fail("completed exceeds sent — the request ledger is inconsistent")

    values = {k: positive_finite(f"latency_ms.{k}", latency[k]) for k in LATENCY_KEYS}
    ladder = ["p50", "p95", "p99", "p999", "max"]
    for lo, hi in zip(ladder, ladder[1:]):
        if values[lo] > values[hi]:
            fail(f"latency_ms.{lo} ({values[lo]}) > latency_ms.{hi} ({values[hi]})")

    cache = (report.get("server") or {}).get("cache")
    if args.min_hit_rate is not None and cache is None:
        fail("--min-hit-rate given but the report has no server.cache section")
    hit_rate = None
    if cache is not None:
        for key in CACHE_COUNTER_KEYS:
            v = cache.get(key)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                fail(f"server.cache.{key} must be a nonnegative integer, got {v!r}")
        if "hit_rate" not in cache:
            fail("server.cache present but missing hit_rate")
        hit_rate = cache["hit_rate"]
        if not isinstance(hit_rate, (int, float)) or isinstance(hit_rate, bool):
            fail(f"server.cache.hit_rate must be a number, got {hit_rate!r}")
        hit_rate = float(hit_rate)
        served = cache["result_hits"] + cache["result_coalesced"]
        looked = served + cache["result_misses"]
        derived = served / looked if looked else 0.0
        # the report rounds to 4 decimals; anything past that is a bug
        if abs(hit_rate - derived) > 5e-4:
            fail(
                f"server.cache.hit_rate {hit_rate} disagrees with its own "
                f"counters ({derived:.4f})"
            )
        if args.min_hit_rate is not None and hit_rate < args.min_hit_rate:
            fail(
                f"cache hit_rate {hit_rate:.1%} is below the "
                f"{args.min_hit_rate:.0%} floor"
            )

    cache_note = f", cache hit rate {hit_rate:.1%}" if hit_rate is not None else ""
    print(
        f"check_bench: OK: {achieved:.1f}/{target:.0f} rps ({ratio:.1%}) over "
        f"{report['connections']} conns on the {report['transport']} transport, "
        f"p50 {values['p50']:.2f} ms, p99 {values['p99']:.2f} ms, "
        f"0 transport errors{cache_note}"
    )


if __name__ == "__main__":
    main()
