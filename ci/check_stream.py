#!/usr/bin/env python3
"""Check a streamed fuseconv frame capture against the v2 contract.

One parser for every smoke step in CI (TCP, HTTP/SSE, and the shard
front tier over both transports):

    ci/check_stream.py --format jsonl /tmp/sweep-stream.jsonl /tmp/local.csv
    ci/check_stream.py --format sse   /tmp/sweep.sse          /tmp/local.csv
    ci/check_stream.py --format sse --mode search /tmp/search.sse

Asserts the protocol-v2 stream contract (PROTOCOL.md sections 3, 11):

* at least one `progress` frame arrives before the `final` frame;
* progress is monotonic with `done <= total`;
* the stream ends with exactly one `final`, and it is `ok`;
* `--mode sweep` (default): the streamed `row` cycle counts equal the
  local sweep's rows, cell for cell and in plan order;
* `--mode search`: `search_row` frames stream, the terminal reply is a
  `search` with a non-empty frontier, and the last generation's rows
  equal the frontier point for point (`--expect-cancelled` flips the
  check to a cancelled partial run instead).
"""

import argparse
import json
import sys


def frames_from_jsonl(path):
    """Newline-delimited TCP frames: one JSON object per line."""
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def frames_from_sse(path):
    """SSE events: blank-line-separated blocks; `data:` carries the
    byte-identical frame JSON, `event:` must match its `frame` tag."""
    frames = []
    with open(path) as fh:
        raw = fh.read()
    for block in raw.split("\n\n"):
        event = None
        for line in block.splitlines():
            if line.startswith("event:"):
                event = line.split(":", 1)[1].strip()
            elif line.startswith("data:"):
                frame = json.loads(line.split(":", 1)[1])
                assert event == frame["frame"], (event, frame)
                frames.append(frame)
    return frames


def local_cycles(csv_path):
    with open(csv_path) as fh:
        lines = fh.read().splitlines()
    col = lines[0].split(",").index("total_cycles")
    return [int(line.split(",")[col]) for line in lines[1:]]


def check_sweep(frames, local_csv):
    streamed = [f["row"]["total_cycles"] for f in frames if f["frame"] == "row"]
    local = local_cycles(local_csv)
    assert streamed == local, (streamed, local)
    return f"{len(streamed)} rows match the local sweep"


def check_search(frames, expect_cancelled):
    rows = [f["point"] for f in frames if f["frame"] == "search_row"]
    assert rows, "a search stream must carry search_row frames"
    reply = frames[-1]["ok"]
    assert reply["kind"] == "search", reply
    assert reply["frontier"], "the converged frontier must be non-empty"
    assert reply["cancelled"] is expect_cancelled, reply
    if expect_cancelled:
        total = frames[0]["total"]
        assert reply["generations"] < total, (reply["generations"], total)
        return (
            f"cancelled after {reply['generations']}/{total} generations, "
            f"{len(reply['frontier'])} partial frontier points"
        )
    # the last generation's rows ARE the converged frontier
    tail = rows[-len(reply["frontier"]):]
    assert tail == reply["frontier"], (tail, reply["frontier"])
    return (
        f"{len(rows)} pareto rows streamed, final frontier of "
        f"{len(reply['frontier'])} matches the last generation"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--format", choices=["jsonl", "sse"], required=True)
    ap.add_argument("--mode", choices=["sweep", "search"], default="sweep")
    ap.add_argument(
        "--expect-cancelled",
        action="store_true",
        help="search mode: the capture is of a cancelled run",
    )
    ap.add_argument("stream", help="captured frame stream")
    ap.add_argument(
        "local_csv",
        nargs="?",
        help="local `fuseconv sweep --format csv` output (sweep mode)",
    )
    args = ap.parse_args()

    parse = frames_from_jsonl if args.format == "jsonl" else frames_from_sse
    frames = parse(args.stream)
    assert frames, f"no frames parsed from {args.stream}"

    kinds = [f["frame"] for f in frames]
    assert "progress" in kinds, kinds
    assert kinds.index("progress") < kinds.index("final"), kinds
    assert kinds[-1] == "final", kinds
    assert kinds.count("final") == 1, kinds
    assert "ok" in frames[-1], frames[-1]

    progress = [(f["done"], f["total"]) for f in frames if f["frame"] == "progress"]
    assert all(d <= t for d, t in progress), progress
    dones = [d for d, _ in progress]
    assert dones == sorted(dones), f"progress must be monotonic: {dones}"

    if args.mode == "sweep":
        assert args.local_csv, "sweep mode needs the local CSV to compare against"
        detail = check_sweep(frames, args.local_csv)
    else:
        detail = check_search(frames, args.expect_cancelled)

    print(
        f"stream ok ({args.format}, {args.mode}): {detail}, "
        f"{len(progress)} progress frames before a single final"
    )


if __name__ == "__main__":
    sys.exit(main())
