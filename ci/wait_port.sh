#!/usr/bin/env bash
# Wait for a port file (written by `fuseconv serve/shard --port-file`
# once the listener is bound) and print the address it holds.
#
#   ADDR=$(ci/wait_port.sh /tmp/fuseconv-port [deadline-secs] [pid])
#
# Polls every 0.1 s against a wall-clock deadline (default 30 s) and
# exits nonzero on timeout — a hung server fails the step instead of
# wedging the job until the runner-level timeout. When a PID is given,
# the wait also aborts as soon as that process is gone (a crashed
# server fails in ~0.1 s, not after the full deadline).
set -euo pipefail

file="${1:?usage: wait_port.sh <port-file> [deadline-secs] [pid]}"
deadline_secs="${2:-30}"
pid="${3:-}"

start=$(date +%s)
while :; do
  if [ -s "$file" ]; then
    cat "$file"
    exit 0
  fi
  if [ -n "$pid" ] && ! kill -0 "$pid" 2>/dev/null; then
    echo "process $pid exited before writing port file $file" >&2
    exit 1
  fi
  if [ $(( $(date +%s) - start )) -ge "$deadline_secs" ]; then
    echo "timed out after ${deadline_secs}s waiting for port file $file" >&2
    exit 1
  fi
  sleep 0.1
done
