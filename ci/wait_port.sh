#!/usr/bin/env bash
# Wait for a port file (written by `fuseconv serve/shard --port-file`
# once the listener is bound) and print the address it holds.
#
#   ADDR=$(ci/wait_port.sh /tmp/fuseconv-port [tries])
#
# Polls every 0.1 s for up to `tries` attempts (default 100 = 10 s).
set -euo pipefail

file="${1:?usage: wait_port.sh <port-file> [tries]}"
tries="${2:-100}"

for _ in $(seq 1 "$tries"); do
  if [ -s "$file" ]; then
    cat "$file"
    exit 0
  fi
  sleep 0.1
done

echo "timed out waiting for port file $file" >&2
exit 1
