//! Epoll transport parity: the single-threaded event loop must be
//! frame-identical on the wire to the thread-per-connection transport,
//! for both frontends. Everything here drives the SAME client helpers
//! the threaded-transport suites use (`WireClient`, `http_call`,
//! `http_sse`) against servers booted with `Transport::Epoll`:
//!
//! * mixed concurrent Infer/Simulate over TCP, every id answered, and
//!   simulate cycles identical to a direct in-process simulation;
//! * a ≥24-cell TCP sweep streams incremental frames before its Final,
//!   rows bit-identical to a serial `run_sweep`, interleaved with
//!   pipelined infers on the same connection;
//! * `--max-requests-per-conn` answers a typed Busy then closes, same
//!   as the threaded budget;
//! * HTTP one-shot + SSE + the error-status taxonomy (400/404/405/504)
//!   on the epoll loop, byte-compatible enough that the stock client
//!   helpers parse it without change;
//! * both transports mount ONE Router concurrently and a shutdown over
//!   the epoll TCP listener trips the shared stop latch.
//!
//! Epoll is Linux-only; the whole file is gated accordingly (the
//! portable stub returns `Unsupported`, covered by unit tests).
#![cfg(target_os = "linux")]

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::wire::encode_request_body;
use fuseconv::coordinator::{
    http_call, http_sse, ConfigPatch, Frame, HttpServer, MockEngine, ModelSpec, Reply,
    Request, RequestBody, Router, ServeError, Server, SimServer, StopLatch, SweepRow,
    Transport, TransportGauges, WireClient, WireServer,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, simulate_network, FuseVariant, LayerCache, SimConfig, SweepPlan,
};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

const T: Duration = Duration::from_secs(300);

fn mock_router() -> Arc<Router> {
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 64);
    Arc::new(Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )))
}

/// Boot a TCP frontend on the epoll event loop.
fn start_epoll_wire(router: Arc<Router>) -> (String, thread::JoinHandle<()>) {
    let server = WireServer::bind("127.0.0.1:0", router)
        .expect("bind ephemeral")
        .with_transport(Transport::Epoll);
    let addr = server.local_addr().to_string();
    let handle = thread::spawn(move || server.run().expect("epoll wire run"));
    (addr, handle)
}

/// Boot an HTTP frontend on the epoll event loop.
fn start_epoll_http(router: Arc<Router>) -> (String, thread::JoinHandle<()>) {
    let http = HttpServer::bind("127.0.0.1:0", router)
        .expect("bind http")
        .with_transport(Transport::Epoll);
    let addr = http.local_addr().to_string();
    let handle = thread::spawn(move || http.run().expect("epoll http run"));
    (addr, handle)
}

fn shutdown_wire(addr: &str, handle: thread::JoinHandle<()>) {
    let mut client = WireClient::connect(addr, Duration::from_secs(30)).expect("connect");
    let resp = client
        .roundtrip(&Request::new(u64::MAX, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    handle.join().expect("listener thread");
}

fn serial_reference(
    names: &[&str],
    variants: &[FuseVariant],
    sizes: &[usize],
) -> fuseconv::sim::SweepOutcome {
    let plan = SweepPlan::new(
        names.iter().map(|m| models::by_name(m).unwrap()).collect(),
        variants.to_vec(),
        sizes.iter().map(|&s| SimConfig::with_size(s)).collect(),
    );
    run_sweep_serial(&plan)
}

fn assert_rows_match(rows: &[SweepRow], reference: &fuseconv::sim::SweepOutcome) {
    assert_eq!(rows.len(), reference.records().len(), "row count");
    for (row, rec) in rows.iter().zip(reference.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
        assert_eq!(row.total_cycles, rec.total_cycles(), "{} {}", row.network, row.rows);
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }
}

#[test]
fn epoll_wire_concurrent_mixed_traffic_zero_dropped_replies() {
    let (addr, handle) = start_epoll_wire(mock_router());

    let workers: Vec<_> = (0..32u64)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut client = WireClient::connect(&addr, T).expect("connect");
                let req = if i % 2 == 0 {
                    Request::new(i, RequestBody::Infer { input: vec![i as f32; 4] })
                } else {
                    Request::new(
                        i,
                        RequestBody::Simulate {
                            model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                            variant: FuseVariant::Half,
                            config: ConfigPatch::sized(8),
                        },
                    )
                };
                let resp = client.roundtrip(&req).expect("roundtrip");
                assert_eq!(resp.id, i, "reply must carry the request id");
                (i, resp)
            })
        })
        .collect();

    let mut infer_seen = 0;
    let mut sim_seen = 0;
    for w in workers {
        let (i, resp) = w.join().expect("client thread");
        match resp.result {
            Ok(Reply::Infer(r)) => {
                assert_eq!(i % 2, 0);
                assert_eq!(r.output[0], (4 * i) as f32);
                infer_seen += 1;
            }
            Ok(Reply::Sim(s)) => {
                assert_eq!(i % 2, 1);
                assert!(s.total_cycles > 0);
                sim_seen += 1;
            }
            other => panic!("request {i}: unexpected {other:?}"),
        }
    }
    assert_eq!((infer_seen, sim_seen), (16, 16), "zero dropped replies");

    shutdown_wire(&addr, handle);
}

#[test]
fn epoll_wire_simulate_matches_direct_simulation() {
    let (addr, handle) = start_epoll_wire(mock_router());
    let mut client = WireClient::connect(&addr, T).expect("connect");
    for (model, variant, size) in [
        ("mobilenet-v2", FuseVariant::Base, 16),
        ("mobilenet-v3-small", FuseVariant::Full, 32),
    ] {
        let resp = client
            .roundtrip(&Request::new(
                7,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo(model.into()),
                    variant,
                    config: ConfigPatch::sized(size),
                },
            ))
            .expect("roundtrip");
        let got = match resp.result {
            Ok(Reply::Sim(s)) => s,
            other => panic!("{model}: unexpected {other:?}"),
        };
        let net = models::by_name(model).unwrap();
        let expect = simulate_network(&variant.apply(&net), &SimConfig::with_size(size));
        assert_eq!(got.total_cycles, expect.total_cycles, "{model}: epoll wire parity");
    }
    drop(client);
    shutdown_wire(&addr, handle);
}

#[test]
fn epoll_wire_sweep_streams_and_interleaves_with_infers() {
    // The event loop's pump must interleave sweep row frames with
    // pipelined one-shot replies on ONE connection, exactly like the
    // per-ticket forwarder threads it replaced.
    let (addr, handle) = start_epoll_wire(mock_router());
    let mut client = WireClient::connect(&addr, T).expect("connect");

    const SIZES: [usize; 8] = [4, 8, 12, 16, 24, 32, 48, 64];
    let variants = [FuseVariant::Base, FuseVariant::Half, FuseVariant::Full];
    client
        .send(&Request::new(
            7,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: variants.to_vec(),
                configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
            },
        ))
        .expect("send sweep");
    for id in 100..104u64 {
        client
            .send(&Request::new(id, RequestBody::Infer { input: vec![id as f32; 4] }))
            .expect("send infer");
    }

    let mut incremental_before_final = 0usize;
    let mut rows = Vec::new();
    let mut infer_answers = 0usize;
    loop {
        let (id, frame) = client.recv_any().expect("frame");
        match frame {
            Frame::Progress { done, total } => {
                assert_eq!(id, 7);
                assert_eq!(total, 24, "1 model × 3 variants × 8 sizes");
                assert!(done <= total);
                incremental_before_final += 1;
            }
            Frame::Row(row) => {
                assert_eq!(id, 7, "rows must not leak into infer streams");
                incremental_before_final += 1;
                rows.push(row);
            }
            Frame::SearchRow(p) => panic!("search row in a sweep/infer stream: {p:?}"),
            Frame::Final(Ok(Reply::Infer(r))) => {
                assert!((100..104).contains(&id));
                assert_eq!(r.output[0], (4 * id) as f32);
                infer_answers += 1;
            }
            Frame::Final(result) => {
                assert_eq!(id, 7);
                assert_eq!(result, Ok(Reply::Done));
                break;
            }
        }
    }
    // drain any infer finals that landed after the sweep's Final
    while infer_answers < 4 {
        match client.recv_any().expect("trailing infer final") {
            (id, Frame::Final(Ok(Reply::Infer(r)))) => {
                assert_eq!(r.output[0], (4 * id) as f32);
                infer_answers += 1;
            }
            (id, frame) => panic!("unexpected trailing frame {frame:?} for id {id}"),
        }
    }
    assert!(
        incremental_before_final >= 2,
        "want ≥2 incremental frames before Final, got {incremental_before_final}"
    );
    assert_rows_match(&rows, &serial_reference(&["mobilenet-v2"], &variants, &SIZES));

    drop(client);
    shutdown_wire(&addr, handle);
}

#[test]
fn epoll_wire_request_budget_answers_busy_and_closes() {
    let router = mock_router();
    let server = WireServer::bind("127.0.0.1:0", router.clone())
        .expect("bind")
        .with_transport(Transport::Epoll)
        .with_request_budget(Some(2));
    let addr = server.local_addr().to_string();
    let stop_handle = thread::spawn(move || server.run().expect("run"));

    let mut client = WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");
    for id in [1u64, 2] {
        let resp = client
            .roundtrip(&Request::new(id, RequestBody::Infer { input: vec![1.0; 4] }))
            .expect("admitted roundtrip");
        assert!(resp.is_ok(), "{resp:?}");
    }
    let resp = client
        .roundtrip(&Request::new(3, RequestBody::Infer { input: vec![1.0; 4] }))
        .expect("the bounce is still a well-formed frame");
    assert_eq!(resp.result, Err(ServeError::Busy), "budget must bounce request 3");
    // past the budget the server closes the connection
    assert!(
        client.roundtrip(&Request::new(4, RequestBody::Stats)).is_err(),
        "connection must be closed after the budget bounce"
    );

    // a fresh connection gets a fresh budget — and can shut us down
    shutdown_wire(&addr, stop_handle);
}

#[test]
fn epoll_http_oneshot_sse_and_error_taxonomy() {
    let (addr, handle) = start_epoll_http(mock_router());

    // healthz + one-shot infer, stock client helpers unchanged
    let reply = http_call(&addr, "/healthz", None, None, T).expect("healthz");
    assert_eq!(reply.status, 200, "{}", reply.body);
    assert!(reply.body.contains("\"protocol_version\":2"), "{}", reply.body);

    let reply = http_call(&addr, "/v1/infer", Some("{\"id\":7,\"input\":[1,2,3,4]}"), None, T)
        .expect("infer");
    assert_eq!(reply.status, 200, "{}", reply.body);
    let resp = reply.response().expect("terminal frame body");
    assert_eq!(resp.id, 7);
    match resp.result {
        Ok(Reply::Infer(r)) => assert_eq!(r.output, vec![10.0, 11.0]),
        other => panic!("expected infer reply, got {other:?}"),
    }

    // SSE sweep: rows bit-identical to the serial reference
    const SIZES: [usize; 4] = [8, 16, 24, 32];
    let variants = [FuseVariant::Base, FuseVariant::Half];
    let body = encode_request_body(&Request::new(
        1,
        RequestBody::Sweep {
            models: vec!["mobilenet-v3-small".into()],
            variants: variants.to_vec(),
            configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
        },
    ));
    let mut rows: Vec<SweepRow> = Vec::new();
    let resp = http_sse(&addr, "/v1/sweep", &body, None, T, |id, frame| {
        assert_eq!(id, 1);
        if let Frame::Row(row) = frame {
            rows.push(row.clone());
        }
    })
    .expect("sse sweep");
    assert!(resp.is_ok(), "{resp:?}");
    assert_rows_match(&rows, &serial_reference(&["mobilenet-v3-small"], &variants, &SIZES));

    // error taxonomy parity: 400 / 404 / 405 / 504
    let reply = http_call(&addr, "/v1/simulate", Some("{not json"), None, T).expect("call");
    assert_eq!(reply.status, 400, "{}", reply.body);
    assert!(matches!(reply.response().unwrap().result, Err(ServeError::BadRequest(_))));
    let reply = http_call(&addr, "/v1/frobnicate", None, None, T).expect("call");
    assert_eq!(reply.status, 404, "{}", reply.body);
    let reply = http_call(&addr, "/v1/sweep", None, None, T).expect("call");
    assert_eq!(reply.status, 405, "{}", reply.body);
    let req = Request::new(
        9,
        RequestBody::Simulate {
            model: ModelSpec::Zoo("mobilenet-v2".into()),
            variant: FuseVariant::Base,
            config: ConfigPatch::default(),
        },
    )
    .with_deadline_ms(0);
    let reply = http_call(&addr, "/v1/simulate", Some(&encode_request_body(&req)), None, T)
        .expect("call");
    assert_eq!(reply.status, 504, "{}", reply.body);
    assert_eq!(reply.response().unwrap().result, Err(ServeError::Deadline));

    // shutdown over the epoll HTTP loop
    let reply = http_call(&addr, "/v1/shutdown", Some("{}"), None, T).expect("shutdown");
    assert_eq!(reply.status, 200, "{}", reply.body);
    handle.join().expect("http listener");
}

#[test]
fn epoll_http_keep_alive_budget_answers_429() {
    let router = mock_router();
    let stop = StopLatch::new();
    let http = HttpServer::bind("127.0.0.1:0", router)
        .expect("bind http")
        .with_transport(Transport::Epoll)
        .with_request_budget(Some(2))
        .with_stop(stop.clone());
    let addr = http.local_addr().to_string();
    let handle = thread::spawn(move || http.run().expect("http run"));

    // three sequential keep-alive calls: 200, 200, then the bounce
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats 1");
    assert_eq!(reply.status, 200);
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats 2");
    assert_eq!(reply.status, 200);
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats 3");
    // http_call opens a fresh connection per call, so each gets a fresh
    // budget; pipelining on one connection is what trips it. Drive raw:
    use std::io::{Read as _, Write as _};
    let mut conn = std::net::TcpStream::connect(&addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let one = format!("GET /v1/stats HTTP/1.1\r\nhost: {addr}\r\n\r\n");
    conn.write_all(one.repeat(3).as_bytes()).expect("pipeline 3 requests");
    let mut raw = String::new();
    let _ = conn.read_to_string(&mut raw); // server closes after the bounce
    let codes: Vec<&str> = raw
        .lines()
        .filter(|l| l.starts_with("HTTP/1.1 "))
        .map(|l| &l[9..12])
        .collect();
    assert_eq!(codes, vec!["200", "200", "429"], "budget must bounce the third request");
    assert_eq!(reply.status, 200, "fresh connections keep their own budget");

    stop.trip();
    handle.join().expect("http listener");
}

#[test]
fn epoll_and_threaded_transports_agree_on_one_router() {
    // Both concurrency models mount ONE Router at once; identical sweeps
    // must agree cell-for-cell, and the shared stop latch stops both.
    let router = mock_router();
    let gauges = TransportGauges::new();
    let stop = StopLatch::new();
    let threaded = WireServer::bind("127.0.0.1:0", router.clone())
        .expect("bind threaded")
        .with_stop(stop.clone())
        .with_gauges(gauges.clone());
    let epoll = WireServer::bind("127.0.0.1:0", router)
        .expect("bind epoll")
        .with_transport(Transport::Epoll)
        .with_stop(stop)
        .with_gauges(gauges);
    let threaded_addr = threaded.local_addr().to_string();
    let epoll_addr = epoll.local_addr().to_string();
    let threaded_handle = thread::spawn(move || threaded.run().expect("threaded run"));
    let epoll_handle = thread::spawn(move || epoll.run().expect("epoll run"));

    const SIZES: [usize; 4] = [8, 16, 24, 32];
    let variants = [FuseVariant::Base, FuseVariant::Half];
    let sweep = |addr: String| {
        thread::spawn(move || {
            let mut client = WireClient::connect(&addr, T).expect("connect");
            client
                .send(&Request::new(
                    11,
                    RequestBody::Sweep {
                        models: vec!["mobilenet-v2".into()],
                        variants: variants.to_vec(),
                        configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
                    },
                ))
                .expect("send sweep");
            let mut rows = Vec::new();
            loop {
                match client.recv_frame(11).expect("frame") {
                    Frame::Progress { .. } => {}
                    Frame::Row(row) => rows.push(row),
                    Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
                    Frame::Final(result) => {
                        assert_eq!(result, Ok(Reply::Done));
                        break;
                    }
                }
            }
            rows
        })
    };
    let threaded_rows = sweep(threaded_addr.clone()).join().expect("threaded sweep");
    let epoll_rows = sweep(epoll_addr.clone()).join().expect("epoll sweep");
    assert_eq!(threaded_rows, epoll_rows, "transports must agree cell-for-cell");
    assert_rows_match(&epoll_rows, &serial_reference(&["mobilenet-v2"], &variants, &SIZES));

    // shutdown over the epoll listener trips the shared latch: both exit
    let mut client = WireClient::connect(&epoll_addr, Duration::from_secs(30)).expect("connect");
    let resp = client
        .roundtrip(&Request::new(u64::MAX, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    drop(client);
    epoll_handle.join().expect("epoll listener");
    threaded_handle.join().expect("threaded listener released by the shared latch");
}
