//! Connection-churn regression: transports must not leak connection,
//! stream, or thread accounting under sustained open/close traffic.
//! The live gauges ([`TransportGauges`]) are shared between the test
//! and the server, so every scenario can assert the *exact* quiescent
//! state instead of eyeballing `lsof`:
//!
//! * ~200 sequential TCP connections (connect → one request → close)
//!   leave `open_conns`/`active_streams` at zero;
//! * 64 concurrent TCP connections with sweeps in flight register 64
//!   open connections, and all gauges return to baseline after the
//!   churn — including the half that disconnect mid-stream;
//! * the same sequence over HTTP (sequential keep-alive-less calls +
//!   concurrent SSE sweeps with mid-stream aborts);
//! * a mid-sweep client disconnect frees its stream slot: a bounded
//!   batch lane that a vanished client was occupying admits new work
//!   again, and `active_streams` drops back to zero;
//! * the wire `stats` reply carries the same gauge values (overlay
//!   wiring), observed while a connection is provably open.
//!
//! Threaded-transport scenarios run everywhere; the epoll copies are
//! Linux-only like the event loop itself.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::{
    request_once, ConfigPatch, Frame, HttpServer, MockEngine, Reply, Request, RequestBody,
    Router, ServeError, Server, SimServer, Transport, TransportGauges, WireClient, WireServer,
};
use fuseconv::sim::{FuseVariant, LayerCache, ResultCache};
use fuseconv::testkit::{wait_until, TestServer};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Duration;

const T: Duration = Duration::from_secs(300);

/// Mock router with a roomy sim pool (the churn is the subject here,
/// not admission control). The gauges are attached to the Router so
/// wire `stats` replies overlay them, same as `fuseconv serve` does.
fn mock_router(gauges: &TransportGauges) -> Arc<Router> {
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 256);
    Arc::new(
        Router::new(sim)
            .with_engine(Server::start(
                MockEngine::new(4, 2, 8),
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            ))
            .with_gauges(gauges.clone()),
    )
}

fn small_sweep(id: u64) -> Request {
    Request::new(
        id,
        RequestBody::Sweep {
            models: vec!["mobilenet-v2".into()],
            variants: vec![FuseVariant::Base, FuseVariant::Half],
            configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(16)],
        },
    )
}

/// Sequential + concurrent churn over the TCP frame frontend.
fn tcp_churn(transport: Transport) {
    let gauges = TransportGauges::new();
    let wire = WireServer::bind("127.0.0.1:0", mock_router(&gauges))
        .expect("bind")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_wire(wire);
    let addr = server.addr().to_string();

    // -- 200 sequential connect → infer → close cycles --
    for i in 0..200u64 {
        let mut client =
            WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");
        let resp = client
            .roundtrip(&Request::new(i, RequestBody::Infer { input: vec![1.0; 4] }))
            .expect("roundtrip");
        assert!(resp.is_ok(), "churn request {i}: {resp:?}");
    }
    wait_until("sequential churn to quiesce", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });

    // -- 64 concurrent connections, each with a sweep in flight --
    // Every worker holds at the barrier with ≥1 streamed frame received,
    // so all 64 connections and their streams are provably live at once.
    let hold = Arc::new(Barrier::new(65));
    let workers: Vec<_> = (0..64u64)
        .map(|i| {
            let addr = addr.clone();
            let hold = Arc::clone(&hold);
            thread::spawn(move || {
                let mut client = WireClient::connect(&addr, T).expect("connect");
                client.send(&small_sweep(i)).expect("send sweep");
                let first = client.recv_frame(i).expect("first streamed frame");
                assert!(!first.is_final(), "a 4-cell sweep must stream before Final");
                hold.wait();
                if i % 2 == 0 {
                    // vanish mid-stream: the server must reap the
                    // connection and its stream slot on its own
                    drop(client);
                } else {
                    loop {
                        if client.recv_frame(i).expect("frame").is_final() {
                            break;
                        }
                    }
                }
            })
        })
        .collect();
    hold.wait();
    assert_eq!(gauges.open_conns(), 64, "all churn connections live at the barrier");
    // the wire stats reply overlays the same gauges — observed while the
    // 64 connections are provably open
    let resp = request_once(&addr, &Request::new(0, RequestBody::Stats), T).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            // 64 held workers, plus the stats connection itself
            assert!(
                s.open_conns >= 64,
                "stats overlay must see the live connections, got {}",
                s.open_conns
            );
        }
        other => panic!("expected stats, got {other:?}"),
    }
    for w in workers {
        w.join().expect("churn worker");
    }
    wait_until("concurrent churn to quiesce", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });

    // -- clean shutdown --
    server.shutdown();
}

/// Sequential + concurrent churn over the HTTP frontend.
fn http_churn(transport: Transport) {
    let gauges = TransportGauges::new();
    let http = HttpServer::bind("127.0.0.1:0", mock_router(&gauges))
        .expect("bind http")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_http(http);
    let addr = server.addr().to_string();

    // -- 200 sequential one-shot calls (connection: close each) --
    for _ in 0..200 {
        let reply = fuseconv::coordinator::http_call(&addr, "/v1/stats", None, None, T)
            .expect("stats");
        assert_eq!(reply.status, 200);
    }
    wait_until("sequential HTTP churn to quiesce", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });

    // -- 64 concurrent raw SSE sweeps; half abort mid-stream --
    let body = fuseconv::coordinator::wire::encode_request_body(&small_sweep(1));
    let hold = Arc::new(Barrier::new(65));
    let workers: Vec<_> = (0..64u32)
        .map(|i| {
            let addr = addr.clone();
            let body = body.clone();
            let hold = Arc::clone(&hold);
            thread::spawn(move || {
                let mut conn = TcpStream::connect(&addr).expect("connect");
                conn.set_read_timeout(Some(T)).unwrap();
                // connection: close so the drain below sees EOF after
                // the final chunk instead of a parked keep-alive socket
                let req = format!(
                    "POST /v1/sweep HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\
                     content-type: application/json\r\ncontent-length: {}\r\n\r\n{body}",
                    body.len()
                );
                conn.write_all(req.as_bytes()).expect("send sweep");
                // read at least the SSE head: the stream slot is live
                let mut buf = [0u8; 256];
                let n = conn.read(&mut buf).expect("sse head");
                assert!(n > 0, "server must start streaming");
                hold.wait();
                if i % 2 == 0 {
                    drop(conn); // mid-stream abort
                } else {
                    // drain until the server finishes the chunked stream
                    let mut rest = Vec::new();
                    conn.read_to_end(&mut rest).expect("drain sse");
                    let text = String::from_utf8_lossy(&rest);
                    assert!(text.contains("final"), "stream must end with a final event");
                }
            })
        })
        .collect();
    hold.wait();
    assert_eq!(gauges.open_conns(), 64, "all SSE connections live at the barrier");
    for w in workers {
        w.join().expect("sse worker");
    }
    wait_until("concurrent HTTP churn to quiesce", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });

    server.shutdown();
}

/// A client that vanishes mid-sweep must release its batch-lane slot:
/// with the lane bounded at 1, follow-up sweeps regain admission.
fn disconnect_frees_stream_slot(transport: Transport) {
    let sim = SimServer::with_lanes(2, Arc::new(LayerCache::new()), 64, 1);
    let router = Arc::new(Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )));
    let gauges = TransportGauges::new();
    let wire = WireServer::bind("127.0.0.1:0", router)
        .expect("bind")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_wire(wire);
    let addr = server.addr().to_string();

    // occupy the single batch-lane slot, then vanish mid-stream
    let mut doomed = WireClient::connect(&addr, T).expect("connect");
    doomed
        .send(&Request::new(
            1,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
                configs: (0..6).map(|i| ConfigPatch::sized(8 << (i % 4))).collect(),
            },
        ))
        .expect("send big sweep");
    assert!(
        !doomed.recv_frame(1).expect("first frame").is_final(),
        "the sweep must be mid-stream when the client vanishes"
    );
    drop(doomed);

    // the server reaps the dead connection and its stream slot…
    wait_until("the vanished client's slots to free", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });
    // …and the bounded lane admits new sweeps again (the in-flight work
    // may still be draining server-side, so admission is awaited too)
    wait_until("the batch lane to admit a new sweep", || {
        let mut probe = WireClient::connect(&addr, T).expect("connect");
        let resp = probe.roundtrip(&small_sweep(2)).expect("probe sweep");
        match resp.result {
            Ok(Reply::Sweep(rows)) => {
                assert_eq!(rows.len(), 4);
                true
            }
            Err(ServeError::Busy) => false,
            other => panic!("probe sweep: unexpected {other:?}"),
        }
    });

    server.shutdown();
}

/// Result-cache churn regression: a follower that vanishes while
/// coalesced onto another request's in-flight simulation must neither
/// stall the single-flight leader nor leak the in-flight cache entry.
/// Half of K identical concurrent sweeps disconnect right after their
/// up-front progress frame; the survivors still drain complete row
/// streams, the gauges quiesce, the miss ledger stays exact (each
/// unique cell simulated once), and a later probe sweep is served from
/// the published entries.
fn follower_disconnect_mid_coalesce(transport: Transport) {
    let results = Arc::new(ResultCache::new(64));
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 256)
        .with_result_cache(Arc::clone(&results));
    let gauges = TransportGauges::new();
    let router = Arc::new(
        Router::new(sim)
            .with_engine(Server::start(
                MockEngine::new(4, 2, 8),
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            ))
            .with_gauges(gauges.clone()),
    );
    let wire = WireServer::bind("127.0.0.1:0", router)
        .expect("bind")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_wire(wire);
    let addr = server.addr().to_string();

    const K: u64 = 8;
    const CELLS: u64 = 4; // small_sweep: 1 model × 2 variants × 2 sizes
    let hold = Arc::new(Barrier::new(K as usize));
    let workers: Vec<_> = (0..K)
        .map(|i| {
            let addr = addr.clone();
            let hold = Arc::clone(&hold);
            thread::spawn(move || {
                let mut client = WireClient::connect(&addr, T).expect("connect");
                client.send(&small_sweep(i)).expect("send sweep");
                // the up-front progress frame: the sweep is provably live
                assert!(!client.recv_frame(i).expect("first frame").is_final());
                hold.wait();
                if i % 2 == 0 {
                    drop(client); // follower vanishes mid-coalesce
                    return 0;
                }
                let mut rows: u64 = 0;
                loop {
                    match client.recv_frame(i).expect("frame") {
                        Frame::Row(_) => rows += 1,
                        Frame::Progress { .. } => {}
                        Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
                        Frame::Final(result) => {
                            assert_eq!(result, Ok(Reply::Done));
                            return rows;
                        }
                    }
                }
            })
        })
        .collect();
    for (i, w) in workers.into_iter().enumerate() {
        let rows = w.join().expect("sweep worker");
        if i % 2 == 1 {
            assert_eq!(rows, CELLS, "survivors must drain their full streams");
        }
    }
    // the vanished followers' server-side sweeps drain on their own
    wait_until("disconnected followers to quiesce", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });
    // K sweeps × 4 cells = 32 lookups; wait for the last detached sweep
    // thread, then the single-flight ledger must be exact
    wait_until("every server-side sweep to finish", || {
        let s = results.stats();
        s.hits + s.coalesced >= (K - 1) * CELLS
    });
    let s = results.stats();
    assert_eq!(s.misses, CELLS, "each unique cell simulated exactly once");
    assert_eq!(s.hits + s.coalesced, (K - 1) * CELLS);
    assert_eq!(s.entries, CELLS, "no abandoned in-flight entry may leak");

    // the leader really published despite its dead followers: a fresh
    // probe is served from cache without a single new simulation
    let mut probe = WireClient::connect(&addr, T).expect("connect");
    match probe.roundtrip(&small_sweep(99)).expect("probe sweep").result {
        Ok(Reply::Sweep(rows)) => assert_eq!(rows.len(), CELLS as usize),
        other => panic!("probe sweep: unexpected {other:?}"),
    }
    let after = results.stats();
    assert_eq!(after.misses, CELLS, "the probe must not re-simulate");
    assert_eq!(after.hits + after.coalesced, K * CELLS);
    drop(probe);

    server.shutdown();
}

/// A disconnected sweep client must stop burning pool cycles: the sink
/// failure trips the sweep's CancelToken, and `run_sweep_coalesced`
/// skips the remaining cells. Observed through the result cache's miss
/// ledger — every simulated cell is a miss on this all-unique grid, so
/// a frozen `result_misses` proves the pool went idle, and a count
/// below the grid size proves cells were actually skipped.
fn disconnect_cancels_sweep_work(transport: Transport) {
    let results = Arc::new(ResultCache::new(256));
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 256)
        .with_result_cache(Arc::clone(&results));
    let gauges = TransportGauges::new();
    let router = Arc::new(
        Router::new(sim)
            .with_engine(Server::start(
                MockEngine::new(4, 2, 8),
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            ))
            .with_gauges(gauges.clone()),
    );
    let wire = WireServer::bind("127.0.0.1:0", router)
        .expect("bind")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_wire(wire);
    let addr = server.addr().to_string();

    // 2 models × 3 variants × 8 sizes = 48 unique, individually cheap
    // cells — far more work than can finish before the disconnect lands,
    // with no single cell slow enough to fake a frozen ledger below.
    const TOTAL: u64 = 48;
    let mut doomed = WireClient::connect(&addr, T).expect("connect");
    doomed
        .send(&Request::new(
            1,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into(), "mobilenet-v3-large".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
                configs: (0..8).map(|i| ConfigPatch::sized(8 + 4 * i)).collect(),
            },
        ))
        .expect("send sweep");
    assert!(
        !doomed.recv_frame(1).expect("first frame").is_final(),
        "the sweep must be mid-stream when the client vanishes"
    );
    drop(doomed);

    wait_until("the vanished client to be reaped", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });
    // wait for the miss ledger to stop moving over a window far longer
    // than any one cell, so a frozen sample can't be two workers merely
    // busy on slow cells (a cancelled sweep drains within the couple of
    // cells already in flight on the pool)…
    let mut last = results.stats().misses;
    wait_until("sweep work to stop after the disconnect", || {
        thread::sleep(Duration::from_millis(1000));
        let now = results.stats().misses;
        let stable = now == last;
        last = now;
        stable
    });
    let frozen = results.stats().misses;
    assert!(
        frozen < TOTAL,
        "disconnect must cancel the remaining cells, but all {TOTAL} were simulated"
    );
    // …and prove it stays frozen: no background thread is still pricing
    // cells for a client that no longer exists.
    thread::sleep(Duration::from_millis(500));
    assert_eq!(
        results.stats().misses,
        frozen,
        "result_misses kept growing after the client disconnected"
    );

    server.shutdown();
}

#[test]
fn threaded_tcp_churn_returns_gauges_to_baseline() {
    tcp_churn(Transport::Threaded);
}

#[test]
fn threaded_disconnect_cancels_sweep_work() {
    disconnect_cancels_sweep_work(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_disconnect_cancels_sweep_work() {
    disconnect_cancels_sweep_work(Transport::Epoll);
}

#[test]
fn threaded_follower_disconnect_mid_coalesce_never_stalls() {
    follower_disconnect_mid_coalesce(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_follower_disconnect_mid_coalesce_never_stalls() {
    follower_disconnect_mid_coalesce(Transport::Epoll);
}

#[test]
fn threaded_http_churn_returns_gauges_to_baseline() {
    http_churn(Transport::Threaded);
}

#[test]
fn threaded_disconnect_mid_sweep_frees_stream_slot() {
    disconnect_frees_stream_slot(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_tcp_churn_returns_gauges_to_baseline() {
    tcp_churn(Transport::Epoll);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_http_churn_returns_gauges_to_baseline() {
    http_churn(Transport::Epoll);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_disconnect_mid_sweep_frees_stream_slot() {
    disconnect_frees_stream_slot(Transport::Epoll);
}

#[test]
fn stats_without_gauges_reports_zeroes() {
    // A server with no gauge registry (direct Router, no overlay) still
    // answers stats — the gauge fields just stay at their defaults.
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 64);
    let router = Arc::new(Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )));
    let server = TestServer::wire(router);
    let addr = server.addr().to_string();
    let resp = request_once(&addr, &Request::new(0, RequestBody::Stats), T).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(
                (s.open_conns, s.active_streams, s.transport_threads),
                (0, 0, 0),
                "ungauged servers report zeroed gauges"
            );
        }
        other => panic!("expected stats, got {other:?}"),
    }
    server.shutdown();
}
