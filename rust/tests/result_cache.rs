//! Global result cache, end to end over the wire frontend:
//!
//! * K concurrent identical sweeps through one listener simulate each
//!   grid cell exactly once (single-flight dedup, proven by the cache's
//!   own miss counter — a miss IS a simulation), while every client
//!   still receives its own complete, plan-ordered row stream;
//! * every client's rows are identical to each other and to a local
//!   serial sweep of the same grid (a cache hit may change latency,
//!   never rows);
//! * `Simulate` point queries and per-cell `Sweep` lookups share one
//!   cache — a point query warms the sweep path and vice versa;
//! * the `result_*` counters render in wire `stats` replies, and stay
//!   zeroed on a server running without `--cache-entries`.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::{
    ConfigPatch, Frame, MockEngine, ModelSpec, Reply, Request, RequestBody, Router, Server,
    SimServer, SweepRow, WireClient, WireServer,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, FuseVariant, LayerCache, ResultCache, SimConfig, SweepPlan,
};
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Duration;

const T: Duration = Duration::from_secs(300);

/// Frontend with a result cache attached; the cache handle stays with
/// the test so counters can be asserted directly.
fn start_cached_frontend(entries: usize) -> (String, thread::JoinHandle<()>, Arc<ResultCache>) {
    let results = Arc::new(ResultCache::new(entries));
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 256)
        .with_result_cache(Arc::clone(&results));
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let handle = thread::spawn(move || server.run().expect("frontend run"));
    (addr, handle, results)
}

fn shutdown_frontend(addr: &str, handle: thread::JoinHandle<()>) {
    let mut client = WireClient::connect(addr, Duration::from_secs(30)).expect("connect");
    let resp = client
        .roundtrip(&Request::new(u64::MAX, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    handle.join().expect("listener thread");
}

/// The one grid every test in this file sweeps: 1 model × 2 variants ×
/// 2 sizes = 4 cells.
const GRID_CELLS: usize = 4;

fn grid_sweep(id: u64) -> Request {
    Request::new(
        id,
        RequestBody::Sweep {
            models: vec!["mobilenet-v3-small".into()],
            variants: vec![FuseVariant::Base, FuseVariant::Half],
            configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(16)],
        },
    )
}

/// Drain one request's stream: plan-ordered rows plus its Final.
fn collect_rows(client: &mut WireClient, id: u64) -> Vec<SweepRow> {
    let mut rows = Vec::new();
    loop {
        match client.recv_frame(id).expect("stream frame") {
            Frame::Progress { done, total } => {
                assert_eq!(total as usize, GRID_CELLS);
                assert!(done <= total);
            }
            Frame::Row(row) => rows.push(row),
            Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
            Frame::Final(result) => {
                assert_eq!(result, Ok(Reply::Done));
                return rows;
            }
        }
    }
}

/// Rows must equal the serial local sweep of the same grid, cell for
/// cell and in plan order (floats compared exactly).
fn assert_rows_are_canonical(rows: &[SweepRow]) {
    let reference = run_sweep_serial(&SweepPlan::new(
        vec![models::by_name("mobilenet-v3-small").unwrap()],
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::with_size(8), SimConfig::with_size(16)],
    ));
    assert_eq!(rows.len(), reference.records().len());
    for (row, rec) in rows.iter().zip(reference.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!(row.total_cycles, rec.total_cycles());
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }
}

#[test]
fn concurrent_identical_sweeps_simulate_each_cell_exactly_once() {
    let (addr, handle, results) = start_cached_frontend(64);

    // K identical sweeps released together: whatever the interleaving,
    // each of the 4 unique cells may simulate only once — every other
    // lookup must resolve as a hit (entry already published) or a
    // coalesce (joined the leader's in-flight simulation).
    const K: usize = 6;
    let release = Arc::new(Barrier::new(K));
    let clients: Vec<_> = (0..K as u64)
        .map(|i| {
            let addr = addr.clone();
            let release = Arc::clone(&release);
            thread::spawn(move || {
                let mut client = WireClient::connect(&addr, T).expect("connect");
                release.wait();
                client.send(&grid_sweep(i)).expect("send sweep");
                collect_rows(&mut client, i)
            })
        })
        .collect();
    let all_rows: Vec<Vec<SweepRow>> =
        clients.into_iter().map(|c| c.join().expect("sweep client")).collect();

    // every client got its own full plan-ordered stream...
    for rows in &all_rows {
        assert_rows_are_canonical(rows);
    }
    // ...and the streams are identical to each other
    for rows in &all_rows[1..] {
        assert_eq!(rows, &all_rows[0], "coalesced streams must be identical");
    }

    let s = results.stats();
    assert_eq!(
        s.misses as usize, GRID_CELLS,
        "each unique cell simulates exactly once across all {K} sweeps"
    );
    assert_eq!(
        (s.hits + s.coalesced) as usize,
        (K - 1) * GRID_CELLS,
        "every other lookup is served without simulating"
    );
    assert_eq!(s.entries as usize, GRID_CELLS);
    assert!(s.bytes > 0);

    shutdown_frontend(&addr, handle);
}

#[test]
fn point_queries_and_sweep_cells_share_one_cache() {
    let (addr, handle, results) = start_cached_frontend(64);
    let mut client = WireClient::connect(&addr, T).expect("connect");

    // a Simulate point query warms the cache...
    let scenario = RequestBody::Simulate {
        model: ModelSpec::Zoo("mobilenet-v2".into()),
        variant: FuseVariant::Half,
        config: ConfigPatch::sized(8),
    };
    let first = client.roundtrip(&Request::new(1, scenario.clone())).expect("simulate");
    let cycles = match first.result {
        Ok(Reply::Sim(s)) => s.total_cycles,
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(results.stats().misses, 1);

    // ...a one-cell sweep of the same scenario is a hit, not a miss...
    client
        .send(&Request::new(
            2,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: vec![FuseVariant::Half],
                configs: vec![ConfigPatch::sized(8)],
            },
        ))
        .expect("send sweep");
    let mut rows = Vec::new();
    loop {
        match client.recv_frame(2).expect("frame") {
            Frame::Row(row) => rows.push(row),
            Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
            Frame::Final(result) => {
                assert_eq!(result, Ok(Reply::Done));
                break;
            }
            Frame::Progress { .. } => {}
        }
    }
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].total_cycles, cycles, "hit must serve the identical result");

    // ...and the repeat point query hits the same entry
    let again = client.roundtrip(&Request::new(3, scenario)).expect("simulate again");
    assert!(again.is_ok());
    let s = results.stats();
    assert_eq!((s.misses, s.hits, s.entries), (1, 2, 1));

    drop(client);
    shutdown_frontend(&addr, handle);
}

#[test]
fn result_counters_render_in_wire_stats() {
    let (addr, handle, _results) = start_cached_frontend(64);
    let mut client = WireClient::connect(&addr, T).expect("connect");

    // cold pass simulates the grid, warm pass is served from cache
    client.send(&grid_sweep(1)).expect("send cold sweep");
    collect_rows(&mut client, 1);
    client.send(&grid_sweep(2)).expect("send warm sweep");
    collect_rows(&mut client, 2);

    let resp = client.roundtrip(&Request::new(3, RequestBody::Stats)).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.result_misses as usize, GRID_CELLS);
            assert_eq!(s.result_hits as usize, GRID_CELLS);
            assert_eq!(s.result_coalesced, 0, "sequential sweeps never coalesce");
            assert_eq!(s.result_evicted, 0);
            assert_eq!(s.result_entries as usize, GRID_CELLS);
            assert!(s.result_bytes > 0);
        }
        other => panic!("unexpected {other:?}"),
    }

    drop(client);
    shutdown_frontend(&addr, handle);
}

#[test]
fn uncached_server_reports_zeroed_result_counters() {
    // no --cache-entries → the additive fields exist but never move
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), 256);
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind");
    let addr = server.local_addr().to_string();
    let handle = thread::spawn(move || server.run().expect("run"));

    let mut client = WireClient::connect(&addr, T).expect("connect");
    client.send(&grid_sweep(1)).expect("send sweep");
    collect_rows(&mut client, 1);
    let resp = client.roundtrip(&Request::new(2, RequestBody::Stats)).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(
                (s.result_hits, s.result_misses, s.result_coalesced, s.result_entries),
                (0, 0, 0, 0),
                "a cacheless server must report zeroed result counters"
            );
        }
        other => panic!("unexpected {other:?}"),
    }

    drop(client);
    shutdown_frontend(&addr, handle);
}
