//! Runtime integration tests over the AOT artifacts (skipped with a notice
//! when `make artifacts` has not run — CI runs them after the build step).
//! The whole file needs the PJRT runtime, so it compiles only under
//! `--features xla`.
#![cfg(feature = "xla")]

use fuseconv::runtime::{
    artifacts_available, default_artifacts_dir, literal_f32, Runtime, Session, Synth,
};

fn runtime() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(default_artifacts_dir()).unwrap())
}

/// Every manifest graph compiles and respects its declared I/O arity.
#[test]
fn all_graphs_compile() {
    let Some(rt) = runtime() else { return };
    let names: Vec<String> = rt.manifest.graphs.keys().cloned().collect();
    assert!(names.len() >= 8, "expected 8 graphs, got {names:?}");
    for name in names {
        let g = rt.graph(&name).unwrap_or_else(|e| panic!("compile {name}: {e:#}"));
        assert!(!g.spec.inputs.is_empty(), "{name} has no inputs");
        assert!(!g.spec.outputs.is_empty(), "{name} has no outputs");
    }
}

/// Teacher and student infer graphs produce different logits from the same
/// input (different operators) but both are finite and well-shaped.
#[test]
fn teacher_student_infer_differ() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.const_usize("infer_batch").unwrap();
    let hw = rt.manifest.const_usize("image_hw").unwrap();
    let classes = rt.manifest.const_usize("num_classes").unwrap();
    let mut synth = Synth::new(hw, classes, 7);
    let (xs, _) = synth.batch(b);
    let x = literal_f32(&xs, &[b, 3, hw, hw]).unwrap();

    let run = |graph: &str, blob: &str, label: &str| -> Vec<f32> {
        let params = rt.load_init(label, blob).unwrap();
        let g = rt.graph(graph).unwrap();
        let mut inputs = params;
        inputs.push(fuseconv::runtime::executor::clone_literal(&x).unwrap());
        g.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap()
    };
    let t = run("teacher_infer", "teacher_init.bin", "teacher");
    let s = run("student_infer", "student_init.bin", "student");
    assert_eq!(t.len(), b * classes);
    assert_eq!(s.len(), b * classes);
    assert!(t.iter().all(|v| v.is_finite()));
    assert!(s.iter().all(|v| v.is_finite()));
    let diff: f32 = t.iter().zip(&s).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "teacher and student identical?");
}

/// Collapse maps scaffold params (teacher + identity adapters) onto student
/// shapes, and the collapsed weights reproduce the teacher's centre
/// row/column (identity-adapter algebra, mirrors python/tests/test_nos.py
/// but exercised through the compiled HLO graph).
#[test]
fn collapse_graph_identity_adapter_algebra() {
    let Some(rt) = runtime() else { return };
    let session = Session::new(&rt).unwrap();
    let teacher = rt.load_init("teacher", "teacher_init.bin").unwrap();
    let blocks = rt.manifest.const_usize("num_blocks").unwrap();
    let k = rt.manifest.const_usize("ksize").unwrap();
    let scaffold = session.scaffold_init(&teacher, blocks, k).unwrap();
    let g = rt.graph("collapse").unwrap();
    let out = g.run(&scaffold).unwrap();
    let student_specs = rt.manifest.param_specs("student").unwrap();
    assert_eq!(out.len(), student_specs.len());
    // spot-check block 0: student fuse_row == teacher dw centre column
    let t_specs = rt.manifest.param_specs("teacher").unwrap();
    let dw_idx = t_specs.iter().position(|s| s.name == "b0.dw").unwrap();
    let row_idx = student_specs.iter().position(|s| s.name == "b0.fuse_row").unwrap();
    let dw = teacher[dw_idx].to_vec::<f32>().unwrap();
    let row = out[row_idx].to_vec::<f32>().unwrap();
    let c = t_specs[dw_idx].dims[0];
    let mid = k / 2;
    for ch in 0..c / 2 {
        for t in 0..k {
            let want = dw[ch * k * k + t * k + mid]; // T_w[ch, t, mid]
            let got = row[ch * k + t];
            assert!((want - got).abs() < 1e-5, "ch {ch} tap {t}: {want} vs {got}");
        }
    }
}

/// One NOS step runs and returns finite loss; the scaffold params change.
#[test]
fn nos_step_executes() {
    let Some(rt) = runtime() else { return };
    let session = Session::new(&rt).unwrap();
    let teacher = rt.load_init("teacher", "teacher_init.bin").unwrap();
    let blocks = rt.manifest.const_usize("num_blocks").unwrap();
    let k = rt.manifest.const_usize("ksize").unwrap();
    let nsc = rt.manifest.const_usize("num_scaffold_params").unwrap();
    let nt = rt.manifest.const_usize("num_teacher_params").unwrap();
    let scaffold = session.scaffold_init(&teacher, blocks, k).unwrap();
    let g = rt.graph("nos_train_step").unwrap();
    let (out, log) = session
        .train_nos(&g, nsc, nt, blocks, scaffold, &teacher, 2, 0.05, 3, 0.5)
        .unwrap();
    assert_eq!(out.len(), nsc);
    assert_eq!(log.entries.len(), 2);
    assert!(log.entries.iter().all(|(_, l, _)| l.is_finite()));
}

/// Eval accuracy on untrained params is near chance (sanity of the whole
/// infer + argmax + labeling path).
#[test]
fn untrained_accuracy_near_chance() {
    let Some(rt) = runtime() else { return };
    let session = Session::new(&rt).unwrap();
    let params = rt.load_init("student", "student_init.bin").unwrap();
    let g = rt.graph("student_infer").unwrap();
    let acc = session.eval_accuracy(&g, &params, 160).unwrap();
    assert!(acc < 0.35, "untrained acc suspiciously high: {acc}");
}
