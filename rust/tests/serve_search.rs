//! Streaming `Search` acceptance over the real transports:
//!
//! * the stream is deterministic — same seed, same spec ⇒ byte-identical
//!   frame sequences across runs, and the remote frontier (TCP frames
//!   and HTTP/SSE alike) equals a local `run_nas_with` of the same
//!   config, point for point, bit for bit;
//! * an explicit `cancel` frame from another connection stops a running
//!   search within one generation and frees its lane slot, on both the
//!   threaded and the epoll transport;
//! * wire auth: a server started with a token answers `unauthorized` to
//!   missing/wrong tokens on TCP (and an unauthorized `Shutdown` does
//!   not stop the deployment) and `401` on HTTP, where `/healthz` stays
//!   open for probes;
//! * an HTTP client that vanishes mid-SSE cancels its search — the
//!   `search_cancelled` counter proves the pool stopped, not just the
//!   socket.

use fuseconv::coordinator::search::{run_nas_with, NasConfig};
use fuseconv::coordinator::wire::{encode_frame, encode_request_body};
use fuseconv::coordinator::{
    http_call_auth, http_sse_auth, ConfigPatch, Evaluator, Frame, HttpServer, Reply, Request,
    RequestBody, Router, SearchReply, SearchSpec, ServeError, SimServer, Transport,
    TransportGauges, WireServer,
};
use fuseconv::exec::CancelToken;
use fuseconv::sim::SimConfig;
use fuseconv::testkit::{stream_frames, wait_until, TestServer};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(300);

/// The one spec every test runs: small population on a tiny array, so a
/// generation is cheap; `iterations` picks short vs effectively-endless.
fn spec(iterations: usize) -> SearchSpec {
    SearchSpec { population: 6, iterations, config: ConfigPatch::sized(8), ..SearchSpec::default() }
}

fn search_req(id: u64, iterations: usize) -> Request {
    Request::new(id, RequestBody::Search { spec: spec(iterations) })
}

/// Simulation-only deployment with a single-slot search lane (so lane
/// accounting is deterministic), on the chosen transport, optionally
/// token-guarded.
fn start_tcp(transport: Transport, auth: Option<&str>) -> (TestServer, TransportGauges) {
    let gauges = TransportGauges::new();
    let sim = SimServer::new(2).with_search_capacity(1);
    let router = Arc::new(Router::new(sim).with_gauges(gauges.clone()));
    let wire = WireServer::bind("127.0.0.1:0", router)
        .expect("bind")
        .with_transport(transport)
        .with_gauges(gauges.clone())
        .with_auth_token(auth.map(str::to_string));
    (TestServer::from_wire(wire), gauges)
}

fn final_search(frames: &[Frame]) -> SearchReply {
    match frames.last() {
        Some(Frame::Final(Ok(Reply::Search(r)))) => r.clone(),
        other => panic!("expected a search terminal, got {other:?}"),
    }
}

#[test]
fn same_seed_streams_are_byte_identical_and_match_local() {
    let (server, _gauges) = start_tcp(Transport::Threaded, None);
    let mut client = server.client(T);

    // Two runs of the same seeded spec over the wire: every frame —
    // progress, rows, terminal — re-encodes to the same bytes.
    client.send(&search_req(5, 3)).expect("send search");
    let first = stream_frames(&mut client, 5);
    client.send(&search_req(5, 3)).expect("send search again");
    let second = stream_frames(&mut client, 5);
    let enc = |frames: &[Frame]| frames.iter().map(|f| encode_frame(5, f)).collect::<Vec<_>>();
    assert_eq!(enc(&first), enc(&second), "same seed must stream byte-identical frames");
    assert!(
        first.iter().any(|f| matches!(f, Frame::SearchRow(_))),
        "per-generation pareto rows must stream"
    );

    // The remote frontier equals the local library run of the same
    // config — genome strings and float bits, not approximately.
    let reply = final_search(&first);
    assert!(!reply.frontier.is_empty());
    assert_eq!(reply.generations, 3);
    let nas = NasConfig { population: 6, iterations: 3, ..NasConfig::default() };
    let local = run_nas_with(
        Arc::new(Evaluator::new(SimConfig::with_size(8))),
        &nas,
        None,
        &CancelToken::new(),
        |_| {},
    );
    assert_eq!(reply.evaluated, local.evaluated as u64);
    assert_eq!(reply.frontier.len(), local.frontier.len());
    for (remote, here) in reply.frontier.iter().zip(&local.frontier) {
        assert_eq!(remote.genome, here.genome.compact());
        assert_eq!(remote.acc.to_bits(), here.acc.to_bits());
        assert_eq!(remote.latency_ms.to_bits(), here.latency_ms.to_bits());
    }

    // The HTTP/SSE transport renders the very same stream: row frames
    // byte-identical to TCP's, the terminal reply equal to TCP's.
    let hserver = TestServer::http(Arc::new(Router::new(SimServer::new(2))));
    let haddr = hserver.addr().to_string();
    let mut sse_rows: Vec<String> = Vec::new();
    let resp = http_sse_auth(
        &haddr,
        "/v1/search",
        &encode_request_body(&search_req(5, 3)),
        None,
        None,
        T,
        |fid, frame| {
            assert_eq!(fid, 5);
            if let Frame::SearchRow(p) = frame {
                sse_rows.push(encode_frame(5, &Frame::SearchRow(p.clone())));
            }
        },
    )
    .expect("sse search");
    let tcp_rows: Vec<String> = first
        .iter()
        .filter(|f| matches!(f, Frame::SearchRow(_)))
        .map(|f| encode_frame(5, f))
        .collect();
    assert_eq!(sse_rows, tcp_rows, "SSE rows must be byte-identical to the TCP stream");
    match resp.result {
        Ok(Reply::Search(r)) => assert_eq!(r, reply, "SSE terminal must equal the TCP terminal"),
        other => panic!("expected a search reply over SSE, got {other:?}"),
    }
    hserver.shutdown();

    let resp = client.roundtrip(&Request::new(99, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    server.join_stopped();
}

fn cancel_frees_the_search_lane(transport: Transport) {
    let (server, _gauges) = start_tcp(transport, None);

    // The long search holds the only lane slot; its first frame proves
    // it is registered and running.
    let mut a = server.client(T);
    a.send(&search_req(1, 1024)).expect("send long search");
    assert!(!a.recv_frame(1).expect("first frame").is_final());

    // While it runs, the lane is full: a second search sheds Busy.
    let mut b = server.client(T);
    let resp = b.roundtrip(&search_req(2, 1)).expect("busy roundtrip");
    assert_eq!(resp.result, Err(ServeError::Busy), "the single search slot must shed");

    // Cancel lands from a DIFFERENT connection — the registry is keyed
    // by request id on the service, not on the victim's socket.
    let resp =
        b.roundtrip(&Request::new(3, RequestBody::Cancel { target: 1 })).expect("cancel ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    let reply = final_search(&stream_frames(&mut a, 1));
    assert!(reply.cancelled, "the terminal must record the cancellation");
    assert!(reply.generations < 1024, "cancel must stop the run within one generation");

    // The slot is released before the terminal frame is sent, so the
    // lane must now admit (and finish) a fresh search.
    b.send(&search_req(4, 1)).expect("send follow-up search");
    let reply = final_search(&stream_frames(&mut b, 4));
    assert!(!reply.cancelled);
    assert_eq!(reply.generations, 1);

    // Taxonomy: the shed request never started; the cancelled and the
    // completed one each count exactly once.
    let resp = b.roundtrip(&Request::new(5, RequestBody::Stats)).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!((s.search_started, s.search_completed, s.search_cancelled), (2, 1, 1));
        }
        other => panic!("expected stats, got {other:?}"),
    }

    let resp = b.roundtrip(&Request::new(9, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    server.join_stopped();
}

#[test]
fn threaded_cancel_frees_the_search_lane() {
    cancel_frees_the_search_lane(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_cancel_frees_the_search_lane() {
    cancel_frees_the_search_lane(Transport::Epoll);
}

fn tcp_auth_taxonomy(transport: Transport) {
    let (server, _gauges) = start_tcp(transport, Some("sesame"));
    let mut client = server.client(T);

    // Missing and wrong tokens answer typed unauthorized — the
    // connection survives to try again.
    let resp = client.roundtrip(&Request::new(1, RequestBody::Stats)).expect("no token");
    assert_eq!(resp.result, Err(ServeError::Unauthorized));
    let resp = client
        .roundtrip(&Request::new(2, RequestBody::Stats).with_token("open-sesame"))
        .expect("wrong token");
    assert_eq!(resp.result, Err(ServeError::Unauthorized));

    // An unauthorized Shutdown must NOT stop the deployment...
    let resp = client
        .roundtrip(&Request::new(3, RequestBody::Shutdown).with_token("nope"))
        .expect("unauthorized shutdown");
    assert_eq!(resp.result, Err(ServeError::Unauthorized));

    // ...because the same connection, correctly tokened, is still
    // served — including a full search stream.
    let resp = client
        .roundtrip(&Request::new(4, RequestBody::Stats).with_token("sesame"))
        .expect("authorized stats");
    assert!(matches!(resp.result, Ok(Reply::Stats(_))), "authorized request must serve");
    client.send(&search_req(5, 2).with_token("sesame")).expect("send authorized search");
    let reply = final_search(&stream_frames(&mut client, 5));
    assert!(!reply.cancelled);
    assert!(!reply.frontier.is_empty());

    let resp = client
        .roundtrip(&Request::new(9, RequestBody::Shutdown).with_token("sesame"))
        .expect("authorized shutdown");
    assert_eq!(resp.result, Ok(Reply::Done));
    server.join_stopped();
}

#[test]
fn threaded_auth_rejects_bad_tokens() {
    tcp_auth_taxonomy(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_auth_rejects_bad_tokens() {
    tcp_auth_taxonomy(Transport::Epoll);
}

#[test]
fn http_auth_rejects_bad_bearers_and_healthz_stays_open() {
    let http = HttpServer::bind("127.0.0.1:0", Arc::new(Router::new(SimServer::new(2))))
        .expect("bind http")
        .with_auth_token(Some("sesame".into()));
    let server = TestServer::from_http(http).with_token("sesame");
    let addr = server.addr().to_string();

    // Missing and wrong bearers are 401 with the typed error body.
    let reply = http_call_auth(&addr, "/v1/stats", None, None, None, T).expect("no bearer");
    assert_eq!(reply.status, 401);
    assert!(reply.body.contains("unauthorized"), "typed error body: {}", reply.body);
    let reply =
        http_call_auth(&addr, "/v1/stats", None, None, Some("wrong"), T).expect("wrong bearer");
    assert_eq!(reply.status, 401);

    // A 401'd search never reaches the lane — no stream, no counters.
    let body = encode_request_body(&search_req(7, 2));
    let reply =
        http_call_auth(&addr, "/v1/search", Some(&body), None, None, T).expect("unauth search");
    assert_eq!(reply.status, 401);

    // The liveness probe stays open for unauthenticated orchestrators.
    let reply = http_call_auth(&addr, "/healthz", None, None, None, T).expect("healthz");
    assert_eq!(reply.status, 200);

    // The right bearer serves — stats, and a full SSE search stream.
    let reply =
        http_call_auth(&addr, "/v1/stats", None, None, Some("sesame"), T).expect("auth stats");
    assert_eq!(reply.status, 200);
    match reply.response().expect("stats body").result {
        Ok(Reply::Stats(s)) => assert_eq!(s.search_started, 0, "the 401'd search never started"),
        other => panic!("expected stats, got {other:?}"),
    }
    let mut rows = 0usize;
    let resp = http_sse_auth(&addr, "/v1/search", &body, None, Some("sesame"), T, |_, frame| {
        if matches!(frame, Frame::SearchRow(_)) {
            rows += 1;
        }
    })
    .expect("authorized sse search");
    assert!(matches!(resp.result, Ok(Reply::Search(_))), "bearer search must stream: {resp:?}");
    assert!(rows > 0, "pareto rows must stream over SSE");

    // the shutdown round-trip presents the same bearer
    server.shutdown();
}

fn http_disconnect_cancels_search(transport: Transport) {
    let gauges = TransportGauges::new();
    let sim = SimServer::new(2).with_search_capacity(1);
    let router = Arc::new(Router::new(sim).with_gauges(gauges.clone()));
    let http = HttpServer::bind("127.0.0.1:0", router)
        .expect("bind http")
        .with_transport(transport)
        .with_gauges(gauges.clone());
    let server = TestServer::from_http(http);
    let addr = server.addr().to_string();

    // A raw SSE client that reads the head of the stream and vanishes.
    let body = encode_request_body(&search_req(5, 1024));
    let mut conn = TcpStream::connect(&addr).expect("connect");
    let req = format!(
        "POST /v1/search HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\
         content-length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(req.as_bytes()).expect("send search");
    let mut buf = [0u8; 512];
    let n = conn.read(&mut buf).expect("sse head");
    assert!(n > 0, "the stream must be live before the disconnect");
    drop(conn);

    // The dead socket must cancel the search — not just close the
    // connection: the server-side counter records the cancellation,
    // which means the NAS loop saw the tripped token and stopped.
    wait_until("the vanished SSE client to be reaped", || {
        gauges.open_conns() == 0 && gauges.active_streams() == 0
    });
    wait_until("the abandoned search to record its cancellation", || {
        let reply = http_call_auth(&addr, "/v1/stats", None, None, None, T).expect("stats");
        matches!(
            reply.response().expect("stats body").result,
            Ok(Reply::Stats(s)) if s.search_cancelled == 1
        )
    });

    server.shutdown();
}

#[test]
fn threaded_http_disconnect_cancels_search() {
    http_disconnect_cancels_search(Transport::Threaded);
}

#[cfg(target_os = "linux")]
#[test]
fn epoll_http_disconnect_cancels_search() {
    http_disconnect_cancels_search(Transport::Epoll);
}
