//! Cross-module integration tests: the paper's claims as executable
//! assertions over the full zoo (transform → simulate → compare), plus
//! property-based invariants over randomly generated networks.

use fuseconv::coordinator::search::{
    run_ea, AccuracyPredictor, EaConfig, TrainMethod,
};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, NetBuilder, Network, OpClass, Variant};
use fuseconv::rng::Rng;
use fuseconv::sim::{simulate_network, Dataflow, SimConfig};
use fuseconv::testkit::{forall, no_shrink, Check};

/// Fig 8(a) band: every evaluation network speeds up substantially with
/// FuSe-Half + ST-OS, and FuSe-Full is slower than Half but still wins.
#[test]
fn speedup_bands_hold_across_the_zoo() {
    let cfg = SimConfig::default();
    for net in models::paper_five() {
        let sb = simulate_network(&net, &cfg);
        let sh = simulate_network(&fuse_all(&net, Variant::Half), &cfg);
        let sf = simulate_network(&fuse_all(&net, Variant::Full), &cfg);
        let spd_h = sb.total_cycles as f64 / sh.total_cycles as f64;
        let spd_f = sb.total_cycles as f64 / sf.total_cycles as f64;
        assert!(spd_h > 4.0 && spd_h < 12.0, "{}: Half speedup {spd_h}", net.name);
        assert!(spd_f > 2.0 && spd_f < 8.0, "{}: Full speedup {spd_f}", net.name);
        assert!(spd_h > spd_f, "{}: Half must beat Full", net.name);
    }
}

/// §2.3: depthwise dominates baseline latency despite being a small
/// fraction of MACs (the incommensurate-scaling motivation).
#[test]
fn depthwise_dominates_baseline_latency() {
    let cfg = SimConfig::default();
    for net in models::paper_five() {
        let sim = simulate_network(&net, &cfg);
        let by = sim.cycles_by_class();
        let dw_cycles = *by.get(&OpClass::Depthwise).unwrap_or(&0) as f64;
        let dw_macs = net.macs_by_class()[&OpClass::Depthwise] as f64;
        let cycle_share = dw_cycles / sim.total_cycles as f64;
        let mac_share = dw_macs / net.total_macs() as f64;
        assert!(cycle_share > 0.6, "{}: dw cycle share {cycle_share}", net.name);
        assert!(mac_share < 0.2, "{}: dw MAC share {mac_share}", net.name);
    }
}

/// Fig 10: utilization contrast between depthwise and FuSe bottlenecks.
#[test]
fn utilization_contrast() {
    let cfg = SimConfig::default();
    let net = models::by_name("mnasnet-b1").unwrap();
    let sb = simulate_network(&net, &cfg);
    let sh = simulate_network(&fuse_all(&net, Variant::Half), &cfg);
    for b in net.bottleneck_blocks() {
        let ub = sb.block_utilization(b);
        let uf = sh.block_utilization(b);
        assert!(uf > 2.0 * ub, "block {b}: fuse {uf} vs base {ub}");
        assert!(uf <= 1.0 + 1e-9 && ub <= 1.0 + 1e-9);
    }
}

/// ST-OS ablation: without the broadcast links, FuSe networks lose their
/// advantage (the co-design is load-bearing).
#[test]
fn stos_hardware_is_load_bearing() {
    let with = SimConfig::default();
    let without = SimConfig::default().without_stos();
    let half = fuse_all(&models::by_name("mobilenet-v2").unwrap(), Variant::Half);
    let s_with = simulate_network(&half, &with);
    let s_without = simulate_network(&half, &without);
    assert!(
        s_without.total_cycles > 3 * s_with.total_cycles,
        "ST-OS gain too small: {} vs {}",
        s_without.total_cycles,
        s_with.total_cycles
    );
}

/// Property: MAC conservation — for random networks, Σ pe_cycles over a
/// simulation equals the IR's MAC count (both dataflows).
#[test]
fn property_mac_conservation_random_networks() {
    forall(
        0xFACE,
        40,
        |rng: &mut Rng| random_network(rng),
        no_shrink,
        |net: &Network| {
            for df in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
                let cfg = SimConfig { dataflow: df, ..SimConfig::default() };
                let sim = simulate_network(net, &cfg);
                let pe: u64 = sim.layers.iter().map(|l| l.pe_cycles).sum();
                if pe != net.total_macs() {
                    return Check::Fail(format!(
                        "{df:?}: pe_cycles {pe} != macs {}",
                        net.total_macs()
                    ));
                }
            }
            Check::Pass
        },
    );
}

/// Property: utilization bounded, cycles positive, fuse transform preserves
/// the drop-in contract (same output channel count per block sequence).
#[test]
fn property_fuse_transform_invariants() {
    forall(
        0xBEEF,
        40,
        |rng: &mut Rng| random_network(rng),
        no_shrink,
        |net: &Network| {
            let half = fuse_all(net, Variant::Half);
            // drop-in: same final layer, fewer-or-equal params
            if half.layers.last().unwrap().op != net.layers.last().unwrap().op {
                return Check::Fail("final layer changed".into());
            }
            if half.total_params() > net.total_params() {
                return Check::Fail("params grew under Half".into());
            }
            let cfg = SimConfig::default();
            let sim = simulate_network(&half, &cfg);
            if sim.total_cycles == 0 {
                return Check::Fail("zero cycles".into());
            }
            for l in &sim.layers {
                if l.utilization > 1.0 + 1e-9 {
                    return Check::Fail(format!("{}: util {} > 1", l.name, l.utilization));
                }
            }
            Check::Pass
        },
    );
}

/// Property: hybrid-space fast path == realized-network simulation for
/// random masks (the EA's core correctness requirement).
#[test]
fn property_hybrid_fast_path_consistency() {
    let ev = Evaluator::new(SimConfig::default());
    let base = models::by_name("mobilenet-v3-small").unwrap();
    let space = HybridSpace::new(&base, &ev);
    let n = space.num_blocks();
    forall(
        0xC0DE,
        30,
        |rng: &mut Rng| (0..n).map(|_| rng.chance(0.5)).collect::<Vec<bool>>(),
        no_shrink,
        |mask: &Vec<bool>| {
            let fast = space.cycles(mask);
            let slow = ev.eval(&space.realize(mask)).cycles;
            Check::from_bool(fast == slow, &format!("fast {fast} != slow {slow}"))
        },
    );
}

/// EA integration: the frontier strictly improves on random search with the
/// same evaluation budget.
#[test]
fn ea_beats_random_search_at_equal_budget() {
    let ev = Evaluator::new(SimConfig::default());
    let base = models::by_name("mobilenet-v3-large").unwrap();
    let space = HybridSpace::new(&base, &ev);
    let pred = AccuracyPredictor::for_space(&space);
    let cfg = EaConfig { population: 24, iterations: 20, seed: 5, ..EaConfig::default() };
    let ea = run_ea(&space, &pred, TrainMethod::Nos, &cfg);

    // random baseline with the same budget
    let mut rng = Rng::new(5);
    let n = space.num_blocks();
    let budget = ea.evaluated;
    let mut best_random = f64::MIN;
    let target_lat = ea.best_acc.latency_ms;
    for _ in 0..budget {
        let mask: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        if space.latency_ms(&mask) <= target_lat {
            best_random = best_random.max(pred.predict_mask(&mask, TrainMethod::Nos));
        }
    }
    assert!(
        ea.best_acc.acc >= best_random - 0.05,
        "EA {} vs random {best_random}",
        ea.best_acc.acc
    );
}

/// Random MobileNet-style network for property tests.
fn random_network(rng: &mut Rng) -> Network {
    let hw = *rng.choose(&[32usize, 56, 64, 96]);
    let mut b = NetBuilder::new("rand", hw, 3);
    b.conv("stem", 3, 2, 8 + 8 * rng.below(3), fuseconv::nn::Act::Relu6);
    let blocks = 1 + rng.below(4);
    for i in 0..blocks {
        let (_, _, cin) = b.cursor();
        let k = *rng.choose(&[3usize, 5]);
        let t = 1 + rng.below(4);
        let cout = 8 * (1 + rng.below(6));
        let stride = 1 + rng.below(2);
        b.begin_block();
        if t > 1 {
            b.pw(&format!("b{i}.expand"), cin * t, fuseconv::nn::Act::Relu6);
        }
        b.dw(&format!("b{i}.dw"), k, stride, fuseconv::nn::Act::Relu6);
        b.pw(&format!("b{i}.project"), cout, fuseconv::nn::Act::None);
        b.end_block();
    }
    b.global_pool("pool");
    b.fc("fc", 10, fuseconv::nn::Act::None);
    b.build()
}
