//! Multi-node sharded serving acceptance: a `ShardRouter` front tier
//! over several real `fuseconv serve`-style backends, each a full
//! `Router` behind its own TCP listener.
//!
//! * a sharded sweep over ≥2 backends is identical on the wire to the
//!   same sweep against a single node — row frames byte-for-byte (kind,
//!   order, payload), one consolidated monotonic progress counter, one
//!   terminal `final` — and both match a local serial sweep;
//! * `Simulate` through the front tier prices identically to a direct
//!   in-process `simulate_network`;
//! * `Stats` aggregates every backend's counters and stamps the
//!   backend count (the `request --op stats` regression);
//! * a lost backend is marked `Down` and its work fails over: a pinned
//!   `Simulate` retries once on a survivor, a sweep re-plans the missing
//!   cells mid-stream — and with no survivors left the stream ends with
//!   a typed `final` error, never a hang;
//! * `Shutdown` through the front tier stops the whole deployment;
//! * the HTTP/SSE frontend mounts the shard router unchanged.
//!
//! Fault-injection coverage (killed/black-holed/drained members, probe
//! hardening, membership cache movement) lives in `shard_chaos.rs`.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::shard::{route, ShardRouter};
use fuseconv::coordinator::wire::encode_frame;
use fuseconv::coordinator::{
    http_call, http_sse, request_once, ConfigPatch, Frame, MockEngine, ModelSpec, Reply,
    Request, RequestBody, Router, SearchSpec, ServeError, Server, Service, SimServer,
    SweepRow,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, simulate_network, FuseVariant, ResultCache, SimConfig, SweepPlan,
};
use fuseconv::testkit;
use fuseconv::testkit::TestServer;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

const T: Duration = Duration::from_secs(120);

/// Mount a shard router over `backends` on its own TCP frontend.
fn start_shard_front(backends: Vec<String>) -> TestServer {
    TestServer::wire(Arc::new(ShardRouter::new(backends, T)))
}

/// A host:port that refuses connections (bound once, then released).
fn dead_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind throwaway");
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    addr
}

/// How many (name, size) shard keys rendezvous-route to `fleet[which]`.
/// Ephemeral ports make the split itself random run to run, so tests
/// compute the actual placement instead of assuming one.
fn keys_on(fleet: &[String], which: usize, names: &[&str], sizes: &[usize]) -> usize {
    let mut n = 0;
    for name in names {
        for &size in sizes {
            if route(name, &SimConfig::with_size(size), fleet) == which {
                n += 1;
            }
        }
    }
    n
}

#[test]
fn sharded_sweep_is_frame_identical_to_single_node() {
    let b1 = TestServer::mock_backend();
    let b2 = TestServer::mock_backend();
    let single = TestServer::mock_backend();
    let fleet = vec![b1.addr().to_string(), b2.addr().to_string()];
    let front = start_shard_front(fleet.clone());

    let names = ["mobilenet-v2", "mobilenet-v3-small"];
    let variants = [FuseVariant::Base, FuseVariant::Half];
    let sizes = [8, 16, 32, 64]; // 2 × 2 × 4 = 16 cells

    let mut sc = front.client(T);
    sc.send(&testkit::sweep_req(7, &names, &variants, &sizes)).expect("send sharded sweep");
    let sharded = testkit::stream_frames(&mut sc, 7);

    let mut nc = single.client(T);
    nc.send(&testkit::sweep_req(7, &names, &variants, &sizes)).expect("send single sweep");
    let direct = testkit::stream_frames(&mut nc, 7);

    // Acceptance: identical frame kinds and counts, row frames
    // byte-for-byte identical (order and payload), identical
    // consolidated progress counter, identical terminal frame.
    assert_eq!(
        testkit::row_frames(&sharded, 7),
        testkit::row_frames(&direct, 7),
        "row frames must match"
    );
    assert_eq!(
        testkit::progress_frames(&sharded),
        testkit::progress_frames(&direct),
        "consolidated progress must match the single-node counter"
    );
    assert_eq!(sharded.last(), direct.last(), "terminal frame must match");
    assert_eq!(sharded.len(), direct.len(), "frame-for-frame identical streams");

    // The progress counter is the single consolidated 0..=total walk.
    let ps = testkit::progress_frames(&sharded);
    assert_eq!(ps.first(), Some(&(0, 16)), "up-front progress with the full grid size");
    assert_eq!(ps.len(), 17, "one progress frame per completed cell plus the up-front one");
    assert!(ps.windows(2).all(|w| w[0].0 < w[1].0), "monotonic progress");
    assert!(matches!(sharded.last(), Some(Frame::Final(Ok(Reply::Done)))));

    // Both streams must also equal the local serial reference.
    let plan = SweepPlan::new(
        names.iter().map(|m| models::by_name(m).unwrap()).collect(),
        variants.to_vec(),
        sizes.iter().map(|&s| SimConfig::with_size(s)).collect(),
    );
    let serial = run_sweep_serial(&plan);
    let streamed: Vec<SweepRow> = sharded
        .iter()
        .filter_map(|f| match f {
            Frame::Row(r) => Some(r.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(streamed.len(), serial.records().len());
    for (row, rec) in streamed.iter().zip(serial.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
        assert_eq!(row.total_cycles, rec.total_cycles());
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }

    // Every backend that owns part of the key space must have served
    // its sub-sweeps (rendezvous over ephemeral ports decides the split,
    // so compute it rather than assume it).
    for (i, backend) in [&b1, &b2].into_iter().enumerate() {
        if keys_on(&fleet, i, &names, &sizes) == 0 {
            continue;
        }
        let resp = request_once(backend.addr(), &Request::new(55, RequestBody::Stats), T)
            .expect("backend stats");
        match resp.result {
            Ok(Reply::Stats(s)) => {
                assert!(s.sim_completed >= 1, "backend {i} served no sub-sweep: {s:?}");
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    // Shutdown through the front tier stops the whole deployment.
    let resp = sc.roundtrip(&Request::new(99, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    front.join_stopped();
    b1.join_stopped();
    b2.join_stopped();

    // The stand-alone single node is its own deployment.
    single.shutdown();
}

#[test]
fn sharded_simulate_matches_direct_and_stats_aggregate() {
    let b1 = TestServer::mock_backend();
    let b2 = TestServer::mock_backend();
    let shard = ShardRouter::new(vec![b1.addr().to_string(), b2.addr().to_string()], T);

    let cases: &[(&str, usize)] = &[
        ("mobilenet-v2", 8),
        ("mobilenet-v2", 16),
        ("mobilenet-v3-small", 8),
        ("mobilenet-v3-small", 32),
        ("mnasnet-b1", 16),
        ("mobilenet-v1", 32),
    ];
    for (i, (name, size)) in cases.iter().enumerate() {
        let ticket = shard.call(Request::new(
            i as u64,
            RequestBody::Simulate {
                model: ModelSpec::Zoo(name.to_string()),
                variant: FuseVariant::Half,
                config: ConfigPatch::sized(*size),
            },
        ));
        let resp = ticket.wait_deadline(T);
        let net = models::by_name(name).unwrap();
        let direct =
            simulate_network(&FuseVariant::Half.apply(&net), &SimConfig::with_size(*size));
        match resp.result {
            Ok(Reply::Sim(s)) => {
                assert_eq!(s.total_cycles, direct.total_cycles, "{name} @ {size}");
                assert_eq!(s.network, direct.network);
            }
            other => panic!("expected sim reply for {name}, got {other:?}"),
        }
    }

    // Satellite regression: stats against the front tier are the *sum*
    // over backends (here: every simulate above), stamped with the
    // backend count — not one node's counters.
    let resp = shard.call(Request::new(100, RequestBody::Stats)).wait_deadline(T);
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.backends, 2, "front tier must report how many nodes it aggregates");
            assert_eq!(s.sim_submitted, cases.len() as u64);
            assert_eq!(s.sim_completed, cases.len() as u64);
            assert!(s.cache_hits + s.cache_misses > 0, "cache counters must aggregate");
        }
        other => panic!("expected stats, got {other:?}"),
    }

    // Fan-out shutdown stops both backends and latches the front tier.
    let resp = shard.call(Request::new(101, RequestBody::Shutdown)).wait_deadline(T);
    assert_eq!(resp.result, Ok(Reply::Done));
    b1.join_stopped();
    b2.join_stopped();
    let resp = shard.call(Request::new(102, RequestBody::Stats)).wait_deadline(T);
    assert_eq!(resp.result, Err(ServeError::Shutdown), "latched after shutdown");
}

/// Like [`TestServer::mock_backend`], with a per-node global result
/// cache — what `fuseconv serve --cache-entries N` mounts.
fn cached_backend() -> TestServer {
    let sim = SimServer::new(2).with_result_cache(Arc::new(ResultCache::new(64)));
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    TestServer::wire(Arc::new(router))
}

#[test]
fn sharded_stats_sum_result_cache_counters() {
    // Hash-pinned routing gives each backend a disjoint slice of the
    // key space, so front-tier `result_*` sums read as fleet totals:
    // 16 unique cells → 16 misses fleet-wide on the cold pass, 16 hits
    // on the identical warm pass, and entry/byte residency that equals
    // the sum over backends.
    let b1 = cached_backend();
    let b2 = cached_backend();
    let fleet = vec![b1.addr().to_string(), b2.addr().to_string()];
    let front = start_shard_front(fleet.clone());

    let names = ["mobilenet-v2", "mobilenet-v3-small"];
    let variants = [FuseVariant::Base, FuseVariant::Half];
    let sizes = [8, 16, 32, 64]; // 16 cells, split across both backends

    let mut sc = front.client(T);
    sc.send(&testkit::sweep_req(1, &names, &variants, &sizes)).expect("send cold sweep");
    let cold = testkit::stream_frames(&mut sc, 1);
    sc.send(&testkit::sweep_req(2, &names, &variants, &sizes)).expect("send warm sweep");
    let warm = testkit::stream_frames(&mut sc, 2);
    // the warm pass is served from the backends' caches, yet stays
    // byte-identical row for row (re-encoded under one id to compare)
    assert_eq!(
        testkit::row_frames(&cold, 0),
        testkit::row_frames(&warm, 0),
        "cached repeat must re-emit identical rows"
    );

    let fa = front.addr();
    let resp = request_once(fa, &Request::new(3, RequestBody::Stats), T).expect("stats");
    let agg = match resp.result {
        Ok(Reply::Stats(s)) => s,
        other => panic!("expected aggregated stats, got {other:?}"),
    };
    assert_eq!(agg.backends, 2);
    assert_eq!(agg.result_misses, 16, "each unique cell simulated once fleet-wide");
    assert_eq!(agg.result_hits, 16, "the warm pass hit on every cell");
    assert_eq!(agg.result_entries, 16, "disjoint per-backend caches sum to the fleet");
    assert!(agg.result_bytes > 0);

    // ...and the aggregate really is the sum over both backends, each
    // holding exactly the cells the rendezvous hash pins to it (each
    // routed (name, size) key caches one entry per variant).
    let (mut hits, mut entries, mut bytes) = (0, 0, 0);
    for (i, backend) in [&b1, &b2].into_iter().enumerate() {
        let expected = (keys_on(&fleet, i, &names, &sizes) * variants.len()) as u64;
        let resp = request_once(backend.addr(), &Request::new(4, RequestBody::Stats), T)
            .expect("backend stats");
        match resp.result {
            Ok(Reply::Stats(s)) => {
                assert_eq!(s.result_entries, expected, "backend {i} cache residency: {s:?}");
                hits += s.result_hits;
                entries += s.result_entries;
                bytes += s.result_bytes;
            }
            other => panic!("expected backend stats, got {other:?}"),
        }
    }
    assert_eq!(
        (hits, entries, bytes),
        (agg.result_hits, agg.result_entries, agg.result_bytes),
        "front-tier counters must be the exact per-backend sums"
    );

    let resp = sc.roundtrip(&Request::new(99, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    front.join_stopped();
    b1.join_stopped();
    b2.join_stopped();
}

#[test]
fn backend_loss_fails_over_to_the_survivor() {
    let live = TestServer::mock_backend();
    let dead = dead_addr();
    let fleet = vec![live.addr().to_string(), dead.clone()];

    // Pick sizes deterministically on each side of the rendezvous split.
    let name = "mobilenet-v2";
    let dead_size = (4..64)
        .find(|&s| route(name, &SimConfig::with_size(s), &fleet) == 1)
        .expect("some size routes to the dead backend");
    let live_size = (4..64)
        .find(|&s| route(name, &SimConfig::with_size(s), &fleet) == 0)
        .expect("some size routes to the live backend");

    // Point query pinned to the dead backend: the front tier marks the
    // member Down and retries once on the survivor — the client gets a
    // correctly priced reply, not an error.
    let shard = ShardRouter::new(fleet.clone(), Duration::from_secs(30));
    let ticket = shard.call(Request::new(
        1,
        RequestBody::Simulate {
            model: ModelSpec::Zoo(name.into()),
            variant: FuseVariant::Base,
            config: ConfigPatch::sized(dead_size),
        },
    ));
    let resp = ticket.wait_deadline(Duration::from_secs(60));
    let net = models::by_name(name).unwrap();
    let direct =
        simulate_network(&FuseVariant::Base.apply(&net), &SimConfig::with_size(dead_size));
    match resp.result {
        Ok(Reply::Sim(s)) => {
            assert_eq!(s.total_cycles, direct.total_cycles, "failover must price identically");
        }
        other => panic!("expected a failed-over sim reply, got {other:?}"),
    }

    // A grid spanning both members, against a fresh front tier that
    // still believes the dead member is up: the missing cells are
    // re-planned onto the survivor mid-stream and the sweep completes.
    // (Row-level byte parity under failover is proven in `shard_chaos`.)
    let shard_b = ShardRouter::new(fleet.clone(), Duration::from_secs(30));
    let req = testkit::sweep_req(2, &[name], &[FuseVariant::Base], &[live_size, dead_size]);
    let resp = shard_b.call(req).wait_deadline(Duration::from_secs(60));
    assert_eq!(resp.result, Ok(Reply::Done), "sweep must survive the lost backend");

    // The loss is visible in stats: the member is Down and the re-steer
    // counter attributes the moved work.
    let resp = shard_b.call(Request::new(3, RequestBody::Stats)).wait_deadline(T);
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert!(s.failover_resteered >= 1, "re-steers must be counted: {s:?}");
            assert!(
                s.backend_state.contains(&format!("{dead}=down")),
                "dead member must read down: {:?}",
                s.backend_state
            );
            assert!(
                s.backend_state.contains(&format!("{}=up", live.addr())),
                "survivor must stay up: {:?}",
                s.backend_state
            );
        }
        other => panic!("expected stats, got {other:?}"),
    }

    // With no survivors at all the error is typed — never a hang.
    let lonely = ShardRouter::new(vec![dead_addr()], Duration::from_secs(5));
    let ticket = lonely.call(Request::new(
        4,
        RequestBody::Simulate {
            model: ModelSpec::Zoo(name.into()),
            variant: FuseVariant::Base,
            config: ConfigPatch::sized(8),
        },
    ));
    let resp = ticket.wait_deadline(Duration::from_secs(60));
    assert_eq!(resp.result, Err(ServeError::Shutdown), "no survivors must be a typed error");

    // Shutdown fan-out tolerates the dead member and still acks.
    let resp = shard.call(Request::new(5, RequestBody::Shutdown)).wait_deadline(T);
    assert_eq!(resp.result, Ok(Reply::Done));
    live.join_stopped();
}

#[test]
fn front_tier_admission_is_bounded() {
    // A backend that accepts connections but never answers: connects
    // land in the listen backlog, replies never come. The first request
    // occupies the only in-flight slot; the second must shed as Busy
    // instead of spawning another relay thread.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind silent backend");
    let addr = listener.local_addr().unwrap().to_string();
    let shard = ShardRouter::new(vec![addr], Duration::from_secs(2)).with_inflight(1);

    let simulate = |id: u64| {
        shard.call(Request::new(
            id,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::sized(8),
            },
        ))
    };
    let first = simulate(1); // holds the only slot, parked on the silent backend
    let second = simulate(2);
    assert_eq!(
        second.wait_deadline(Duration::from_secs(5)).result,
        Err(ServeError::Busy),
        "over-capacity admission must shed, not spawn"
    );
    // The parked request still resolves (typed) once the silent backend
    // times out — and its slot is released for new traffic. The release
    // trails the final frame by a hair (relay-thread exit), so poll.
    let resp = first.wait_deadline(Duration::from_secs(30));
    assert_eq!(resp.result, Err(ServeError::Shutdown));
    let t0 = std::time::Instant::now();
    loop {
        let resp = simulate(3).wait_deadline(Duration::from_secs(30));
        if resp.result == Err(ServeError::Busy) && t0.elapsed() < Duration::from_secs(10) {
            thread::sleep(Duration::from_millis(10));
            continue;
        }
        assert_eq!(resp.result, Err(ServeError::Shutdown), "released slot must admit again");
        break;
    }
    drop(listener);
}

#[test]
fn http_frontend_mounts_the_shard_router_unchanged() {
    let b1 = TestServer::mock_backend();
    let b2 = TestServer::mock_backend();
    let shard = ShardRouter::new(vec![b1.addr().to_string(), b2.addr().to_string()], T);
    let front = TestServer::http(Arc::new(shard));
    let addr = front.addr().to_string();

    // Liveness probes the whole deployment (healthz → Stats fan-out).
    let reply = http_call(&addr, "/healthz", None, None, T).expect("healthz");
    assert_eq!(reply.status, 200);

    // An SSE sweep through the front tier matches the serial reference.
    let body = concat!(
        "{\"id\":9,\"models\":[\"mobilenet-v2\",\"mnasnet-b1\"],",
        "\"variants\":[\"base\",\"fuse-half\"],\"configs\":[{\"size\":8},{\"size\":16}]}"
    );
    let mut rows: Vec<SweepRow> = Vec::new();
    let resp = http_sse(&addr, "/v1/sweep", body, None, T, |_, frame| {
        if let Frame::Row(r) = frame {
            rows.push(r.clone());
        }
    })
    .expect("sse sweep");
    assert!(resp.is_ok(), "sweep must succeed: {resp:?}");
    let plan = SweepPlan::new(
        vec![
            models::by_name("mobilenet-v2").unwrap(),
            models::by_name("mnasnet-b1").unwrap(),
        ],
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::with_size(8), SimConfig::with_size(16)],
    );
    let serial = run_sweep_serial(&plan);
    assert_eq!(rows.len(), serial.records().len());
    for (row, rec) in rows.iter().zip(serial.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.total_cycles, rec.total_cycles());
    }

    // Aggregated stats are visible over HTTP too.
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats");
    match reply.response().expect("stats body").result {
        Ok(Reply::Stats(s)) => assert_eq!(s.backends, 2),
        other => panic!("expected stats, got {other:?}"),
    }

    // Shutdown over HTTP stops the front tier and both backends.
    let reply = http_call(&addr, "/v1/shutdown", Some("{}"), None, T).expect("shutdown");
    assert_eq!(reply.status, 200);
    front.join_stopped();
    b1.join_stopped();
    b2.join_stopped();
}

fn search_req(id: u64, iterations: usize) -> Request {
    Request::new(
        id,
        RequestBody::Search {
            spec: SearchSpec {
                population: 6,
                iterations,
                config: ConfigPatch::sized(8),
                ..SearchSpec::default()
            },
        },
    )
}

/// Every frame of a search stream, re-encoded, for byte-wise stream
/// comparison (rows AND progress AND the terminal).
fn encoded_frames(frames: &[Frame], id: u64) -> Vec<String> {
    frames.iter().map(|f| encode_frame(id, f)).collect()
}

#[test]
fn sharded_search_runs_whole_on_one_backend() {
    let b1 = TestServer::mock_backend();
    let b2 = TestServer::mock_backend();
    let single = TestServer::mock_backend();
    let front = start_shard_front(vec![b1.addr().to_string(), b2.addr().to_string()]);

    // The same seeded job through the front tier and against a lone
    // node: a search is never partitioned, so the relayed stream must
    // be byte-for-byte the single-node stream.
    let mut sc = front.client(T);
    sc.send(&search_req(7, 3)).expect("send sharded search");
    let sharded = testkit::stream_frames(&mut sc, 7);

    let mut nc = single.client(T);
    nc.send(&search_req(7, 3)).expect("send single search");
    let direct = testkit::stream_frames(&mut nc, 7);

    assert_eq!(
        encoded_frames(&sharded, 7),
        encoded_frames(&direct, 7),
        "relayed search stream must be byte-identical to the single node"
    );
    assert!(
        sharded.iter().any(|f| matches!(f, Frame::SearchRow(_))),
        "pareto rows must pass through the relay"
    );
    let reply = match sharded.last() {
        Some(Frame::Final(Ok(Reply::Search(r)))) => r.clone(),
        other => panic!("expected a search terminal, got {other:?}"),
    };
    assert!(!reply.frontier.is_empty(), "converged frontier must be non-empty");
    assert!(!reply.cancelled);
    assert_eq!(reply.generations, 3);

    // Round-robin placement, not fan-out: exactly one backend ran it.
    let mut started = Vec::new();
    for backend in [&b1, &b2] {
        let resp = request_once(backend.addr(), &Request::new(55, RequestBody::Stats), T)
            .expect("backend stats");
        match resp.result {
            Ok(Reply::Stats(s)) => started.push(s.search_started),
            other => panic!("expected stats, got {other:?}"),
        }
    }
    started.sort_unstable();
    assert_eq!(started, vec![0, 1], "one backend must own the whole job");

    // ...and the front tier's aggregate sums the fleet's counters.
    let fa = front.addr();
    let resp = request_once(fa, &Request::new(56, RequestBody::Stats), T).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!((s.search_started, s.search_completed, s.search_cancelled), (1, 1, 0));
        }
        other => panic!("expected stats, got {other:?}"),
    }

    let resp = sc.roundtrip(&Request::new(99, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    front.join_stopped();
    b1.join_stopped();
    b2.join_stopped();
    let resp = nc.roundtrip(&Request::new(98, RequestBody::Shutdown)).expect("single shutdown");
    assert_eq!(resp.result, Ok(Reply::Done));
    single.join_stopped();
}

#[test]
fn cancel_passes_through_the_front_tier() {
    let b1 = TestServer::mock_backend();
    let b2 = TestServer::mock_backend();
    let front = start_shard_front(vec![b1.addr().to_string(), b2.addr().to_string()]);

    // A long search parked on whichever backend round-robin picked; the
    // first frame proves it is registered and streaming.
    let mut sc = front.client(T);
    sc.send(&search_req(21, 1024)).expect("send long search");
    assert!(
        !sc.recv_frame(21).expect("first frame").is_final(),
        "the long search must still be streaming before the cancel"
    );

    // The canceller does not know which backend owns request 21 — the
    // front tier fans the (idempotent) cancel to the whole fleet.
    let mut cc = front.client(T);
    let resp =
        cc.roundtrip(&Request::new(90, RequestBody::Cancel { target: 21 })).expect("cancel ack");
    assert_eq!(resp.result, Ok(Reply::Done), "cancel fan-out must ack");

    // The victim's stream terminates with a cancelled search reply —
    // partial frontier, fewer generations than asked.
    let frames = testkit::stream_frames(&mut sc, 21);
    let reply = match frames.last() {
        Some(Frame::Final(Ok(Reply::Search(r)))) => r.clone(),
        other => panic!("expected a cancelled search terminal, got {other:?}"),
    };
    assert!(reply.cancelled, "the relayed terminal must record the cancellation");
    assert!(reply.generations < 1024, "cancel must stop the job early: {reply:?}");

    // Aggregate stats attribute the job: started once, cancelled once,
    // completed never.
    let fa = front.addr();
    let resp = request_once(fa, &Request::new(91, RequestBody::Stats), T).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!((s.search_started, s.search_completed, s.search_cancelled), (1, 0, 1));
        }
        other => panic!("expected stats, got {other:?}"),
    }

    let resp = sc.roundtrip(&Request::new(99, RequestBody::Shutdown)).expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    front.join_stopped();
    b1.join_stopped();
    b2.join_stopped();
}
