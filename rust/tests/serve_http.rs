//! HTTP/SSE frontend integration (acceptance criteria for the HTTP
//! transport over the v2 Frame protocol):
//!
//! * one-shot `POST /v1/infer` / `POST /v1/simulate` answer `200` with
//!   the reply's terminal frame, and simulate matches a direct
//!   in-process `simulate_network` cycle-for-cycle;
//! * a ≥24-cell `POST /v1/sweep` streams SSE `progress`/`row`/`final`
//!   events whose rows are bit-identical to a local serial `run_sweep`;
//! * a saturated batch lane answers `429` (typed `busy`) while the
//!   interactive lane keeps admitting — same semantics as TCP;
//! * malformed bodies answer `400`, unknown endpoints `404`, wrong
//!   methods `405`, expired deadlines `504`;
//! * concurrent TCP and HTTP clients on ONE `Router` agree on every
//!   cycle count, and a shutdown served over HTTP stops both listeners;
//! * `--max-requests-per-conn` counts kept-alive HTTP requests exactly
//!   like the TCP budget (`429` + close past the cap);
//! * `PROTOCOL.md` documents every `ServeError` code, every `Frame`
//!   tag, and the HTTP status mapping (the spec cannot drift from
//!   `protocol.rs` without failing here).

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::wire::encode_request_body;
use fuseconv::coordinator::{
    http_call, http_sse, ConfigPatch, Frame, HttpServer, MockEngine, ModelSpec, Reply,
    Request, RequestBody, Router, SearchPoint, ServeError, Server, SimServer, StopLatch,
    SweepRow, WireClient, WireServer,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, simulate_network, FuseVariant, LayerCache, ResultCache, SimConfig,
    SweepPlan,
};
use fuseconv::testkit::TestServer;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

const T: Duration = Duration::from_secs(300);

/// Local serial reference sweep for (zoo names × variants × sizes).
fn serial_reference(
    names: &[&str],
    variants: &[FuseVariant],
    sizes: &[usize],
) -> fuseconv::sim::SweepOutcome {
    let plan = SweepPlan::new(
        names.iter().map(|m| models::by_name(m).unwrap()).collect(),
        variants.to_vec(),
        sizes.iter().map(|&s| SimConfig::with_size(s)).collect(),
    );
    run_sweep_serial(&plan)
}

fn assert_rows_match(rows: &[SweepRow], reference: &fuseconv::sim::SweepOutcome) {
    assert_eq!(rows.len(), reference.records().len(), "row count");
    for (row, rec) in rows.iter().zip(reference.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
        assert_eq!(row.total_cycles, rec.total_cycles(), "{} {}", row.network, row.rows);
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }
}

fn mock_router(interactive: usize, batch: usize) -> Arc<Router> {
    let sim = SimServer::with_lanes(2, Arc::new(LayerCache::new()), interactive, batch);
    Arc::new(Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )))
}

fn sweep_body(models: &[&str], variants: &[FuseVariant], sizes: &[usize]) -> String {
    encode_request_body(&Request::new(
        1,
        RequestBody::Sweep {
            models: models.iter().map(|m| m.to_string()).collect(),
            variants: variants.to_vec(),
            configs: sizes.iter().map(|&s| ConfigPatch::sized(s)).collect(),
        },
    ))
}

#[test]
fn http_oneshot_infer_simulate_and_ops() {
    let server = TestServer::http(mock_router(64, 32));
    let addr = server.addr().to_string();

    // healthz: liveness + protocol version
    let reply = http_call(&addr, "/healthz", None, None, T).expect("healthz");
    assert_eq!(reply.status, 200, "{}", reply.body);
    assert!(reply.body.contains("\"protocol_version\":2"), "{}", reply.body);

    // infer through the mock engine: output[0] = sum(input)
    let reply = http_call(
        &addr,
        "/v1/infer",
        Some("{\"id\":7,\"input\":[1,2,3,4]}"),
        None,
        T,
    )
    .expect("infer");
    assert_eq!(reply.status, 200, "{}", reply.body);
    let resp = reply.response().expect("terminal frame body");
    assert_eq!(resp.id, 7, "the body id must be echoed");
    match resp.result {
        Ok(Reply::Infer(r)) => assert_eq!(r.output, vec![10.0, 11.0]),
        other => panic!("expected infer reply, got {other:?}"),
    }

    // simulate: identical cycles to a direct in-process simulation
    let req = Request::new(
        8,
        RequestBody::Simulate {
            model: ModelSpec::Zoo("mobilenet-v2".into()),
            variant: FuseVariant::Half,
            config: ConfigPatch::sized(16),
        },
    );
    let reply = http_call(&addr, "/v1/simulate", Some(&encode_request_body(&req)), None, T)
        .expect("simulate");
    assert_eq!(reply.status, 200, "{}", reply.body);
    let got = match reply.response().unwrap().result {
        Ok(Reply::Sim(s)) => s,
        other => panic!("expected sim reply, got {other:?}"),
    };
    let net = models::by_name("mobilenet-v2").unwrap();
    let expect = simulate_network(&FuseVariant::Half.apply(&net), &SimConfig::with_size(16));
    assert_eq!(got.total_cycles, expect.total_cycles);

    // stats and zoo over GET
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats");
    match reply.response().unwrap().result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.infer_served, 1);
            assert_eq!(s.sim_completed, 1);
        }
        other => panic!("expected stats, got {other:?}"),
    }
    let reply = http_call(&addr, "/v1/zoo", None, None, T).expect("zoo");
    match reply.response().unwrap().result {
        Ok(Reply::Zoo(entries)) => assert_eq!(entries.len(), models::ZOO_NAMES.len()),
        other => panic!("expected zoo, got {other:?}"),
    }

    server.shutdown();
}

#[test]
fn http_sweep_streams_sse_bit_identical_to_serial() {
    // Acceptance: a ≥24-cell SSE sweep must stream incremental events
    // before its final, and row-by-row cycle counts must be
    // bit-identical to the local serial sweep of the same grid.
    let server = TestServer::http(mock_router(64, 32));
    let addr = server.addr().to_string();
    const SIZES: [usize; 8] = [4, 8, 12, 16, 20, 24, 28, 32];
    let variants = [FuseVariant::Base, FuseVariant::Half, FuseVariant::Full];

    let mut tags: Vec<&'static str> = Vec::new();
    let mut rows: Vec<SweepRow> = Vec::new();
    let resp = http_sse(
        &addr,
        "/v1/sweep",
        &sweep_body(&["mobilenet-v3-small"], &variants, &SIZES),
        None,
        T,
        |id, frame| {
            assert_eq!(id, 1, "every event carries the request id");
            tags.push(frame.tag());
            if let Frame::Row(row) = frame {
                rows.push(row.clone());
            }
        },
    )
    .expect("sse sweep");

    // grammar: progress* / row* then exactly one final, final last
    assert_eq!(tags.last(), Some(&"final"));
    assert_eq!(tags.iter().filter(|t| **t == "final").count(), 1);
    let progress_before_final = tags
        .iter()
        .take_while(|t| **t != "final")
        .filter(|t| **t == "progress")
        .count();
    assert!(
        progress_before_final >= 2,
        "want ≥2 progress events before final, got {progress_before_final}"
    );
    assert_eq!(rows.len(), 24, "1 model × 3 variants × 8 sizes");
    let reference = serial_reference(&["mobilenet-v3-small"], &variants, &SIZES);
    assert_rows_match(&rows, &reference);
    // the collapsed response merges the same rows
    match resp.result {
        Ok(Reply::Sweep(merged)) => assert_eq!(merged, rows),
        other => panic!("expected merged sweep, got {other:?}"),
    }

    server.shutdown();
}

#[test]
fn http_error_statuses_cover_the_taxonomy() {
    let server = TestServer::http(mock_router(64, 32));
    let addr = server.addr().to_string();

    // malformed JSON body: 400 + typed bad_request frame
    let reply = http_call(&addr, "/v1/simulate", Some("{not json"), None, T).expect("call");
    assert_eq!(reply.status, 400, "{}", reply.body);
    assert!(
        matches!(reply.response().unwrap().result, Err(ServeError::BadRequest(_))),
        "{}",
        reply.body
    );

    // well-formed JSON, bad protocol content: still 400
    let reply = http_call(
        &addr,
        "/v1/simulate",
        Some("{\"model\":{\"zoo\":\"nonesuch\"}}"),
        None,
        T,
    )
    .expect("call");
    assert_eq!(reply.status, 400, "{}", reply.body);

    // unknown endpoint: 404; wrong method on a known one: 405
    let reply = http_call(&addr, "/v1/frobnicate", None, None, T).expect("call");
    assert_eq!(reply.status, 404, "{}", reply.body);
    let reply = http_call(&addr, "/v1/sweep", None, None, T).expect("call");
    assert_eq!(reply.status, 405, "{}", reply.body);

    // expired deadline: 504 + typed deadline error
    let req = Request::new(
        9,
        RequestBody::Simulate {
            model: ModelSpec::Zoo("mobilenet-v2".into()),
            variant: FuseVariant::Base,
            config: ConfigPatch::default(),
        },
    )
    .with_deadline_ms(0);
    let reply = http_call(&addr, "/v1/simulate", Some(&encode_request_body(&req)), None, T)
        .expect("call");
    assert_eq!(reply.status, 504, "{}", reply.body);
    assert_eq!(reply.response().unwrap().result, Err(ServeError::Deadline));

    server.shutdown();
}

#[test]
fn http_429_on_saturated_batch_lane_still_admits_interactive() {
    // Batch lane bound 1: while one streamed sweep holds the slot, a
    // second sweep answers 429 (typed busy) — but interactive simulate
    // keeps being admitted, exactly like the TCP frontend.
    let server = TestServer::http(mock_router(64, 1));
    let addr = server.addr().to_string();

    let (started_tx, started_rx) = mpsc::channel();
    let addr2 = addr.clone();
    let big = thread::spawn(move || {
        let mut signalled = false;
        http_sse(
            &addr2,
            "/v1/sweep",
            &sweep_body(
                &["mobilenet-v2"],
                &[FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
                &[16, 32, 48, 64],
            ),
            None,
            T,
            |_, _| {
                if !signalled {
                    signalled = true;
                    let _ = started_tx.send(());
                }
            },
        )
    });
    started_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("big sweep must start streaming");

    // the batch lane slot is held: a second sweep bounces as busy
    let resp = http_sse(
        &addr,
        "/v1/sweep",
        &sweep_body(&["mobilenet-v3-small"], &[FuseVariant::Base], &[8]),
        None,
        T,
        |_, _| {},
    )
    .expect("bounced sweep decodes");
    assert_eq!(resp.result, Err(ServeError::Busy), "batch lane bound 1 must bounce");

    // ...while the interactive lane still admits and answers
    let req = Request::new(
        3,
        RequestBody::Simulate {
            model: ModelSpec::Zoo("mobilenet-v3-small".into()),
            variant: FuseVariant::Base,
            config: ConfigPatch::sized(8),
        },
    );
    let reply = http_call(&addr, "/v1/simulate", Some(&encode_request_body(&req)), None, T)
        .expect("interactive");
    assert_eq!(reply.status, 200, "interactive query starved: {}", reply.body);

    // the admitted sweep still runs to completion
    let resp = big.join().expect("big sweep thread").expect("big sweep");
    match resp.result {
        Ok(Reply::Sweep(rows)) => assert_eq!(rows.len(), 12),
        other => panic!("expected sweep rows, got {other:?}"),
    }

    server.shutdown();
}

#[test]
fn concurrent_tcp_and_http_clients_agree_on_one_router() {
    // One Router, both transports, one stop latch: identical grids
    // swept concurrently over TCP frames and HTTP SSE must agree
    // cell-for-cell, and a shutdown over HTTP stops both listeners.
    let router = mock_router(64, 32);
    let stop = StopLatch::new();
    let wire = WireServer::bind("127.0.0.1:0", router.clone())
        .expect("bind tcp")
        .with_stop(stop.clone());
    let http = HttpServer::bind("127.0.0.1:0", router).expect("bind http").with_stop(stop);
    let tcp_front = TestServer::from_wire(wire);
    let http_front = TestServer::from_http(http);
    let tcp_addr = tcp_front.addr().to_string();
    let http_addr = http_front.addr().to_string();

    const SIZES: [usize; 4] = [8, 16, 24, 32];
    let variants = [FuseVariant::Base, FuseVariant::Half];

    let tcp_addr2 = tcp_addr.clone();
    let tcp_worker = thread::spawn(move || {
        let mut client = WireClient::connect(&tcp_addr2, T).expect("connect tcp");
        client
            .send(&Request::new(
                11,
                RequestBody::Sweep {
                    models: vec!["mobilenet-v2".into()],
                    variants: variants.to_vec(),
                    configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
                },
            ))
            .expect("send sweep");
        let mut rows = Vec::new();
        loop {
            match client.recv_frame(11).expect("tcp frame") {
                Frame::Progress { .. } => {}
                Frame::Row(row) => rows.push(row),
                Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
                Frame::Final(result) => {
                    assert_eq!(result, Ok(Reply::Done));
                    break;
                }
            }
        }
        rows
    });
    let http_addr2 = http_addr.clone();
    let http_worker = thread::spawn(move || {
        let mut rows = Vec::new();
        let resp = http_sse(
            &http_addr2,
            "/v1/sweep",
            &sweep_body(&["mobilenet-v2"], &variants, &SIZES),
            None,
            T,
            |_, frame| {
                if let Frame::Row(row) = frame {
                    rows.push(row.clone());
                }
            },
        )
        .expect("http sweep");
        assert!(resp.is_ok(), "{resp:?}");
        rows
    });

    let tcp_rows = tcp_worker.join().expect("tcp worker");
    let http_rows = http_worker.join().expect("http worker");
    assert_eq!(tcp_rows, http_rows, "transports must agree cell-for-cell");
    assert_rows_match(&tcp_rows, &serial_reference(&["mobilenet-v2"], &variants, &SIZES));

    // one more point of agreement: the same simulate on both transports
    let sim_req = Request::new(
        21,
        RequestBody::Simulate {
            model: ModelSpec::Zoo("mnasnet-b1".into()),
            variant: FuseVariant::Half,
            config: ConfigPatch::sized(16),
        },
    );
    let mut tcp_client = WireClient::connect(&tcp_addr, T).expect("connect tcp");
    let tcp_sim = match tcp_client.roundtrip(&sim_req).expect("tcp simulate").result {
        Ok(Reply::Sim(s)) => s,
        other => panic!("tcp: expected sim, got {other:?}"),
    };
    let reply = http_call(&http_addr, "/v1/simulate", Some(&encode_request_body(&sim_req)), None, T)
        .expect("http simulate");
    match reply.response().unwrap().result {
        Ok(Reply::Sim(s)) => assert_eq!(s.total_cycles, tcp_sim.total_cycles),
        other => panic!("http: expected sim, got {other:?}"),
    }
    drop(tcp_client);

    // shutdown over HTTP trips the shared latch: both listeners exit,
    // so the TCP guard joins without ever sending its own shutdown
    http_front.shutdown();
    tcp_front.join_stopped();
}

/// Read one HTTP response (status + content-length framed body) off a
/// raw kept-alive connection; `None` once the server closed it.
fn read_http_response(reader: &mut BufReader<TcpStream>) -> Option<(u16, String)> {
    let mut line = String::new();
    if reader.read_line(&mut line).ok()? == 0 {
        return None;
    }
    let status: u16 = line.split_whitespace().nth(1)?.parse().ok()?;
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).ok()?;
        let t = h.trim();
        if t.is_empty() {
            break;
        }
        if let Some((name, value)) = t.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                len = value.trim().parse().ok()?;
            }
        }
    }
    let mut buf = vec![0u8; len];
    reader.read_exact(&mut buf).ok()?;
    Some((status, String::from_utf8(buf).ok()?))
}

#[test]
fn keep_alive_budget_answers_429_and_closes() {
    // --max-requests-per-conn over HTTP: three pipelined requests on one
    // kept-alive connection against a budget of 2 → 200, 200, 429 +
    // close. A fresh connection gets a fresh budget.
    let router = mock_router(64, 32);
    let http = HttpServer::bind("127.0.0.1:0", router)
        .expect("bind http")
        .with_request_budget(Some(2));
    let server = TestServer::from_http(http);
    let addr = server.addr().to_string();

    let mut conn = TcpStream::connect(&addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let one = format!("GET /v1/stats HTTP/1.1\r\nhost: {addr}\r\n\r\n");
    conn.write_all(one.repeat(3).as_bytes()).expect("pipeline 3 requests");
    let mut reader = BufReader::new(conn);
    let mut statuses = Vec::new();
    while let Some((status, _body)) = read_http_response(&mut reader) {
        statuses.push(status);
        if statuses.len() > 3 {
            break;
        }
    }
    assert_eq!(statuses, vec![200, 200, 429], "budget must bounce the third request");
    // the connection is closed after the bounce (read_http_response → None)

    // fresh connection, fresh budget
    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("fresh stats");
    assert_eq!(reply.status, 200);

    server.shutdown();
}

#[test]
fn http_stats_render_result_cache_counters() {
    // `request --op stats`-equivalent over HTTP: a cache-enabled server
    // renders the additive result_* fields, with values matching a
    // cold-then-warm pair of identical sweeps.
    let results = Arc::new(ResultCache::new(64));
    let sim = SimServer::with_lanes(2, Arc::new(LayerCache::new()), 64, 32)
        .with_result_cache(Arc::clone(&results));
    let router = Arc::new(Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )));
    let server = TestServer::http(router);
    let addr = server.addr().to_string();

    let body =
        sweep_body(&["mobilenet-v3-small"], &[FuseVariant::Base, FuseVariant::Half], &[8, 16]);
    for _ in 0..2 {
        let resp = http_sse(&addr, "/v1/sweep", &body, None, T, |_, _| {}).expect("sweep");
        assert!(resp.is_ok(), "{resp:?}");
    }

    let reply = http_call(&addr, "/v1/stats", None, None, T).expect("stats");
    assert_eq!(reply.status, 200, "{}", reply.body);
    // raw rendering: every additive field is spelled out in the JSON
    for field in [
        "result_hits",
        "result_misses",
        "result_coalesced",
        "result_evicted",
        "result_entries",
        "result_bytes",
    ] {
        assert!(reply.body.contains(field), "stats body must render {field}: {}", reply.body);
    }
    match reply.response().unwrap().result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.result_misses, 4, "cold pass simulates the 4-cell grid");
            assert_eq!(s.result_hits, 4, "warm pass is served from cache");
            assert_eq!(s.result_entries, 4);
            assert!(s.result_bytes > 0);
        }
        other => panic!("expected stats, got {other:?}"),
    }

    server.shutdown();
}

#[test]
fn protocol_md_documents_the_wire_contract() {
    // Acceptance: the spec must name every ServeError code, every Frame
    // tag, and the HTTP status each error maps to. Enumerated from the
    // protocol types themselves so the spec cannot silently drift.
    let spec = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../PROTOCOL.md"))
        .expect("PROTOCOL.md at the repository root");
    let errors = [
        ServeError::Busy,
        ServeError::BadRequest(String::new()),
        ServeError::Deadline,
        ServeError::Shutdown,
        ServeError::Unauthorized,
    ];
    for e in &errors {
        let code = format!("`{}`", e.code());
        assert!(spec.contains(&code), "PROTOCOL.md must document the {code} error code");
        let (status, _) = fuseconv::coordinator::http::status_of(&Err(e.clone()));
        assert!(
            spec.contains(&status.to_string()),
            "PROTOCOL.md must document the HTTP {status} mapping of `{}`",
            e.code()
        );
    }
    let frames = [
        Frame::Progress { done: 0, total: 0 },
        Frame::Row(SweepRow {
            network: String::new(),
            variant: FuseVariant::Base,
            rows: 0,
            cols: 0,
            dataflow: fuseconv::sim::Dataflow::OutputStationary,
            stos: true,
            total_cycles: 0,
            latency_ms: 0.0,
        }),
        Frame::SearchRow(SearchPoint {
            genome: String::new(),
            acc: 0.0,
            latency_ms: 0.0,
            macs_m: 0.0,
            params_m: 0.0,
            rank: 0,
        }),
        Frame::Final(Ok(Reply::Done)),
    ];
    for f in &frames {
        let tag = format!("`{}`", f.tag());
        assert!(spec.contains(&tag), "PROTOCOL.md must document the {tag} frame");
    }
    // the ordering guarantees, both renderings, and the sharded
    // front-tier semantics must be spelled out
    for needle in [
        "plan order",
        "exactly one",
        "text/event-stream",
        "timeout-ms",
        "Sharded deployment",
        "consolidated",
        "`backends`",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the transport concurrency appendix: both models, wire-invisible,
    // backpressure via write-readiness, and every live gauge the stats
    // reply carries (enumerated from the field names so the spec tracks
    // `StatsReply`)
    for needle in [
        "Transport concurrency model",
        "--transport threaded|epoll",
        "thread-per-connection",
        "readiness loop",
        "write-readiness",
        "not observable on the wire",
        "`open_conns`",
        "`active_streams`",
        "`transport_threads`",
        "fuseconv bench",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the global result cache section: keying, single-flight
    // coalescing, shard locality, and every result_* stats field
    for needle in [
        "Global result cache",
        "--cache-entries",
        "single-flight",
        "coalesc",
        "price_key",
        "`result_hits`",
        "`result_misses`",
        "`result_coalesced`",
        "`result_evicted`",
        "`result_entries`",
        "`result_bytes`",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the search op & cancellation section: the stream grammar, the
    // cancel semantics (cross-connection, idempotent, one-generation
    // latency), the admission lane, and every search_* stats field
    for needle in [
        "Search op & cancellation",
        "`search`",
        "`cancel`",
        "within one generation",
        "idempotent",
        "--search-capacity",
        "`search_started`",
        "`search_completed`",
        "`search_cancelled`",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the authentication section: both carriers of the credential, the
    // constant-time check, the open probe, and the shard-tier caveat
    for needle in [
        "Authentication",
        "--auth-token",
        "Authorization: Bearer",
        "constant-time",
        "`/healthz`",
        "unauthenticated",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the health, failover & membership section: probe states, the
    // failover semantics, both admin ops, the rendezvous key movement,
    // and every fleet-level stats field
    for needle in [
        "Health, failover & membership",
        "--probe-interval-ms",
        "--probe-failures",
        "`up`",
        "`suspect`",
        "`down`",
        "`draining`",
        "`add-backend`",
        "`drain-backend`",
        "rendezvous",
        "re-plan",
        "`backend_state`",
        "`failover_resteered`",
        "`probe_failures`",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the operator & dataflow vocabulary section: every inline op kind
    // tag (enumerated from the codec's own vocabulary), the three
    // dataflow tokens, and the additive-field defaults for the
    // dilated/grouped fields
    for needle in [
        "Operator & dataflow vocabulary",
        "`\"conv2d\"`",
        "`\"depthwise\"`",
        "`\"pointwise\"`",
        "`\"fuse_row\"`",
        "`\"fuse_col\"`",
        "`\"fc\"`",
        "`\"global_pool\"`",
        "`\"squeeze_excite\"`",
        "`\"add\"`",
        "`\"dilated\"`",
        "`\"transposed\"`",
        "`\"grouped\"`",
        "`dilation`",
        "`groups`",
        "input-stationary",
        "MUST divide",
    ] {
        assert!(spec.contains(needle), "PROTOCOL.md must cover {needle:?}");
    }
    // the dataflow vocabulary itself, as the parse/short pair renders it
    for df in fuseconv::sim::config::ALL_DATAFLOWS {
        let tok = format!("`{}`", df.short());
        assert!(spec.contains(&tok), "PROTOCOL.md must document the {tok} dataflow");
    }
}
