//! Sweep-engine integration tests: the parallel zoo×config sweep must be
//! bit-identical to the serial `simulate_network` path for every worker
//! count, and the shared layer cache must actually fire across networks.

use fuseconv::exec::Pool;
use fuseconv::nn::models;
use fuseconv::sim::{
    grid_configs, run_sweep, run_sweep_serial, run_sweep_with, Dataflow, FuseVariant,
    LayerCache, SimConfig, SweepEvent, SweepPlan,
};
use std::sync::Arc;

/// The acceptance-criteria sweep: the paper's five networks × {Base, Half,
/// Full} × a 4-config grid (two sizes × two dataflows).
fn acceptance_plan() -> SweepPlan {
    SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
        grid_configs(
            &[8, 16],
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
            &[true],
        ),
    )
}

#[test]
fn parallel_sweep_is_bit_identical_to_serial_for_any_worker_count() {
    let plan = acceptance_plan();
    assert_eq!(plan.len(), 5 * 3 * 4);
    let serial = run_sweep_serial(&plan);

    for workers in [1usize, 2, 7] {
        let pool = Pool::new(workers);
        let cache = Arc::new(LayerCache::new());
        let par = run_sweep(&plan, &pool, &cache);
        assert_eq!(par.records().len(), serial.records().len());
        for (s, p) in serial.records().iter().zip(par.records()) {
            assert_eq!(s.network, p.network);
            assert_eq!(s.variant, p.variant);
            assert_eq!(s.cfg.label(), p.cfg.label());
            assert_eq!(
                s.total_cycles(),
                p.total_cycles(),
                "{} {} {} differs with {workers} workers",
                s.network,
                s.variant.label(),
                s.cfg.label()
            );
            // latency is derived purely from cycles — must match exactly too
            assert_eq!(s.latency_ms().to_bits(), p.latency_ms().to_bits());
            // and so must the per-layer breakdown
            for (a, b) in s.sim.layers.iter().zip(&p.sim.layers) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.total_cycles, b.total_cycles);
                assert_eq!(a.stall_cycles, b.stall_cycles);
                assert_eq!(a.pe_cycles, b.pe_cycles);
            }
        }
    }
}

#[test]
fn streamed_sweep_rows_are_bit_identical_to_serial_for_any_worker_count() {
    // The serving layer's streamed Sweep path rides run_sweep_with; its
    // plan-order row emission must match the serial sweep exactly, for
    // any pool size, with progress covering every cell.
    let plan = acceptance_plan();
    let serial = run_sweep_serial(&plan);
    for workers in [1usize, 3, 8] {
        let pool = Pool::new(workers);
        let cache = Arc::new(LayerCache::new());
        let mut streamed: Vec<(usize, String, u64)> = Vec::new();
        let mut completions = 0usize;
        let out = run_sweep_with(&plan, &pool, &cache, |e| match e {
            SweepEvent::Progress { done, total } => {
                assert_eq!(total, plan.len());
                assert!(done >= 1 && done <= total);
                completions += 1;
            }
            SweepEvent::Row { index, record } => {
                streamed.push((index, record.network.clone(), record.total_cycles()));
            }
        });
        assert_eq!(completions, plan.len(), "{workers} workers");
        assert_eq!(streamed.len(), plan.len());
        for (pos, ((index, network, cycles), s)) in
            streamed.iter().zip(serial.records()).enumerate()
        {
            assert_eq!(*index, pos, "rows must stream in plan order");
            assert_eq!(network, &s.network);
            assert_eq!(*cycles, s.total_cycles(), "{workers} workers");
        }
        // the returned outcome is the same records the stream delivered
        for (r, s) in out.records().iter().zip(serial.records()) {
            assert_eq!(r.total_cycles(), s.total_cycles());
        }
    }
}

#[test]
fn shared_cache_reports_cross_network_hits() {
    // The five-network zoo shares bottleneck geometries and the FuSe
    // transform keeps pointwise/stem/head layers, so a zoo sweep must see
    // substantial reuse through ONE shared cache.
    let plan = acceptance_plan();
    let pool = Pool::new(4);
    let cache = Arc::new(LayerCache::new());
    let out = run_sweep(&plan, &pool, &cache);
    let cs = out.cache_stats;
    let total_layer_sims: u64 = out
        .records()
        .iter()
        .map(|r| r.sim.layers.len() as u64)
        .sum();
    assert_eq!(cs.hits + cs.misses, total_layer_sims);
    assert!(cs.hits > 0, "no cache hits across the zoo: {cs:?}");
    // the zoo is redundant enough that reuse should dominate
    assert!(
        cs.hit_rate() > 0.3,
        "hit rate suspiciously low: {:.3} ({cs:?})",
        cs.hit_rate()
    );
    // schedule cache can only be hit at least as often as priced layers
    // were rebuilt from shared lowerings
    assert_eq!(cs.sched_hits + cs.sched_misses, cs.misses);
}

#[test]
fn sweep_records_match_plan_indexing() {
    let plan = SweepPlan::new(
        vec![
            models::by_name("mobilenet-v1").unwrap(),
            models::by_name("mobilenet-v2").unwrap(),
        ],
        vec![FuseVariant::Base, FuseVariant::Half],
        grid_configs(&[8, 32], &[Dataflow::OutputStationary], &[true, false]),
    );
    let pool = Pool::new(2);
    let cache = Arc::new(LayerCache::new());
    let out = run_sweep(&plan, &pool, &cache);
    for (n, net) in plan.networks.iter().enumerate() {
        for (v, variant) in plan.variants.iter().enumerate() {
            for (c, cfg) in plan.configs.iter().enumerate() {
                let r = out.record(n, v, c);
                assert_eq!(r.network, net.name);
                assert_eq!(r.variant, *variant);
                assert_eq!((r.cfg.rows, r.cfg.stos), (cfg.rows, cfg.stos));
                assert!(r.total_cycles() > 0);
            }
        }
    }
}

#[test]
fn stos_and_dataflow_grid_shapes_the_expected_ordering() {
    // Sanity over the grid semantics: on the same array, FuSe-Half with
    // ST-OS beats the depthwise baseline; without ST-OS it loses the edge.
    let plan = SweepPlan::new(
        vec![models::by_name("mobilenet-v2").unwrap()],
        vec![FuseVariant::Base, FuseVariant::Half],
        grid_configs(&[16], &[Dataflow::OutputStationary], &[true, false]),
    );
    let pool = Pool::new(2);
    let cache = Arc::new(LayerCache::new());
    let out = run_sweep(&plan, &pool, &cache);
    let base_stos = out.record(0, 0, 0).total_cycles();
    let half_stos = out.record(0, 1, 0).total_cycles();
    let half_nostos = out.record(0, 1, 1).total_cycles();
    assert!(half_stos * 3 < base_stos, "FuSe+ST-OS not >3x faster");
    assert!(half_nostos > 3 * half_stos, "ST-OS ablation lost its cost");
}
