//! Decoder robustness: no input — random garbage, truncated or mutated
//! valid frames, hostile JSON — may ever panic the wire codec. Every
//! decode returns `Ok` or a typed `WireError`; a panic here would tear
//! down a server connection thread on attacker-controlled bytes.
//!
//! Runs 10k seeded cases per surface through the in-tree property
//! harness (`fuseconv::testkit::forall`), so every failure replays from
//! its printed seed.

use fuseconv::coordinator::wire::{
    decode_frame, decode_request, decode_request_body, decode_response, encode_frame,
    encode_request, parse_json,
};
use fuseconv::coordinator::{Frame, Reply, Request, RequestBody, ServeError};
use fuseconv::rng::Rng;
use fuseconv::testkit::{forall, no_shrink, Check};

const CASES: usize = 10_000;

/// Random bytes, lossily stringified — exercises the full parser
/// surface including invalid UTF-8 replacement chars and embedded
/// NULs/newlines.
fn garbage(r: &mut Rng, max_len: usize) -> String {
    let len = r.below(max_len + 1);
    let bytes: Vec<u8> = (0..len).map(|_| r.below(256) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// JSON-flavored garbage: random splices of structural tokens, so cases
/// get past the first byte and into the recursive parser.
fn jsonish(r: &mut Rng) -> String {
    const TOKENS: [&str; 18] = [
        "{",
        "}",
        "[",
        "]",
        ":",
        ",",
        "\"",
        "\\",
        "op",
        "\"op\"",
        "\"id\"",
        "1e999",
        "-0.5",
        "null",
        "true",
        "1234567890123456789012345",
        "\"\\u00\"",
        " ",
    ];
    let n = r.below(40);
    let mut out = String::new();
    for _ in 0..n {
        out.push_str(TOKENS[r.below(TOKENS.len())]);
    }
    out
}

/// A valid encoded frame to mutate/truncate.
fn valid_frame(r: &mut Rng) -> String {
    let id = r.next_u64() % 1000;
    let (done, total) = (r.next_u64() % 100, r.next_u64() % 100);
    let frame = match r.below(4) {
        0 => Frame::Progress { done, total },
        1 => Frame::Final(Ok(Reply::Done)),
        2 => Frame::Final(Err(ServeError::BadRequest("x".into()))),
        _ => Frame::Final(Err(ServeError::Busy)),
    };
    encode_frame(id, &frame)
}

/// Corrupt `text`: truncate at a random byte boundary, flip bytes, or
/// splice in garbage — the shapes a cut TCP stream actually produces.
fn mutate(r: &mut Rng, text: &str) -> String {
    let mut bytes = text.as_bytes().to_vec();
    match r.below(3) {
        0 => {
            // truncate (a mid-frame connection cut)
            bytes.truncate(r.below(bytes.len() + 1));
        }
        1 => {
            // flip a few bytes in place
            for _ in 0..r.range(1, 8) {
                if bytes.is_empty() {
                    break;
                }
                let i = r.below(bytes.len());
                bytes[i] = r.below(256) as u8;
            }
        }
        _ => {
            // splice garbage into the middle
            let i = r.below(bytes.len() + 1);
            let extra: Vec<u8> = (0..r.below(16)).map(|_| r.below(256) as u8).collect();
            bytes.splice(i..i, extra);
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

const OPS: [&str; 10] = [
    "infer",
    "simulate",
    "sweep",
    "search",
    "stats",
    "zoo",
    "cancel",
    "add-backend",
    "drain-backend",
    "shutdown",
];

#[test]
fn decoders_never_panic_on_garbage() {
    forall(
        0xFACE_FEED,
        CASES,
        |r| {
            if r.chance(0.5) {
                garbage(r, 200)
            } else {
                jsonish(r)
            }
        },
        no_shrink,
        |input| {
            // Every decode surface must return, never unwind.
            let _ = parse_json(input);
            let _ = decode_frame(input);
            let _ = decode_request(input);
            let _ = decode_response(input);
            Check::Pass
        },
    );
}

#[test]
fn decoders_never_panic_on_mutated_valid_frames() {
    forall(
        0xBADC_0FFE,
        CASES,
        |r| {
            let text = valid_frame(r);
            mutate(r, &text)
        },
        no_shrink,
        |input| {
            let _ = decode_frame(input);
            let _ = decode_response(input);
            Check::Pass
        },
    );
}

#[test]
fn request_body_decoder_never_panics_on_hostile_json() {
    forall(
        0xDEAD_BEEF,
        CASES,
        |r| {
            let op = OPS[r.below(OPS.len())].to_string();
            let body = if r.chance(0.5) {
                garbage(r, 120)
            } else {
                jsonish(r)
            };
            (op, body)
        },
        no_shrink,
        |(op, body)| {
            // Only well-formed JSON reaches decode_request_body in the
            // real pipeline, but it must be panic-free on ANY Json value.
            if let Ok(v) = parse_json(body) {
                let _ = decode_request_body(op, &v);
            }
            Check::Pass
        },
    );
}

#[test]
fn round_trip_survives_for_every_op_envelope() {
    // The structured complement to the garbage cases: for every op, a
    // canonical request round-trips; mutating its encoding never panics.
    let mut r = Rng::new(7);
    let bodies = [
        RequestBody::Stats,
        RequestBody::Zoo,
        RequestBody::Shutdown,
        RequestBody::Cancel { target: 9 },
        RequestBody::AddBackend { addr: "10.0.0.9:4242".into() },
        RequestBody::DrainBackend { addr: "10.0.0.9:4242".into() },
        RequestBody::Infer { input: vec![0.5, -1.0] },
    ];
    for body in bodies {
        let req = Request::new(3, body);
        let text = encode_request(&req);
        let back = decode_request(&text).expect("canonical round-trip");
        assert_eq!(back, req);
        for _ in 0..200 {
            let _ = decode_request(&mutate(&mut r, &text));
        }
    }
}
