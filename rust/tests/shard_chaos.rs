//! Self-healing fleet acceptance, driven by the deterministic
//! fault-injection harness ([`fuseconv::testkit::ChaosProxy`]): every
//! fault here fires at an exact, reproducible point (a frame boundary,
//! an accept, a probe), not via `kill -9` races.
//!
//! * a backend killed mid-sweep has its remaining sub-grid re-planned
//!   onto the survivor — the client still receives every row,
//!   byte-identical to a single node, under one consolidated progress
//!   counter, and `failover_resteered` records the move;
//! * draining a backend mid-sweep loses zero rows, then removes the
//!   member once its in-flight work finishes;
//! * `add-backend` at runtime routes a fresh sweep's rendezvous share
//!   onto the new node;
//! * a black-holed backend cannot hold a deadlined client past its
//!   deadline, and the health probes harden it `Suspect`→`Down` within
//!   the probe budget, after which traffic routes around it;
//! * membership changes only invalidate the moved shard's cache keys —
//!   the surviving backends' result caches stay warm;
//! * a `Search` pinned to a dead backend fails typed, never hangs.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::shard::{route, ShardRouter};
use fuseconv::coordinator::{
    request_once, ConfigPatch, Frame, MockEngine, Reply, Request, RequestBody, Router,
    SearchSpec, ServeError, Server, SimServer,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, FuseVariant, ResultCache, SimConfig, SweepPlan, SweepRow,
};
use fuseconv::testkit::{
    progress_frames, row_frames, stream_frames, sweep_req, wait_until, ChaosMode, ChaosProxy,
    TestServer,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(120);

const NAMES: [&str; 2] = ["mobilenet-v2", "mobilenet-v3-small"];
const VARIANTS: [FuseVariant; 2] = [FuseVariant::Base, FuseVariant::Half];
const SIZES: [usize; 6] = [8, 12, 16, 24, 32, 48]; // 2 × 2 × 6 = 24 cells

/// How many of the 24 grid cells rendezvous-route to `fleet[which]`.
fn cells_on(fleet: &[String], which: usize) -> usize {
    let mut n = 0;
    for name in NAMES {
        for &s in &SIZES {
            if route(name, &SimConfig::with_size(s), fleet) == which {
                n += 1;
            }
        }
    }
    n * VARIANTS.len()
}

fn fetch_stats(addr: &str, id: u64) -> fuseconv::coordinator::StatsReply {
    let resp = request_once(addr, &Request::new(id, RequestBody::Stats), T).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => s,
        other => panic!("expected stats, got {other:?}"),
    }
}

fn assert_rows_match_serial(frames: &[Frame]) {
    let plan = SweepPlan::new(
        NAMES.iter().map(|m| models::by_name(m).unwrap()).collect(),
        VARIANTS.to_vec(),
        SIZES.iter().map(|&s| SimConfig::with_size(s)).collect(),
    );
    let serial = run_sweep_serial(&plan);
    let rows: Vec<SweepRow> = frames
        .iter()
        .filter_map(|f| match f {
            Frame::Row(r) => Some(r.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(rows.len(), serial.records().len(), "every cell must arrive exactly once");
    for (row, rec) in rows.iter().zip(serial.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
        assert_eq!(row.total_cycles, rec.total_cycles());
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }
}

#[test]
fn killed_backend_mid_sweep_resteers_remaining_cells_byte_identically() {
    let survivor = TestServer::mock_backend();
    let victim = TestServer::mock_backend();
    let proxy = ChaosProxy::start(victim.addr());
    let single = TestServer::mock_backend();
    let fleet = vec![proxy.addr().to_string(), survivor.addr().to_string()];
    let front = TestServer::wire(Arc::new(ShardRouter::new(fleet.clone(), T)));

    // The grid splits over both members (rendezvous over ephemeral-port
    // addresses: with 24 cells, both sides own a share).
    let on_victim = cells_on(&fleet, 0);
    assert!(on_victim > 0, "grid must put cells on the proxied backend");

    // The victim "crashes" after relaying exactly one frame of its
    // first sub-sweep — before any row it owns has been delivered.
    proxy.set_mode(ChaosMode::DropAfterFrames(1));

    let mut sc = front.client(T);
    sc.send(&sweep_req(7, &NAMES, &VARIANTS, &SIZES)).expect("send sharded sweep");
    let sharded = stream_frames(&mut sc, 7);

    let mut nc = single.client(T);
    nc.send(&sweep_req(7, &NAMES, &VARIANTS, &SIZES)).expect("send single sweep");
    let direct = stream_frames(&mut nc, 7);

    // Failover acceptance: despite the mid-stream kill, the client's
    // stream is row-for-row byte-identical to the single node — no
    // lost cells, no duplicates, plan order intact — with the same
    // consolidated 0..=24 progress walk and the same terminal.
    assert_eq!(row_frames(&sharded, 7), row_frames(&direct, 7), "rows survive the failover");
    assert_eq!(progress_frames(&sharded), progress_frames(&direct), "one progress counter");
    assert!(matches!(sharded.last(), Some(Frame::Final(Ok(Reply::Done)))));
    assert_rows_match_serial(&sharded);

    // The front tier accounted for the re-steer and took the dead
    // member out of routing.
    let stats = fetch_stats(front.addr(), 40);
    assert!(
        stats.failover_resteered >= on_victim as u64,
        "re-planned cells must be counted: {stats:?}"
    );
    assert!(
        stats.backend_state.iter().any(|e| *e == format!("{}=down", proxy.addr())),
        "the killed backend must be Down: {:?}",
        stats.backend_state
    );
    assert!(
        stats.backend_state.iter().any(|e| *e == format!("{}=up", survivor.addr())),
        "the survivor must stay Up: {:?}",
        stats.backend_state
    );

    single.shutdown();
    front.shutdown(); // fans out: stops the survivor and (via the proxy) the victim
    survivor.join_stopped();
    victim.join_stopped();
}

#[test]
fn drain_mid_sweep_loses_zero_rows_then_removes_the_member() {
    let a = TestServer::mock_backend();
    let b = TestServer::mock_backend();
    let proxy = ChaosProxy::start(a.addr());
    let fleet = vec![proxy.addr().to_string(), b.addr().to_string()];
    let front = TestServer::wire(Arc::new(ShardRouter::new(fleet.clone(), T)));
    assert!(cells_on(&fleet, 0) > 0, "grid must put cells on the proxied backend");

    // Slow the proxied backend's stream down so the drain demonstrably
    // lands while its sub-sweeps are still in flight.
    proxy.set_mode(ChaosMode::DelayMs(50));

    let mut sc = front.client(T);
    sc.send(&sweep_req(5, &NAMES, &VARIANTS, &SIZES)).expect("send sweep");
    let mut frames = vec![sc.recv_frame(5).expect("up-front progress")];

    // Drain the proxied member mid-stream: new work stops routing to
    // it, but its in-flight sub-sweeps run to completion.
    let resp = request_once(
        front.addr(),
        &Request::new(50, RequestBody::DrainBackend { addr: proxy.addr().to_string() }),
        T,
    )
    .expect("drain ack");
    assert_eq!(resp.result, Ok(Reply::Done));

    loop {
        let frame = sc.recv_frame(5).expect("stream frame");
        let last = frame.is_final();
        frames.push(frame);
        if last {
            break;
        }
    }
    // Zero rows lost: the full grid arrived, in plan order, terminated
    // cleanly.
    assert!(matches!(frames.last(), Some(Frame::Final(Ok(Reply::Done)))));
    assert_rows_match_serial(&frames);

    // Once its in-flight work finished, the drained member left the
    // fleet entirely.
    wait_until("drained member removed", || {
        let stats = fetch_stats(front.addr(), 60);
        stats.backend_state.len() == 1
            && stats.backend_state[0] == format!("{}=up", b.addr())
    });

    front.shutdown();
    b.join_stopped();
    // The drained node is no longer in the fleet, so the front tier's
    // fan-out never reached it: it is its own deployment now.
    a.shutdown();
}

#[test]
fn add_backend_at_runtime_routes_the_new_nodes_share() {
    let a = TestServer::mock_backend();
    let front = TestServer::wire(Arc::new(ShardRouter::new(
        vec![a.addr().to_string()],
        T,
    )));

    // Join a brand-new node over the admin op, mid-deployment.
    let b = TestServer::mock_backend();
    let resp = request_once(
        front.addr(),
        &Request::new(1, RequestBody::AddBackend { addr: b.addr().to_string() }),
        T,
    )
    .expect("add ack");
    assert_eq!(resp.result, Ok(Reply::Done));

    let fleet = vec![a.addr().to_string(), b.addr().to_string()];
    let expected_b = cells_on(&fleet, 1);
    assert!(expected_b > 0, "the new node must own a rendezvous share of the grid");

    // A fresh sweep routes the new node's share onto it — and the
    // stream stays correct and complete.
    let mut sc = front.client(T);
    sc.send(&sweep_req(9, &NAMES, &VARIANTS, &SIZES)).expect("send sweep");
    let frames = stream_frames(&mut sc, 9);
    assert!(matches!(frames.last(), Some(Frame::Final(Ok(Reply::Done)))));
    assert_rows_match_serial(&frames);

    // `sim_*` counters count requests (one per sub-sweep), so the
    // joined node serving anything at all proves cells routed to it;
    // the exact per-cell split is pinned by the warm-cache test below.
    let on_b = fetch_stats(b.addr(), 70);
    assert!(
        on_b.sim_completed >= 1,
        "the new node must serve its rendezvous share ({expected_b} cells): {on_b:?}"
    );
    let stats = fetch_stats(front.addr(), 71);
    assert_eq!(stats.backends, 2, "aggregation must span the joined node");
    assert!(stats.backend_state.iter().any(|e| *e == format!("{}=up", b.addr())));

    front.shutdown();
    a.join_stopped();
    b.join_stopped();
}

#[test]
fn black_holed_backend_cannot_hold_a_deadlined_client() {
    let a = TestServer::mock_backend();
    let b = TestServer::mock_backend();
    let proxy = ChaosProxy::start(a.addr());
    proxy.set_mode(ChaosMode::BlackHole);
    let fleet = vec![proxy.addr().to_string(), b.addr().to_string()];
    // Deliberately huge backend timeout: the deadline, not the
    // transport timeout, must be what unblocks the client.
    let front = TestServer::wire(Arc::new(ShardRouter::new(fleet.clone(), T)));
    assert!(cells_on(&fleet, 0) > 0, "grid must put cells on the black hole");

    let t0 = Instant::now();
    let mut sc = front.client(T);
    sc.send(&sweep_req(3, &NAMES, &VARIANTS, &SIZES).with_deadline_ms(500))
        .expect("send deadlined sweep");
    let frames = stream_frames(&mut sc, 3);
    assert!(
        matches!(frames.last(), Some(Frame::Final(Err(ServeError::Deadline)))),
        "a black-holed shard must surface the deadline, got {:?}",
        frames.last()
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "the client was held {}ms — far past its 500ms deadline",
        t0.elapsed().as_millis()
    );

    // Unstick the parked relay threads, then shut down cleanly.
    proxy.set_mode(ChaosMode::Refuse);
    proxy.kill_connections();
    front.shutdown();
    b.join_stopped();
    a.shutdown(); // nothing ever got through the black hole to `a`
}

#[test]
fn probes_harden_a_black_hole_to_down_and_traffic_routes_around_it() {
    let a = TestServer::mock_backend();
    let b = TestServer::mock_backend();
    let proxy = ChaosProxy::start(a.addr());
    proxy.set_mode(ChaosMode::BlackHole);
    let fleet = vec![proxy.addr().to_string(), b.addr().to_string()];
    let front = TestServer::wire(Arc::new(
        ShardRouter::new(fleet, T).with_probes(Duration::from_millis(25), 2),
    ));

    // Probe budget: 2 failed round-trips at a 25ms cadence (each capped
    // at the interval) — well under the polling ceiling.
    wait_until("black-holed backend probed Down", || {
        let stats = fetch_stats(front.addr(), 80);
        stats.probe_failures >= 2
            && stats.backend_state.iter().any(|e| *e == format!("{}=down", proxy.addr()))
    });

    // With the black hole Down, a fresh sweep routes entirely around it
    // and completes — the fleet healed itself.
    let mut sc = front.client(T);
    sc.send(&sweep_req(4, &NAMES, &VARIANTS, &SIZES)).expect("send sweep");
    let frames = stream_frames(&mut sc, 4);
    assert!(matches!(frames.last(), Some(Frame::Final(Ok(Reply::Done)))));
    assert_rows_match_serial(&frames);

    proxy.set_mode(ChaosMode::Refuse);
    proxy.kill_connections();
    front.shutdown();
    b.join_stopped();
    a.shutdown();
}

/// A backend with a per-node global result cache, as mounted by
/// `fuseconv serve --cache-entries N`.
fn cached_backend() -> TestServer {
    let sim = SimServer::new(2).with_result_cache(Arc::new(ResultCache::new(64)));
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    TestServer::wire(Arc::new(router))
}

#[test]
fn membership_growth_only_invalidates_the_moved_shards_keys() {
    let a = cached_backend();
    let b = cached_backend();
    let front = TestServer::wire(Arc::new(ShardRouter::new(
        vec![a.addr().to_string(), b.addr().to_string()],
        T,
    )));

    // Cold pass fills the fleet's caches; identical warm pass hits on
    // every cell.
    let mut sc = front.client(T);
    sc.send(&sweep_req(1, &NAMES, &VARIANTS, &SIZES)).expect("cold sweep");
    let _ = stream_frames(&mut sc, 1);
    sc.send(&sweep_req(2, &NAMES, &VARIANTS, &SIZES)).expect("warm sweep");
    let _ = stream_frames(&mut sc, 2);
    let warm = fetch_stats(front.addr(), 10);
    assert_eq!((warm.result_misses, warm.result_hits), (24, 24));
    let a_before = fetch_stats(a.addr(), 11).result_misses;
    let b_before = fetch_stats(b.addr(), 12).result_misses;

    // Grow the fleet. Rendezvous routing moves exactly the new node's
    // share of the keyspace — nothing shuffles between a and b.
    let c = cached_backend();
    let resp = request_once(
        front.addr(),
        &Request::new(13, RequestBody::AddBackend { addr: c.addr().to_string() }),
        T,
    )
    .expect("add ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    let grown = vec![a.addr().to_string(), b.addr().to_string(), c.addr().to_string()];
    let moved = cells_on(&grown, 2);
    assert!(moved > 0 && moved < 24, "the new node must take a proper share, got {moved}");

    sc.send(&sweep_req(3, &NAMES, &VARIANTS, &SIZES)).expect("resharded sweep");
    let frames = stream_frames(&mut sc, 3);
    assert_rows_match_serial(&frames);

    // Only the moved keys went cold: fleet-wide misses grew by exactly
    // the moved count, everything else kept hitting…
    let after = fetch_stats(front.addr(), 14);
    assert_eq!(
        after.result_misses,
        24 + moved as u64,
        "only the keys that moved to the new node may miss"
    );
    assert_eq!(after.result_hits, 24 + (24 - moved as u64), "unmoved keys stay warm");
    // …and the incumbents' caches were never invalidated at all.
    assert_eq!(fetch_stats(a.addr(), 15).result_misses, a_before, "a stayed warm");
    assert_eq!(fetch_stats(b.addr(), 16).result_misses, b_before, "b stayed warm");

    front.shutdown();
    a.join_stopped();
    b.join_stopped();
    c.join_stopped();
}

#[test]
fn search_on_a_dead_backend_fails_typed_never_hangs() {
    // A fleet whose only member closes every accepted connection: the
    // relay observes the dead transport and terminates the stream with
    // a typed error, bounded by the backend timeout — never a hang.
    let proxy = ChaosProxy::start("127.0.0.1:9"); // upstream never reached
    proxy.set_mode(ChaosMode::Refuse);
    let front = TestServer::wire(Arc::new(ShardRouter::new(
        vec![proxy.addr().to_string()],
        Duration::from_secs(5),
    )));

    let t0 = Instant::now();
    let mut sc = front.client(T);
    sc.send(&Request::new(
        21,
        RequestBody::Search {
            spec: SearchSpec {
                population: 6,
                iterations: 4,
                config: ConfigPatch::sized(8),
                ..SearchSpec::default()
            },
        },
    ))
    .expect("send search");
    let frames = stream_frames(&mut sc, 21);
    assert!(
        matches!(frames.last(), Some(Frame::Final(Err(ServeError::Shutdown)))),
        "dead backend must fail the search typed, got {:?}",
        frames.last()
    );
    assert!(t0.elapsed() < Duration::from_secs(30), "typed failure must be prompt");

    front.shutdown();
}
