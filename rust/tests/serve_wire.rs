//! Wire-level integration: the TCP/JSON frontend under concurrent mixed
//! traffic (acceptance criteria for the unified serving API).
//!
//! * ≥ 32 concurrent Infer/Simulate requests through one listener, zero
//!   dropped replies, every id answered;
//! * `Simulate` by zoo name over the wire returns cycle counts identical
//!   to a direct in-process `simulate_network`;
//! * a full bounded queue answers `busy` — it never hangs.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::{
    ConfigPatch, MockEngine, ModelSpec, Reply, Request, RequestBody, Router, ServeError,
    Server, SimServer, WireClient, WireServer,
};
use fuseconv::nn::models;
use fuseconv::sim::{simulate_network, FuseVariant, LayerCache, SimConfig};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Boot a full frontend (mock engine + sim pool) on an ephemeral port.
fn start_frontend(sim_capacity: usize) -> (String, thread::JoinHandle<()>) {
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), sim_capacity);
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind ephemeral");
    let addr = server.local_addr().to_string();
    let handle = thread::spawn(move || server.run().expect("frontend run"));
    (addr, handle)
}

fn shutdown_frontend(addr: &str, handle: thread::JoinHandle<()>) {
    let mut client = WireClient::connect(addr, Duration::from_secs(10)).expect("connect");
    let resp = client
        .roundtrip(&Request::new(u64::MAX, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    handle.join().expect("listener thread");
}

#[test]
fn concurrent_mixed_traffic_zero_dropped_replies() {
    let (addr, handle) = start_frontend(256);

    // 32 client threads, each its own connection: even ids infer, odd
    // ids simulate. Every thread must get exactly its own reply back.
    let workers: Vec<_> = (0..32u64)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut client =
                    WireClient::connect(&addr, Duration::from_secs(120)).expect("connect");
                let req = if i % 2 == 0 {
                    Request::new(i, RequestBody::Infer { input: vec![i as f32; 4] })
                } else {
                    Request::new(
                        i,
                        RequestBody::Simulate {
                            model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                            variant: FuseVariant::Half,
                            config: ConfigPatch::sized(8),
                        },
                    )
                };
                let resp = client.roundtrip(&req).expect("roundtrip");
                assert_eq!(resp.id, i, "reply must carry the request id");
                (i, resp)
            })
        })
        .collect();

    let mut infer_seen = 0;
    let mut sim_cycles = Vec::new();
    for w in workers {
        let (i, resp) = w.join().expect("client thread");
        match resp.result {
            Ok(Reply::Infer(r)) => {
                assert_eq!(i % 2, 0);
                // MockEngine: output[0] = sum(input) = 4i
                assert_eq!(r.output.len(), 2);
                assert_eq!(r.output[0], (4 * i) as f32);
                infer_seen += 1;
            }
            Ok(Reply::Sim(s)) => {
                assert_eq!(i % 2, 1);
                assert!(s.total_cycles > 0);
                sim_cycles.push(s.total_cycles);
            }
            other => panic!("request {i}: unexpected {other:?}"),
        }
    }
    assert_eq!(infer_seen, 16, "all infer replies arrived");
    assert_eq!(sim_cycles.len(), 16, "all simulate replies arrived");
    // determinism: every identical scenario priced identically
    assert!(sim_cycles.windows(2).all(|w| w[0] == w[1]));

    shutdown_frontend(&addr, handle);
}

#[test]
fn wire_simulate_matches_direct_simulation() {
    let (addr, handle) = start_frontend(64);
    let mut client = WireClient::connect(&addr, Duration::from_secs(120)).expect("connect");

    for (model, variant, size) in [
        ("mobilenet-v2", FuseVariant::Base, 16),
        ("mobilenet-v2", FuseVariant::Half, 16),
        ("mobilenet-v3-small", FuseVariant::Full, 32),
        ("mnasnet-b1", FuseVariant::Half, 8),
    ] {
        let resp = client
            .roundtrip(&Request::new(
                7,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo(model.into()),
                    variant,
                    config: ConfigPatch::sized(size),
                },
            ))
            .expect("roundtrip");
        let got = match resp.result {
            Ok(Reply::Sim(s)) => s,
            other => panic!("{model}: unexpected {other:?}"),
        };
        let net = models::by_name(model).unwrap();
        let expect = simulate_network(&variant.apply(&net), &SimConfig::with_size(size));
        assert_eq!(
            got.total_cycles, expect.total_cycles,
            "{model}/{}/{size}: wire cycles must equal direct simulation",
            variant.label()
        );
        assert_eq!(got.network, expect.network);
        assert_eq!(got.num_layers, expect.layers.len());
    }

    drop(client);
    shutdown_frontend(&addr, handle);
}

#[test]
fn full_bounded_queue_answers_busy_over_the_wire() {
    // capacity 1 → a burst of pipelined simulates must include at least
    // one `busy` answer, and every frame still gets a reply (no hang).
    let (addr, handle) = start_frontend(1);
    let mut client = WireClient::connect(&addr, Duration::from_secs(120)).expect("connect");

    const BURST: u64 = 8;
    for i in 0..BURST {
        client
            .send(&Request::new(
                100 + i,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v2".into()),
                    variant: FuseVariant::Full,
                    config: ConfigPatch::sized(32),
                },
            ))
            .expect("send");
    }
    let mut ok = 0;
    let mut busy = 0;
    for _ in 0..BURST {
        let resp = client.recv().expect("every frame gets a reply");
        match resp.result {
            Ok(Reply::Sim(_)) => ok += 1,
            Err(ServeError::Busy) => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + busy, BURST, "zero dropped replies");
    assert!(ok >= 1, "the admitted request completes");
    assert!(busy >= 1, "overload must surface as typed Busy, not a hang");

    drop(client);
    shutdown_frontend(&addr, handle);
}

#[test]
fn stats_and_zoo_over_the_wire() {
    let (addr, handle) = start_frontend(64);
    let mut client = WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");

    // drive one of each, then check the counters moved
    let resp = client
        .roundtrip(&Request::new(1, RequestBody::Infer { input: vec![0.5; 4] }))
        .expect("infer");
    assert!(resp.is_ok());
    let resp = client
        .roundtrip(&Request::new(
            2,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::default(),
            },
        ))
        .expect("simulate");
    assert!(resp.is_ok());

    let resp = client.roundtrip(&Request::new(3, RequestBody::Zoo)).expect("zoo");
    match resp.result {
        Ok(Reply::Zoo(entries)) => {
            assert_eq!(entries.len(), models::ZOO_NAMES.len());
        }
        other => panic!("unexpected {other:?}"),
    }
    let resp = client.roundtrip(&Request::new(4, RequestBody::Stats)).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.infer_served, 1);
            assert_eq!(s.sim_completed, 1);
            assert!(s.cache_misses > 0);
        }
        other => panic!("unexpected {other:?}"),
    }

    drop(client);
    shutdown_frontend(&addr, handle);
}

#[test]
fn deadline_is_enforced_over_the_wire() {
    let (addr, handle) = start_frontend(64);
    let mut client = WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");
    // a deadline that has effectively already expired
    let resp = client
        .roundtrip(
            &Request::new(
                11,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v2".into()),
                    variant: FuseVariant::Base,
                    config: ConfigPatch::default(),
                },
            )
            .with_deadline_ms(0),
        )
        .expect("roundtrip");
    assert_eq!(resp.result, Err(ServeError::Deadline));
    drop(client);
    shutdown_frontend(&addr, handle);
}
