//! Wire-level integration: the TCP/JSON frontend under concurrent mixed
//! traffic (acceptance criteria for the protocol-v2 streaming API).
//!
//! * ≥ 32 concurrent Infer/Simulate requests through one listener, zero
//!   dropped replies, every id answered;
//! * `Simulate` by zoo name over the wire returns cycle counts identical
//!   to a direct in-process `simulate_network`;
//! * a `Sweep` over a ≥24-point grid streams incremental `Progress`/`Row`
//!   frames before its `Final`, and the merged rows are bit-identical to
//!   a local serial `run_sweep`;
//! * two concurrent streamed sweeps plus pipelined infers on ONE
//!   connection each reassemble their own rows, in plan order, with zero
//!   cross-request leakage;
//! * a full bounded lane answers `busy` — it never hangs — and a
//!   saturated batch lane still admits interactive queries.

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::{
    ConfigPatch, Frame, MockEngine, ModelSpec, Reply, Request, RequestBody, Router,
    ServeError, Server, SimServer, SweepRow, WireClient,
};
use fuseconv::nn::models;
use fuseconv::sim::{
    run_sweep_serial, simulate_network, FuseVariant, LayerCache, SimConfig, SweepPlan,
};
use fuseconv::testkit::TestServer;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Local serial reference sweep for (zoo names × variants × sizes).
fn serial_reference(
    names: &[&str],
    variants: &[FuseVariant],
    sizes: &[usize],
) -> fuseconv::sim::SweepOutcome {
    let plan = SweepPlan::new(
        names.iter().map(|m| models::by_name(m).unwrap()).collect(),
        variants.to_vec(),
        sizes.iter().map(|&s| SimConfig::with_size(s)).collect(),
    );
    run_sweep_serial(&plan)
}

/// Assert streamed rows equal the serial reference, cell for cell.
fn assert_rows_match(rows: &[SweepRow], reference: &fuseconv::sim::SweepOutcome) {
    assert_eq!(rows.len(), reference.records().len(), "row count");
    for (row, rec) in rows.iter().zip(reference.records()) {
        assert_eq!(row.network, rec.network);
        assert_eq!(row.variant, rec.variant);
        assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
        assert_eq!(row.total_cycles, rec.total_cycles(), "{} {}", row.network, row.rows);
        // floats survive the wire exactly (shortest round-trip formatting)
        assert_eq!(row.latency_ms.to_bits(), rec.latency_ms().to_bits());
    }
}

/// Boot a full frontend (mock engine + sim pool) on an ephemeral port.
fn start_frontend(sim_capacity: usize) -> TestServer {
    let sim = SimServer::with_capacity(2, Arc::new(LayerCache::new()), sim_capacity);
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    TestServer::wire(Arc::new(router))
}

#[test]
fn concurrent_mixed_traffic_zero_dropped_replies() {
    let server = start_frontend(256);
    let addr = server.addr().to_string();

    // 32 client threads, each its own connection: even ids infer, odd
    // ids simulate. Every thread must get exactly its own reply back.
    let workers: Vec<_> = (0..32u64)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut client =
                    WireClient::connect(&addr, Duration::from_secs(120)).expect("connect");
                let req = if i % 2 == 0 {
                    Request::new(i, RequestBody::Infer { input: vec![i as f32; 4] })
                } else {
                    Request::new(
                        i,
                        RequestBody::Simulate {
                            model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                            variant: FuseVariant::Half,
                            config: ConfigPatch::sized(8),
                        },
                    )
                };
                let resp = client.roundtrip(&req).expect("roundtrip");
                assert_eq!(resp.id, i, "reply must carry the request id");
                (i, resp)
            })
        })
        .collect();

    let mut infer_seen = 0;
    let mut sim_cycles = Vec::new();
    for w in workers {
        let (i, resp) = w.join().expect("client thread");
        match resp.result {
            Ok(Reply::Infer(r)) => {
                assert_eq!(i % 2, 0);
                // MockEngine: output[0] = sum(input) = 4i
                assert_eq!(r.output.len(), 2);
                assert_eq!(r.output[0], (4 * i) as f32);
                infer_seen += 1;
            }
            Ok(Reply::Sim(s)) => {
                assert_eq!(i % 2, 1);
                assert!(s.total_cycles > 0);
                sim_cycles.push(s.total_cycles);
            }
            other => panic!("request {i}: unexpected {other:?}"),
        }
    }
    assert_eq!(infer_seen, 16, "all infer replies arrived");
    assert_eq!(sim_cycles.len(), 16, "all simulate replies arrived");
    // determinism: every identical scenario priced identically
    assert!(sim_cycles.windows(2).all(|w| w[0] == w[1]));

    server.shutdown();
}

#[test]
fn wire_simulate_matches_direct_simulation() {
    let server = start_frontend(64);
    let mut client = server.client(Duration::from_secs(120));

    for (model, variant, size) in [
        ("mobilenet-v2", FuseVariant::Base, 16),
        ("mobilenet-v2", FuseVariant::Half, 16),
        ("mobilenet-v3-small", FuseVariant::Full, 32),
        ("mnasnet-b1", FuseVariant::Half, 8),
    ] {
        let resp = client
            .roundtrip(&Request::new(
                7,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo(model.into()),
                    variant,
                    config: ConfigPatch::sized(size),
                },
            ))
            .expect("roundtrip");
        let got = match resp.result {
            Ok(Reply::Sim(s)) => s,
            other => panic!("{model}: unexpected {other:?}"),
        };
        let net = models::by_name(model).unwrap();
        let expect = simulate_network(&variant.apply(&net), &SimConfig::with_size(size));
        assert_eq!(
            got.total_cycles, expect.total_cycles,
            "{model}/{}/{size}: wire cycles must equal direct simulation",
            variant.label()
        );
        assert_eq!(got.network, expect.network);
        assert_eq!(got.num_layers, expect.layers.len());
    }

    drop(client);
    server.shutdown();
}

#[test]
fn full_bounded_queue_answers_busy_over_the_wire() {
    // capacity 1 → a burst of pipelined simulates must include at least
    // one `busy` answer, and every frame still gets a reply (no hang).
    let server = start_frontend(1);
    let mut client = server.client(Duration::from_secs(120));

    const BURST: u64 = 8;
    for i in 0..BURST {
        client
            .send(&Request::new(
                100 + i,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v2".into()),
                    variant: FuseVariant::Full,
                    config: ConfigPatch::sized(32),
                },
            ))
            .expect("send");
    }
    let mut ok = 0;
    let mut busy = 0;
    for i in 0..BURST {
        // demux by id: busy bounces land immediately, admitted work later
        let resp = client.recv_response(100 + i).expect("every request gets a final");
        assert_eq!(resp.id, 100 + i);
        match resp.result {
            Ok(Reply::Sim(_)) => ok += 1,
            Err(ServeError::Busy) => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + busy, BURST, "zero dropped replies");
    assert!(ok >= 1, "the admitted request completes");
    assert!(busy >= 1, "overload must surface as typed Busy, not a hang");

    drop(client);
    server.shutdown();
}

#[test]
fn stats_and_zoo_over_the_wire() {
    let server = start_frontend(64);
    let mut client = server.client(Duration::from_secs(60));

    // drive one of each, then check the counters moved
    let resp = client
        .roundtrip(&Request::new(1, RequestBody::Infer { input: vec![0.5; 4] }))
        .expect("infer");
    assert!(resp.is_ok());
    let resp = client
        .roundtrip(&Request::new(
            2,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::default(),
            },
        ))
        .expect("simulate");
    assert!(resp.is_ok());

    let resp = client.roundtrip(&Request::new(3, RequestBody::Zoo)).expect("zoo");
    match resp.result {
        Ok(Reply::Zoo(entries)) => {
            assert_eq!(entries.len(), models::ZOO_NAMES.len());
        }
        other => panic!("unexpected {other:?}"),
    }
    let resp = client.roundtrip(&Request::new(4, RequestBody::Stats)).expect("stats");
    match resp.result {
        Ok(Reply::Stats(s)) => {
            assert_eq!(s.infer_served, 1);
            assert_eq!(s.sim_completed, 1);
            assert!(s.cache_misses > 0);
        }
        other => panic!("unexpected {other:?}"),
    }

    drop(client);
    server.shutdown();
}

#[test]
fn large_grid_streams_incremental_frames_before_final() {
    // Acceptance: a wire Sweep over a ≥24-point grid must stream ≥2
    // incremental Row/Progress frames before Final, and the merged rows
    // must be bit-identical to a serial run_sweep of the same grid.
    let server = start_frontend(64);
    let mut client = server.client(Duration::from_secs(300));

    const SIZES: [usize; 8] = [4, 8, 12, 16, 24, 32, 48, 64];
    let variants = [FuseVariant::Base, FuseVariant::Half, FuseVariant::Full];
    client
        .send(&Request::new(
            7,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: variants.to_vec(),
                configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
            },
        ))
        .expect("send sweep");

    let mut incremental_before_final = 0usize;
    let mut rows = Vec::new();
    loop {
        match client.recv_frame(7).expect("stream frame") {
            Frame::Progress { done, total } => {
                assert_eq!(total, 24, "1 model × 3 variants × 8 sizes");
                assert!(done <= total);
                incremental_before_final += 1;
            }
            Frame::Row(row) => {
                incremental_before_final += 1;
                rows.push(row);
            }
            Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
            Frame::Final(result) => {
                assert_eq!(result, Ok(Reply::Done));
                break;
            }
        }
    }
    assert!(
        incremental_before_final >= 2,
        "want ≥2 incremental frames before Final, got {incremental_before_final}"
    );
    assert_eq!(rows.len(), 24);
    assert_rows_match(&rows, &serial_reference(&["mobilenet-v2"], &variants, &SIZES));

    drop(client);
    server.shutdown();
}

#[test]
fn interleaved_streams_reassemble_per_request() {
    // Two concurrent streamed Sweeps plus pipelined Infers on ONE
    // connection: each stream must reassemble its own rows in plan
    // order, with zero cross-request leakage.
    let server = start_frontend(64);
    let mut client = server.client(Duration::from_secs(300));

    client
        .send(&Request::new(
            1,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half],
                configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(16)],
            },
        ))
        .expect("send sweep 1");
    client
        .send(&Request::new(
            2,
            RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Full],
                configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(32)],
            },
        ))
        .expect("send sweep 2");
    for id in 10..14u64 {
        client
            .send(&Request::new(id, RequestBody::Infer { input: vec![id as f32; 4] }))
            .expect("send infer");
    }

    // drive the raw interleaved frame stream until all 6 finals land
    let mut rows: HashMap<u64, Vec<SweepRow>> = HashMap::new();
    let mut finals: HashMap<u64, Result<Reply, ServeError>> = HashMap::new();
    while finals.len() < 6 {
        let (id, frame) = client.recv_any().expect("frame");
        assert!(!finals.contains_key(&id), "frame after final for id {id}");
        match frame {
            Frame::Progress { .. } => {}
            Frame::Row(row) => rows.entry(id).or_default().push(row),
            Frame::SearchRow(p) => panic!("search row in a sweep/infer stream: {p:?}"),
            Frame::Final(result) => {
                finals.insert(id, result);
            }
        }
    }

    // infers: answered correctly, with zero leaked row frames
    for id in 10..14u64 {
        match finals.remove(&id) {
            Some(Ok(Reply::Infer(r))) => assert_eq!(r.output[0], (4 * id) as f32),
            other => panic!("infer {id}: unexpected {other:?}"),
        }
        assert!(!rows.contains_key(&id), "rows leaked into infer stream {id}");
    }
    // each sweep's rows match its own grid (and only its own grid)
    assert_eq!(finals.remove(&1), Some(Ok(Reply::Done)));
    assert_eq!(finals.remove(&2), Some(Ok(Reply::Done)));
    assert_rows_match(
        &rows.remove(&1).expect("sweep 1 rows"),
        &serial_reference(
            &["mobilenet-v3-small"],
            &[FuseVariant::Base, FuseVariant::Half],
            &[8, 16],
        ),
    );
    assert_rows_match(
        &rows.remove(&2).expect("sweep 2 rows"),
        &serial_reference(
            &["mobilenet-v2"],
            &[FuseVariant::Base, FuseVariant::Full],
            &[8, 32],
        ),
    );
    assert!(rows.is_empty(), "rows for unknown request ids: {:?}", rows.keys());

    drop(client);
    server.shutdown();
}

#[test]
fn saturated_batch_lane_still_admits_interactive_over_the_wire() {
    // Batch lane bound 1: queue it full of sweeps, then an interactive
    // Simulate on a second connection must be admitted and answered Ok.
    let sim = SimServer::with_lanes(2, Arc::new(LayerCache::new()), 64, 1);
    let router = Router::new(sim).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = TestServer::wire(Arc::new(router));

    let mut batch = server.client(Duration::from_secs(300));
    let sweep_body = RequestBody::Sweep {
        models: vec!["mobilenet-v2".into()],
        variants: vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
        configs: vec![
            ConfigPatch::sized(8),
            ConfigPatch::sized(16),
            ConfigPatch::sized(32),
            ConfigPatch::sized(64),
        ],
    };
    const SWEEPS: u64 = 6;
    for i in 0..SWEEPS {
        batch.send(&Request::new(200 + i, sweep_body.clone())).expect("send sweep");
    }

    // interactive lane must stay open regardless of the sweep pile-up
    let mut interactive = server.client(Duration::from_secs(120));
    let resp = interactive
        .roundtrip(&Request::new(
            1,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::sized(8),
            },
        ))
        .expect("interactive roundtrip");
    match resp.result {
        Ok(Reply::Sim(s)) => assert!(s.total_cycles > 0),
        other => panic!("interactive query starved: {other:?}"),
    }

    // every queued sweep still resolves (Ok rows or a typed Busy bounce)
    let mut ok = 0;
    let mut busy = 0;
    for i in 0..SWEEPS {
        match batch.recv_response(200 + i).expect("sweep final").result {
            Ok(Reply::Sweep(rows)) => {
                assert_eq!(rows.len(), 12);
                ok += 1;
            }
            Err(ServeError::Busy) => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + busy, SWEEPS);
    assert!(ok >= 1, "at least one sweep runs");
    assert!(busy >= 1, "lane bound 1 must bounce a {SWEEPS}-sweep burst");

    drop(batch);
    drop(interactive);
    server.shutdown();
}

#[test]
fn stalled_reader_pauses_stream_and_resumes_losslessly() {
    // ROADMAP backpressure item, end to end: a sweep client that stops
    // reading mid-stream is paced by the bounded writer channel and
    // bounded ticket buffer — the server neither buffers without limit
    // nor wedges — and on resume it still receives every row, in plan
    // order, bit-identical to the serial sweep.
    let server = start_frontend(64);
    let mut stalled = server.client(Duration::from_secs(300));
    const SIZES: [usize; 8] = [4, 8, 12, 16, 24, 32, 48, 64];
    let variants = [FuseVariant::Base, FuseVariant::Half, FuseVariant::Full];
    stalled
        .send(&Request::new(
            5,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into()],
                variants: variants.to_vec(),
                configs: SIZES.iter().map(|&s| ConfigPatch::sized(s)).collect(),
            },
        ))
        .expect("send sweep");
    // Deliberately stall: read nothing while the sweep streams.
    thread::sleep(Duration::from_millis(1500));

    // The server must stay fully responsive for other connections.
    let mut other = server.client(Duration::from_secs(120));
    let resp = other
        .roundtrip(&Request::new(
            1,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::sized(8),
            },
        ))
        .expect("interactive roundtrip");
    assert!(resp.is_ok(), "server wedged by a stalled reader: {resp:?}");

    // Resume: the paused stream picks up where it left off, losslessly.
    let mut rows = Vec::new();
    loop {
        match stalled.recv_frame(5).expect("frame after resume") {
            Frame::Progress { .. } => {}
            Frame::Row(row) => rows.push(row),
            Frame::SearchRow(p) => panic!("search row in a sweep stream: {p:?}"),
            Frame::Final(result) => {
                assert_eq!(result, Ok(Reply::Done));
                break;
            }
        }
    }
    assert_rows_match(&rows, &serial_reference(&["mobilenet-v3-small"], &variants, &SIZES));

    drop(stalled);
    drop(other);
    server.shutdown();
}

#[test]
fn deadline_is_enforced_over_the_wire() {
    let server = start_frontend(64);
    let mut client = server.client(Duration::from_secs(60));
    // a deadline that has effectively already expired
    let resp = client
        .roundtrip(
            &Request::new(
                11,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v2".into()),
                    variant: FuseVariant::Base,
                    config: ConfigPatch::default(),
                },
            )
            .with_deadline_ms(0),
        )
        .expect("roundtrip");
    assert_eq!(resp.result, Err(ServeError::Deadline));
    drop(client);
    server.shutdown();
}
