#![allow(dead_code)]
//! Minimal benchmarking harness shared by the `cargo bench` targets
//! (criterion is unavailable offline). Provides wall-clock timing with
//! warmup + repetitions, table-style reporting identical in spirit to the
//! paper's tables/figures, and CSV dumps next to the bench output.

use std::time::Instant;

/// Time `f` with `warmup` + `iters` runs; returns per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing::from(samples)
}

#[derive(Debug, Clone)]
pub struct Timing {
    pub samples: Vec<f64>,
}

impl Timing {
    pub fn from(mut samples: Vec<f64>) -> Timing {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Timing { samples }
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn p50(&self) -> f64 {
        self.samples[self.samples.len() / 2]
    }

    pub fn min(&self) -> f64 {
        self.samples[0]
    }

    pub fn report(&self, label: &str) {
        println!(
            "  [bench] {label:40} mean {:>10.3} ms   p50 {:>10.3} ms   min {:>10.3} ms   (n={})",
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.min() * 1e3,
            self.samples.len()
        );
    }
}

/// Write a CSV next to the bench output for plotting.
pub fn write_csv(name: &str, content: &str) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(name);
    if std::fs::write(&path, content).is_ok() {
        println!("  [csv] wrote {}", path.display());
    }
}

/// Section banner.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Should this section run, given argv selectors? With no selectors,
/// everything runs.
pub fn selected(selectors: &[String], key: &str) -> bool {
    selectors.is_empty() || selectors.iter().any(|s| s.trim_start_matches("--") == key)
}

/// Collect CLI selectors (skipping cargo-bench's --bench flag).
pub fn selectors() -> Vec<String> {
    std::env::args().skip(1).filter(|a| a != "--bench").collect()
}
