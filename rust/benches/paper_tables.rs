//! Regenerates the paper's TABLES (experiment index E2, E3, E4, E16).
//!
//!   --table2         ST-OS VLSI overheads (paper Table 2)
//!   --table2-detail  component breakdown (paper §5.2)
//!   --table3         ImageNet acc / MACs / params for 5 nets × 5 variants
//!   --table4         NAS networks: acc / MACs / params / 16×16 latency
//!
//! Run all: `cargo bench --bench paper_tables`

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::{section, selected, selectors, write_csv};
use fuseconv::coordinator::mapping::greedy_half;
use fuseconv::coordinator::search::{AccuracyPredictor, TrainMethod};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::exec::Pool;
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, fuse_network, Network, Selection, Variant};
use fuseconv::sim::{run_sweep, FuseVariant, LayerCache, SimConfig, SweepPlan};
use fuseconv::vlsi;
use std::sync::Arc;

fn main() {
    let sel = selectors();
    if selected(&sel, "table2") {
        table2();
    }
    if selected(&sel, "table2-detail") {
        table2_detail();
    }
    if selected(&sel, "table3") {
        table3();
    }
    if selected(&sel, "table4") {
        table4();
    }
}

fn table2() {
    section("Table 2 — ST-OS area/power overheads vs array size");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>12}",
        "array", "area %", "paper", "power %", "paper"
    );
    let mut csv = String::from("size,area_pct,paper_area,power_pct,paper_power\n");
    for (s, pa, pp) in vlsi::PAPER_TABLE2 {
        let o = vlsi::st_os_overhead(s, s);
        println!(
            "{:>7}x{:<3} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            s,
            s,
            o.area_pct(),
            pa,
            o.power_pct(),
            pp
        );
        csv.push_str(&format!("{s},{:.2},{pa},{:.2},{pp}\n", o.area_pct(), o.power_pct()));
    }
    write_csv("table2.csv", &csv);
}

fn table2_detail() {
    section("Table 2 detail — overhead composition (gate-equivalents)");
    for s in vlsi::table2_sizes() {
        let o = vlsi::st_os_overhead(s, s);
        println!(
            "{:>3}x{:<3} base_area {:>12.0}  extra_area {:>9.0}  base_pwr {:>8.0}  extra_pwr {:>7.2}",
            s, s, o.base_area, o.extra_area, o.base_power, o.extra_power
        );
    }
}

/// Row of Table 3: name, accuracy (predictor, anchored to the paper's
/// measurements), MACs, params.
fn t3_row(csv: &mut String, name: &str, acc: f64, net: &Network) {
    println!(
        "{:36} {:>8.2} {:>10.1} {:>11.2}",
        name,
        acc,
        net.macs_millions(),
        net.params_millions()
    );
    csv.push_str(&format!(
        "{name},{acc:.2},{:.1},{:.2}\n",
        net.macs_millions(),
        net.params_millions()
    ));
}

fn table3() {
    section("Table 3 — ImageNet accuracy / MACs / params (in-place variants)");
    println!("{:36} {:>8} {:>10} {:>11}", "network", "acc %", "MACs (M)", "params (M)");
    let ev = Evaluator::new(SimConfig::default());
    let mut csv = String::from("network,acc,macs_m,params_m\n");
    for base in models::paper_five() {
        let space = HybridSpace::new(&base, &ev);
        let pred = AccuracyPredictor::for_space(&space);

        t3_row(&mut csv, &base.name, pred.anchor.base_acc, &base);

        // Full / Half variants: anchored drops from the paper.
        let full = fuse_all(&base, Variant::Full);
        t3_row(&mut csv, &full.name, pred.anchor.base_acc - pred.anchor.drop_full, &full);
        let half = fuse_all(&base, Variant::Half);
        t3_row(&mut csv, &half.name, pred.predict_all(TrainMethod::InPlace), &half);

        // 50% variants: greedy-by-latency block choice (paper §6.2).
        let mask = greedy_half(&space);
        let blocks: Vec<usize> = space
            .blocks
            .iter()
            .zip(&mask)
            .filter(|(_, &m)| m)
            .map(|(&b, _)| b)
            .collect();
        let full50 = fuse_network(&base, Variant::Full, &Selection::Blocks(blocks.clone()));
        let frac: f64 = mask
            .iter()
            .zip(&pred.block_weight)
            .filter(|(&m, _)| m)
            .map(|(_, &w)| w)
            .sum();
        t3_row(
            &mut csv,
            &format!("{}-50%", full.name),
            pred.anchor.base_acc - pred.anchor.drop_full * frac,
            &full50,
        );
        let half50 = fuse_network(&base, Variant::Half, &Selection::Blocks(blocks));
        t3_row(
            &mut csv,
            &format!("{}-50%", half.name),
            pred.predict_mask(&mask, TrainMethod::InPlace),
            &half50,
        );
        println!();
    }
    write_csv("table3.csv", &csv);
}

fn table4() {
    section("Table 4 — NAS networks on a 16x16 systolic array");
    println!(
        "{:36} {:>8} {:>10} {:>11} {:>10}",
        "network", "acc %", "MACs (M)", "params (M)", "lat (ms)"
    );
    let mut csv = String::from("network,acc,macs_m,params_m,latency_ms\n");
    // (zoo name, paper-reported accuracy)
    let rows: &[(&str, f64)] = &[
        ("mnasnet-b1", 73.5),
        ("proxylessnas", 74.6),
        ("single-path-nas", 74.7),
        ("fbnet-c", 74.9),
        ("efficientnet-lite0", 75.1),
        ("efficientnet-edgetpu-s", 77.2),
        ("mobilenet-v3-large", 75.3),
        ("ofa", 77.1),
        ("fuse-ofa-1", 76.7),
        ("fuse-ofa-2", 77.2),
    ];
    // The whole comparison column is one sweep: every Table-4 network (and
    // "ours" — the FuSe-Half conversions) through the 16×16 default config
    // in parallel on a shared layer cache.
    let pool = Pool::new(0);
    let cache = Arc::new(LayerCache::new());
    let plan = SweepPlan::new(
        rows.iter().map(|&(name, _)| models::by_name(name).unwrap()).collect(),
        vec![FuseVariant::Base],
        vec![SimConfig::default()],
    );
    let out = run_sweep(&plan, &pool, &cache);
    for (i, &(_, acc)) in rows.iter().enumerate() {
        let net = &plan.networks[i];
        let sim = &out.record(i, 0, 0).sim;
        println!(
            "{:36} {:>8.2} {:>10.1} {:>11.2} {:>10.3}",
            net.name,
            acc,
            net.macs_millions(),
            net.params_millions(),
            sim.latency_ms
        );
        csv.push_str(&format!(
            "{},{acc},{:.1},{:.2},{:.3}\n",
            net.name,
            net.macs_millions(),
            net.params_millions(),
            sim.latency_ms
        ));
    }
    // ours: FuSe-Half conversions of the two strongest baselines (NOS acc),
    // priced through the same shared cache.
    let ours_plan = SweepPlan::new(
        vec![
            models::by_name("mnasnet-b1").unwrap(),
            models::by_name("mobilenet-v3-large").unwrap(),
        ],
        vec![FuseVariant::Half],
        vec![SimConfig::default()],
    );
    let ours = run_sweep(&ours_plan, &pool, &cache);
    let ev = Evaluator::with_cache(SimConfig::default(), Arc::clone(&cache));
    for (i, base) in ours_plan.networks.iter().enumerate() {
        let space = HybridSpace::new(base, &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let half = fuse_all(base, Variant::Half);
        let sim = &ours.record(i, 0, 0).sim;
        let acc = pred.predict_all(TrainMethod::Nos);
        println!(
            "{:36} {:>8.2} {:>10.1} {:>11.2} {:>10.3}  (ours, NOS)",
            half.name,
            acc,
            half.macs_millions(),
            half.params_millions(),
            sim.latency_ms
        );
        csv.push_str(&format!(
            "{},{acc:.2},{:.1},{:.2},{:.3}\n",
            half.name,
            half.macs_millions(),
            half.params_millions(),
            sim.latency_ms
        ));
    }
    write_csv("table4.csv", &csv);

    // Shape checks the paper's narrative depends on (rows 9, 5, 7 above):
    let fuse2 = &out.record(9, 0, 0).sim;
    let edgetpu = &out.record(5, 0, 0).sim;
    let ofa = &out.record(7, 0, 0).sim;
    println!(
        "\nshape checks: FuSe-OFA-2 faster than EfficientNet-EdgeTPU-S: {} ({:.2}x); \
         faster than OFA: {} ({:.2}x)",
        fuse2.total_cycles < edgetpu.total_cycles,
        edgetpu.total_cycles as f64 / fuse2.total_cycles as f64,
        fuse2.total_cycles < ofa.total_cycles,
        ofa.total_cycles as f64 / fuse2.total_cycles as f64,
    );
}
