//! Regenerates the paper's FIGURES (experiment index E5–E10).
//!
//!   --fig8a  network latency: base OS/WS vs FuSe-Half/Full ST-OS (16×16)
//!   --fig8b  layerwise (bottleneck-block) speedup, MobileNetV2 FuSe-Half
//!   --fig9a  operator-class latency distribution, base vs FuSe
//!   --fig9b  speedup scaling with array size 8→64
//!   --fig10  per-bottleneck utilization, base vs FuSe-Half
//!   --fig11  layerwise DRAM/SRAM bandwidth, MobileNetV3-Large
//!
//! Every figure is a sweep (networks × variants × configs); all of them
//! submit through `sim::sweep::run_sweep` on one shared pool + layer
//! cache, so the whole bench run prices each distinct layer once.
//!
//! Run all: `cargo bench --bench paper_figures`

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::{section, selected, selectors, write_csv};
use fuseconv::exec::Pool;
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, OpClass, Variant};
use fuseconv::sim::{
    grid_configs, run_sweep, Dataflow, FuseVariant, LayerCache, SimConfig, SweepOutcome,
    SweepPlan,
};
use std::sync::Arc;

/// Shared sweep substrate for every figure in one bench run.
struct Ctx {
    pool: Pool,
    cache: Arc<LayerCache>,
}

impl Ctx {
    fn sweep(&self, plan: &SweepPlan) -> SweepOutcome {
        run_sweep(plan, &self.pool, &self.cache)
    }
}

fn main() {
    let ctx = Ctx { pool: Pool::new(0), cache: Arc::new(LayerCache::new()) };
    let sel = selectors();
    if selected(&sel, "fig8a") {
        fig8a(&ctx);
    }
    if selected(&sel, "fig8b") {
        fig8b(&ctx);
    }
    if selected(&sel, "fig9a") {
        fig9a(&ctx);
    }
    if selected(&sel, "fig9b") {
        fig9b(&ctx);
    }
    if selected(&sel, "fig10") {
        fig10(&ctx);
    }
    if selected(&sel, "fig11") {
        fig11(&ctx);
    }
    if selected(&sel, "ablations") {
        ablations(&ctx);
    }
    let cs = ctx.cache.stats();
    println!(
        "\n[sweep cache] {} hits / {} misses across all figures ({:.1}% hit rate, {} entries)",
        cs.hits,
        cs.misses,
        100.0 * cs.hit_rate(),
        cs.entries
    );
}

/// Design-choice ablations DESIGN.md calls out (paper §3.3–3.4, §6.1.4):
/// (a) ST-OS broadcast links on/off, (b) slice-to-row mapping policy,
/// (c) bandwidth-constrained execution.
fn ablations(ctx: &Ctx) {
    section("Ablation (a) — ST-OS hardware support on/off (FuSe-Half nets)");
    let plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Half],
        vec![SimConfig::default(), SimConfig::default().without_stos()],
    );
    let out = ctx.sweep(&plan);
    for (i, net) in plan.networks.iter().enumerate() {
        let a = &out.record(i, 0, 0).sim;
        let b = &out.record(i, 0, 1).sim;
        println!(
            "{:22} with ST-OS {:>8.3} ms   without {:>8.3} ms   ({:.1}x from the broadcast links)",
            net.name,
            a.latency_ms,
            b.latency_ms,
            b.total_cycles as f64 / a.total_cycles as f64
        );
    }

    section("Ablation (b) — ST-OS mapping policy (weight-SRAM reads, MobileNetV2 FuSe)");
    use fuseconv::sim::engine::schedule_layer;
    use fuseconv::sim::MappingPolicy;
    let half = fuse_all(&models::by_name("mobilenet-v2").unwrap(), Variant::Half);
    let fuse_layer = half
        .layers
        .iter()
        .find(|l| matches!(l.class(), OpClass::FuSe))
        .unwrap();
    for (name, policy) in [
        ("spatial-first", MappingPolicy::SpatialFirst),
        ("channels-first", MappingPolicy::ChannelsFirst),
        ("hybrid", MappingPolicy::Hybrid),
    ] {
        let cfg = SimConfig { mapping: policy, ..SimConfig::default() };
        let fs = schedule_layer(fuse_layer, &cfg);
        let wreads: u64 = fs.folds.iter().map(|f| f.weight_reads * f.count).sum();
        println!(
            "{:16} weight-SRAM reads {:>9}   compute cycles {:>8}",
            name,
            wreads,
            fs.compute_cycles()
        );
    }
    println!("(paper §3.4: spatial-first trades broadcast circuitry for fewer SRAM reads)");

    section("Ablation (c) — bandwidth-constrained execution (enforce_dram_bw)");
    let bws = [8.0, 16.0, 32.0, 64.0, 128.0];
    let configs: Vec<SimConfig> = bws
        .iter()
        .map(|&bw| SimConfig { enforce_dram_bw: true, dram_bw: bw, ..SimConfig::default() })
        .collect();
    let plan = SweepPlan::new(
        vec![models::by_name("mobilenet-v2").unwrap()],
        vec![FuseVariant::Base, FuseVariant::Half],
        configs,
    );
    let out = ctx.sweep(&plan);
    for (c, bw) in bws.iter().enumerate() {
        let sb = &out.record(0, 0, c).sim;
        let sh = &out.record(0, 1, c).sim;
        println!(
            "dram {bw:>5.0} B/cyc:  base {:>8.3} ms   FuSe-Half {:>8.3} ms   speedup {:>5.2}x",
            sb.latency_ms,
            sh.latency_ms,
            sb.total_cycles as f64 / sh.total_cycles as f64
        );
    }
    println!("(ST-OS parallelism is bandwidth-hungry: the speedup grows with DRAM bandwidth)");
}

fn fig8a(ctx: &Ctx) {
    section("Fig 8(a) — latency on 16x16: baselines (OS, WS) vs FuSe (ST-OS)");
    // Two plans on the shared pool/cache: the figure only needs WS for the
    // baseline column, so don't simulate Half/Full under WS.
    let plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
        vec![SimConfig::default()],
    );
    let ws_plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base],
        vec![SimConfig::default().with_dataflow(Dataflow::WeightStationary)],
    );
    let out = ctx.sweep(&plan);
    let ws_out = ctx.sweep(&ws_plan);
    println!(
        "{:22} {:>9} {:>9} {:>9} {:>9} {:>7} {:>7}",
        "network", "OS ms", "WS ms", "half ms", "full ms", "spd-H", "spd-F"
    );
    let mut csv =
        String::from("network,base_os_ms,base_ws_ms,half_ms,full_ms,speedup_half,speedup_full\n");
    let mut spd_h = Vec::new();
    let mut spd_f = Vec::new();
    for (i, net) in plan.networks.iter().enumerate() {
        let so = &out.record(i, 0, 0).sim;
        let sw = &ws_out.record(i, 0, 0).sim;
        let sh = &out.record(i, 1, 0).sim;
        let sf = &out.record(i, 2, 0).sim;
        let h = so.total_cycles as f64 / sh.total_cycles as f64;
        let f = so.total_cycles as f64 / sf.total_cycles as f64;
        spd_h.push(h);
        spd_f.push(f);
        println!(
            "{:22} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>6.2}x {:>6.2}x",
            net.name, so.latency_ms, sw.latency_ms, sh.latency_ms, sf.latency_ms, h, f
        );
        csv.push_str(&format!(
            "{},{:.3},{:.3},{:.3},{:.3},{h:.2},{f:.2}\n",
            net.name, so.latency_ms, sw.latency_ms, sh.latency_ms, sf.latency_ms
        ));
    }
    write_csv("fig8a.csv", &csv);
    println!(
        "\nFuSe-Half speedups {:.2}–{:.2}x (paper: 7.01–9.36x); FuSe-Full {:.2}–{:.2}x (paper: 4.15–5.05x)",
        spd_h.iter().cloned().fold(f64::MAX, f64::min),
        spd_h.iter().cloned().fold(0.0, f64::max),
        spd_f.iter().cloned().fold(f64::MAX, f64::min),
        spd_f.iter().cloned().fold(0.0, f64::max),
    );
}

fn fig8b(ctx: &Ctx) {
    section("Fig 8(b) — per-bottleneck-block speedup, MobileNetV2 FuSe-Half");
    let base = models::by_name("mobilenet-v2").unwrap();
    let plan = SweepPlan::new(
        vec![base.clone()],
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::default()],
    );
    let out = ctx.sweep(&plan);
    let sb = &out.record(0, 0, 0).sim;
    let sh = &out.record(0, 1, 0).sim;
    let mut csv = String::from("block,base_cycles,fuse_cycles,speedup\n");
    println!("{:>6} {:>12} {:>12} {:>9}", "block", "base cyc", "fuse cyc", "speedup");
    let mut speedups = Vec::new();
    for b in base.bottleneck_blocks() {
        let bc = sb.block_cycles(b);
        let fc = sh.block_cycles(b);
        let s = bc as f64 / fc.max(1) as f64;
        speedups.push(s);
        println!("{:>6} {:>12} {:>12} {:>8.2}x", b, bc, fc, s);
        csv.push_str(&format!("{b},{bc},{fc},{s:.2}\n"));
    }
    write_csv("fig8b.csv", &csv);
    println!(
        "\nblock speedups span {:.1}–{:.1}x (paper: 4–11x, smaller late layers lower)",
        speedups.iter().cloned().fold(f64::MAX, f64::min),
        speedups.iter().cloned().fold(0.0, f64::max)
    );
}

fn fig9a(ctx: &Ctx) {
    section("Fig 9(a) — latency share per operator class");
    let plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::default()],
    );
    let out = ctx.sweep(&plan);
    let mut csv = String::from("network,variant,class,share\n");
    for (i, net) in plan.networks.iter().enumerate() {
        for (v, variant) in [(0, "base"), (1, "fuse-half")] {
            let sim = &out.record(i, v, 0).sim;
            let by = sim.cycles_by_class();
            let share = |c: OpClass| {
                *by.get(&c).unwrap_or(&0) as f64 / sim.total_cycles as f64 * 100.0
            };
            println!(
                "{:22} {:9}  dw {:>5.1}%  fuse {:>5.1}%  pw {:>5.1}%  conv {:>5.1}%  other {:>5.1}%",
                net.name,
                variant,
                share(OpClass::Depthwise),
                share(OpClass::FuSe),
                share(OpClass::Pointwise),
                share(OpClass::OtherConv),
                share(OpClass::Other)
            );
            for c in [
                OpClass::Depthwise,
                OpClass::FuSe,
                OpClass::Pointwise,
                OpClass::OtherConv,
                OpClass::Other,
            ] {
                csv.push_str(&format!("{},{variant},{c:?},{:.2}\n", net.name, share(c)));
            }
        }
    }
    write_csv("fig9a.csv", &csv);
    println!("\n(paper: depthwise >90% of baseline latency; FuSe <50% after conversion)");
}

fn fig9b(ctx: &Ctx) {
    section("Fig 9(b) — FuSe-Half speedup vs systolic-array size");
    let sizes = [8usize, 16, 32, 64, 128];
    let plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base, FuseVariant::Half],
        grid_configs(&sizes, &[Dataflow::OutputStationary], &[true]),
    );
    let out = ctx.sweep(&plan);
    print!("{:22}", "network");
    for s in sizes {
        print!(" {:>8}", format!("{s}x{s}"));
    }
    println!();
    let mut csv = String::from("network,size,speedup\n");
    for (i, net) in plan.networks.iter().enumerate() {
        print!("{:22}", net.name);
        for (c, s) in sizes.iter().enumerate() {
            let sb = &out.record(i, 0, c).sim;
            let sh = &out.record(i, 1, c).sim;
            let spd = sb.total_cycles as f64 / sh.total_cycles as f64;
            print!(" {:>7.2}x", spd);
            csv.push_str(&format!("{},{s},{spd:.2}\n", net.name));
        }
        println!();
    }
    write_csv("fig9b.csv", &csv);
    println!("\n(paper: speedup grows with array size; MobileNetV3-Small saturates early)");
}

fn fig10(ctx: &Ctx) {
    section("Fig 10 — bottleneck-block PE utilization (base vs FuSe-Half)");
    let plan = SweepPlan::new(
        models::paper_five(),
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::default()],
    );
    let out = ctx.sweep(&plan);
    let mut csv = String::from("network,block,base_util,fuse_util\n");
    for (i, net) in plan.networks.iter().enumerate() {
        let sb = &out.record(i, 0, 0).sim;
        let sh = &out.record(i, 1, 0).sim;
        let mut base_us = Vec::new();
        let mut fuse_us = Vec::new();
        for b in net.bottleneck_blocks() {
            let ub = sb.block_utilization(b);
            let uf = sh.block_utilization(b);
            base_us.push(ub);
            fuse_us.push(uf);
            csv.push_str(&format!("{},{b},{ub:.4},{uf:.4}\n", net.name));
        }
        let rng = |v: &[f64]| {
            (v.iter().cloned().fold(f64::MAX, f64::min) * 100.0,
             v.iter().cloned().fold(0.0, f64::max) * 100.0)
        };
        let (bl, bh) = rng(&base_us);
        let (fl, fh) = rng(&fuse_us);
        println!(
            "{:22} base {:>4.1}–{:>4.1}%   FuSe {:>5.1}–{:>5.1}%",
            net.name, bl, bh, fl, fh
        );
    }
    write_csv("fig10.csv", &csv);
    println!("\n(paper: baselines 5–6%, FuSe 56–100%)");
}

fn fig11(ctx: &Ctx) {
    section("Fig 11 — layerwise DRAM/SRAM bandwidth, MobileNetV3-Large");
    let plan = SweepPlan::new(
        vec![models::by_name("mobilenet-v3-large").unwrap()],
        vec![FuseVariant::Base, FuseVariant::Half],
        vec![SimConfig::default()],
    );
    let out = ctx.sweep(&plan);
    let mut csv =
        String::from("variant,layer,class,dram_avg,dram_max,sram_avg,sram_max\n");
    for (v, variant) in [(0, "base"), (1, "fuse-half")] {
        let sim = &out.record(0, v, 0).sim;
        let mut dw_or_fuse_avg: Vec<f64> = Vec::new();
        let mut pw_avg: Vec<f64> = Vec::new();
        let mut dw_max = 0.0f64;
        let mut pw_max = 0.0f64;
        for l in &sim.layers {
            csv.push_str(&format!(
                "{variant},{},{:?},{:.2},{:.2},{:.2},{:.2}\n",
                l.name, l.class, l.mem.dram_bw_avg, l.mem.dram_bw_max, l.mem.sram_bw_avg,
                l.mem.sram_bw_max
            ));
            match l.class {
                OpClass::Depthwise | OpClass::FuSe => {
                    dw_or_fuse_avg.push(l.mem.dram_bw_avg);
                    dw_max = dw_max.max(l.mem.dram_bw_max);
                }
                OpClass::Pointwise => {
                    pw_avg.push(l.mem.dram_bw_avg);
                    pw_max = pw_max.max(l.mem.dram_bw_max);
                }
                _ => {}
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "{variant:9}: spatial-op DRAM avg {:>6.2} B/cyc (max {:>7.2}) | pointwise avg {:>6.2} (max {:>7.2})",
            mean(&dw_or_fuse_avg),
            dw_max,
            mean(&pw_avg),
            pw_max
        );
    }
    write_csv("fig11.csv", &csv);
    println!(
        "\n(paper: FuSe layers demand more average bandwidth than depthwise, but peak \
         DRAM demand stays comparable to pointwise layers)"
    );
}
