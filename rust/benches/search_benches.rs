//! Regenerates the paper's search + training experiments (index E11–E15).
//!
//!   --fig13  EA pareto frontiers for hybrid MobileNetV3-L / MnasNet-B1
//!   --fig14  EA-found vs manual hybrid layer maps (text visualization)
//!   --fig15  OFA NAS pareto with vs without the FuSe operator
//!   --fig12  teacher/student feature-map similarity (needs artifacts)
//!   --nos    NOS vs in-place accuracy at small scale (needs artifacts)
//!
//! `--fig12`/`--nos` run the AOT graphs; they skip (with a notice) when
//! `make artifacts` has not been run. Run all: `cargo bench --bench
//! search_benches`

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::{section, selected, selectors, write_csv};
use fuseconv::coordinator::mapping::greedy_half;
use fuseconv::coordinator::search::{
    run_ea, run_nas, AccuracyPredictor, EaConfig, NasConfig, TrainMethod,
};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::sim::SimConfig;
use std::sync::Arc;

fn main() {
    let sel = selectors();
    if selected(&sel, "fig13") {
        fig13();
    }
    if selected(&sel, "fig14") {
        fig14();
    }
    if selected(&sel, "fig15") {
        fig15();
    }
    if selected(&sel, "fig12") {
        fig12();
    }
    if selected(&sel, "nos") {
        nos();
    }
}

fn fig13() {
    section("Fig 13 — EA pareto frontier for hybrid networks (NOS-trained)");
    let ev = Evaluator::new(SimConfig::default());
    let mut csv = String::from("network,acc,latency_ms,macs_m\n");
    for name in ["mobilenet-v3-large", "mnasnet-b1"] {
        let base = models::by_name(name).unwrap();
        let space = HybridSpace::new(&base, &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let cfg = EaConfig { population: 100, iterations: 100, seed: 42, ..EaConfig::default() };
        let t0 = std::time::Instant::now();
        let r = run_ea(&space, &pred, TrainMethod::Nos, &cfg);
        println!(
            "\n{name}: {} candidates in {:.2}s; frontier ({} points):",
            r.evaluated,
            t0.elapsed().as_secs_f64(),
            r.frontier.len()
        );
        for c in &r.frontier {
            println!("  acc {:>6.2}%  lat {:>7.3} ms  MACs {:>6.1} M", c.acc, c.latency_ms, c.macs as f64 / 1e6);
            csv.push_str(&format!("{name},{:.3},{:.4},{:.1}\n", c.acc, c.latency_ms, c.macs as f64 / 1e6));
        }
        // Endpoints for reference (the paper's Fig 13 anchors)
        let n = space.num_blocks();
        let base_acc = pred.predict_mask(&vec![false; n], TrainMethod::Nos);
        let base_lat = space.latency_ms(&vec![false; n]);
        let full_acc = pred.predict_mask(&vec![true; n], TrainMethod::Nos);
        let full_lat = space.latency_ms(&vec![true; n]);
        println!(
            "  [anchors] baseline {base_acc:.2}% @ {base_lat:.3} ms   all-FuSe(NOS) {full_acc:.2}% @ {full_lat:.3} ms"
        );
        // paper claim: best hybrid within ~0.4% of baseline at much lower latency
        let best = &r.best_acc;
        println!(
            "  [claim] best hybrid {:.2}% @ {:.3} ms -> gap to baseline {:.2}% at {:.2}x lower latency",
            best.acc,
            best.latency_ms,
            base_acc - best.acc,
            base_lat / best.latency_ms
        );
    }
    write_csv("fig13.csv", &csv);
}

fn fig14() {
    section("Fig 14 — hybrid layer maps: manual vs EA-found (MobileNetV3-Large)");
    let ev = Evaluator::new(SimConfig::default());
    let base = models::by_name("mobilenet-v3-large").unwrap();
    let space = HybridSpace::new(&base, &ev);
    let pred = AccuracyPredictor::for_space(&space);

    let manual = greedy_half(&space);
    let cfg = EaConfig { population: 100, iterations: 60, seed: 7, ..EaConfig::default() };
    let r = run_ea(&space, &pred, TrainMethod::Nos, &cfg);
    // pick the frontier point that dominates/ties manual accuracy
    let manual_acc = pred.predict_mask(&manual, TrainMethod::Nos);
    let ea_pick = r
        .frontier
        .iter()
        .filter(|c| c.acc >= manual_acc - 0.05)
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .unwrap_or(&r.best_acc);

    let render = |mask: &[bool]| -> String {
        mask.iter().map(|&m| if m { 'F' } else { 'd' }).collect()
    };
    println!("  block:              {}", (0..space.num_blocks()).map(|i| char::from_digit((i % 10) as u32, 10).unwrap()).collect::<String>());
    println!(
        "  manual (greedy 50%): {}  acc {:.2}%  lat {:.3} ms",
        render(&manual),
        manual_acc,
        space.latency_ms(&manual)
    );
    println!(
        "  EA-found:            {}  acc {:.2}%  lat {:.3} ms",
        render(&ea_pick.mask),
        ea_pick.acc,
        ea_pick.latency_ms
    );
    let ea_fuse = ea_pick.mask.iter().filter(|&&m| m).count();
    let manual_fuse = manual.iter().filter(|&&m| m).count();
    println!(
        "\n(paper: the EA hybrid uses MORE FuSe blocks ({ea_fuse} vs {manual_fuse}) \
         while keeping accuracy — it picks the cheap-to-convert blocks)"
    );
}

fn fig15() {
    section("Fig 15 — OFA NAS pareto: baseline space vs +FuSe operator");
    let mut csv = String::from("space,acc,latency_ms,macs_m\n");
    for (label, allow_fuse) in [("ofa-baseline", false), ("ofa+fuse", true)] {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg = NasConfig {
            population: 32,
            iterations: 20,
            allow_fuse,
            seed: 42,
            threads: 0,
            ..NasConfig::default()
        };
        let t0 = std::time::Instant::now();
        let r = run_nas(ev, &cfg);
        println!(
            "\n{label}: {} genomes in {:.1}s; frontier ({}):",
            r.evaluated,
            t0.elapsed().as_secs_f64(),
            r.frontier.len()
        );
        for c in &r.frontier {
            println!(
                "  acc {:>6.2}%  lat {:>7.3} ms  MACs {:>6.1} M  params {:>5.2} M",
                c.acc, c.latency_ms, c.macs_millions, c.params_millions
            );
            csv.push_str(&format!(
                "{label},{:.3},{:.4},{:.1}\n",
                c.acc, c.latency_ms, c.macs_millions
            ));
        }
    }
    write_csv("fig15.csv", &csv);
    println!("\n(paper: the +FuSe frontier dominates — more accurate AND faster)");
}

#[cfg(feature = "xla")]
fn artifacts() -> Option<std::path::PathBuf> {
    let dir = fuseconv::runtime::default_artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        println!("  [skip] artifacts not built — run `make artifacts` first");
        None
    }
}

#[cfg(not(feature = "xla"))]
fn fig12() {
    section("Fig 12 — teacher/student feature similarity (NOS vs in-place)");
    println!("  [skip] built without the `xla` feature (PJRT runtime unavailable)");
}

#[cfg(not(feature = "xla"))]
fn nos() {
    section("§6.2/§6.3 — in-place drop and NOS recovery at small scale");
    println!("  [skip] built without the `xla` feature (PJRT runtime unavailable)");
}

#[cfg(feature = "xla")]
fn fig12() {
    section("Fig 12 — teacher/student feature similarity (NOS vs in-place)");
    let Some(dir) = artifacts() else { return };
    // A short pipeline run is enough to show the separation.
    match fuseconv::runtime::pipeline::run_nos_pipeline(
        dir.to_str().unwrap(),
        40,
        0.06,
        23,
        128,
        false,
    ) {
        Ok(r) => {
            println!(
                "  feature cosine similarity to teacher: in-place {:.3} vs NOS {:.3}",
                r.feature_sim_inplace, r.feature_sim_nos
            );
            println!("  (paper: NOS feature maps match the teacher, in-place ones do not)");
            write_csv(
                "fig12.csv",
                &format!(
                    "variant,similarity\nin-place,{:.4}\nnos,{:.4}\n",
                    r.feature_sim_inplace, r.feature_sim_nos
                ),
            );
        }
        Err(e) => println!("  [error] {e:#}"),
    }
}

#[cfg(feature = "xla")]
fn nos() {
    section("§6.2/§6.3 — in-place drop and NOS recovery at small scale");
    let Some(dir) = artifacts() else { return };
    // 150 steps/phase: the NOS fine-tuning needs the full budget to beat
    // in-place training (see EXPERIMENTS.md E12); shorter runs under-train
    // the scaffold and invert the ordering.
    match fuseconv::runtime::pipeline::run_nos_pipeline(
        dir.to_str().unwrap(),
        150,
        0.06,
        17,
        256,
        false,
    ) {
        Ok(r) => {
            println!(
                "  teacher {:.3}  in-place {:.3}  NOS {:.3}  -> recovery {:.0}%",
                r.teacher_acc,
                r.inplace_acc,
                r.nos_acc,
                100.0 * r.nos_recovery()
            );
            write_csv(
                "nos_small_scale.csv",
                &format!(
                    "variant,acc\nteacher,{:.4}\ninplace,{:.4}\nnos,{:.4}\n",
                    r.teacher_acc, r.inplace_acc, r.nos_acc
                ),
            );
        }
        Err(e) => println!("  [error] {e:#}"),
    }
}
