//! L3 performance microbenchmarks (§Perf instrument in EXPERIMENTS.md):
//! simulator layer/network throughput, hybrid-space evaluation rate, EA
//! and NAS end-to-end timing, batcher overhead.
//!
//! Run: `cargo bench --bench sim_micro`

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::{section, selected, selectors, time_it};
use fuseconv::coordinator::search::{run_ea, AccuracyPredictor, EaConfig, TrainMethod};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, Variant};
use fuseconv::rng::Rng;
use fuseconv::sim::{simulate_layer, simulate_network, SimConfig};

fn main() {
    let sel = selectors();
    if selected(&sel, "layers") {
        layer_throughput();
    }
    if selected(&sel, "networks") {
        network_throughput();
    }
    if selected(&sel, "hybrid") {
        hybrid_eval_rate();
    }
    if selected(&sel, "ea") {
        ea_end_to_end();
    }
    if selected(&sel, "batcher") {
        batcher_overhead();
    }
}

fn layer_throughput() {
    section("simulator: single-layer simulation cost");
    let cfg = SimConfig::default();
    let net = models::by_name("mobilenet-v3-large").unwrap();
    // representative layers: big dw, big pw, fuse pair
    let dw = net
        .layers
        .iter()
        .find(|l| matches!(l.class(), fuseconv::nn::OpClass::Depthwise))
        .unwrap();
    let pw = net
        .layers
        .iter()
        .find(|l| matches!(l.class(), fuseconv::nn::OpClass::Pointwise))
        .unwrap();
    let fused = fuse_all(&net, Variant::Half);
    let fu = fused
        .layers
        .iter()
        .find(|l| matches!(l.class(), fuseconv::nn::OpClass::FuSe))
        .unwrap();
    for (label, layer) in [("depthwise", dw), ("pointwise", pw), ("fuse-row", fu)] {
        let t = time_it(3, 30, || {
            std::hint::black_box(simulate_layer(layer, &cfg));
        });
        t.report(&format!("simulate_layer({label})"));
    }
}

fn network_throughput() {
    section("simulator: whole-network simulation cost");
    let cfg = SimConfig::default();
    for name in ["mobilenet-v2", "mobilenet-v3-large", "efficientnet-edgetpu-s"] {
        let net = models::by_name(name).unwrap();
        let t = time_it(2, 15, || {
            std::hint::black_box(simulate_network(&net, &cfg));
        });
        t.report(&format!("simulate_network({name})"));
    }
    // larger array sizes scale the fold counts
    let net = models::by_name("mobilenet-v2").unwrap();
    for size in [8usize, 64] {
        let cfg = SimConfig::with_size(size);
        let t = time_it(2, 10, || {
            std::hint::black_box(simulate_network(&net, &cfg));
        });
        t.report(&format!("simulate_network(mbv2, {size}x{size})"));
    }
}

fn hybrid_eval_rate() {
    section("coordinator: hybrid-space genome evaluation rate");
    let ev = Evaluator::new(SimConfig::default());
    let base = models::by_name("mobilenet-v3-large").unwrap();

    let t = time_it(1, 5, || {
        std::hint::black_box(HybridSpace::new(&base, &ev));
    });
    t.report("HybridSpace::new (pre-factorization, cached evaluator)");

    let space = HybridSpace::new(&base, &ev);
    let n = space.num_blocks();
    let mut rng = Rng::new(1);
    let masks: Vec<Vec<bool>> =
        (0..10_000).map(|_| (0..n).map(|_| rng.chance(0.5)).collect()).collect();
    let t = time_it(2, 10, || {
        let mut acc = 0u64;
        for m in &masks {
            acc = acc.wrapping_add(space.cycles(m));
        }
        std::hint::black_box(acc);
    });
    println!(
        "  [rate] {:.1} M genome evals/s",
        10_000.0 / t.p50() / 1e6
    );
    t.report("10k mask evaluations");
}

fn ea_end_to_end() {
    section("coordinator: EA / search end-to-end");
    let ev = Evaluator::new(SimConfig::default());
    let base = models::by_name("mobilenet-v3-large").unwrap();
    let space = HybridSpace::new(&base, &ev);
    let pred = AccuracyPredictor::for_space(&space);
    let cfg = EaConfig { population: 100, iterations: 100, seed: 1, ..EaConfig::default() };
    let t = time_it(1, 5, || {
        std::hint::black_box(run_ea(&space, &pred, TrainMethod::Nos, &cfg));
    });
    t.report("run_ea(pop=100, iters=100)");
}

fn batcher_overhead() {
    section("coordinator: serving path overhead (mock engine)");
    use fuseconv::coordinator::batcher::{BatchPolicy, Batcher};
    use std::time::Instant;
    let mut b: Batcher<u64> = Batcher::new(BatchPolicy::default());
    let t = time_it(2, 20, || {
        for i in 0..10_000u64 {
            b.push(i);
            if b.len() >= 8 {
                std::hint::black_box(b.take_batch());
            }
        }
        while !b.is_empty() {
            std::hint::black_box(b.take_batch());
        }
        std::hint::black_box(b.ready(Instant::now()));
    });
    println!("  [rate] {:.1} M requests/s through the batcher", 10_000.0 / t.p50() / 1e6);
    t.report("10k push+batch cycles");
}
