//! # fuseconv
//!
//! A production-grade reproduction of *"Design and Scaffolded Training of an
//! Efficient DNN Operator for Computer Vision on the Edge"* (Ganesan & Kumar,
//! 2021): the **FuSeConv** operator, the **ST-OS** systolic-array dataflow,
//! and **NOS** scaffolded training — as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! * [`sim`] — cycle-level systolic-array simulator (SCALE-Sim-FuSe rebuilt).
//! * [`nn`] — network IR + model zoo + the FuSe transform.
//! * [`coordinator`] — network evaluation, EA / OFA-NAS search, serving.
//! * [`vlsi`] — ST-OS area/power overhead model (Table 2).
//! * [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas
//!   artifacts (training + inference drivers).
//! * [`cli`], [`exec`], [`rng`], [`stats`], [`testkit`] — in-repo substrates
//!   for the offline build environment.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod exec;
pub mod nn;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod testkit;
pub mod vlsi;
