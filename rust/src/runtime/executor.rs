//! PJRT execution of the AOT artifacts: load HLO text, compile once per
//! graph on the CPU client, execute from the Rust hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute` → unwrap the result tuple.

use super::manifest::{DType, GraphSpec, Manifest, TensorSpec};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// The runtime: one PJRT client + the artifact manifest + compiled graphs.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    compiled: Mutex<HashMap<String, std::sync::Arc<Graph>>>,
}

/// One compiled executable with its I/O contract.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    pub spec: GraphSpec,
}

impl Runtime {
    /// Open the artifacts directory (does not compile anything yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, dir, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) graph by manifest name.
    pub fn graph(&self, name: &str) -> Result<std::sync::Arc<Graph>> {
        if let Some(g) = self.compiled.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let spec = self.manifest.graph(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling graph {name}"))?;
        let g = std::sync::Arc::new(Graph { exe, spec });
        self.compiled.lock().unwrap().insert(name.to_string(), g.clone());
        Ok(g)
    }

    /// Read a raw f32 init blob, split per the named param block's specs.
    pub fn load_init(&self, label: &str, file: &str) -> Result<Vec<xla::Literal>> {
        let specs = self.manifest.param_specs(label)?;
        let bytes = std::fs::read(self.dir.join(file))
            .with_context(|| format!("reading init blob {file}"))?;
        let total: usize = specs.iter().map(|s| s.elements()).sum();
        if bytes.len() != 4 * total {
            bail!("init blob {file}: {} bytes, expected {}", bytes.len(), 4 * total);
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(specs.len());
        let mut off = 0;
        for s in specs {
            let n = s.elements();
            out.push(literal_f32(&floats[off..off + n], &s.dims)?);
            off += n;
        }
        Ok(out)
    }
}

impl Graph {
    /// Execute with positional inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run_generic(inputs)
    }

    /// Borrowing variant — the §Perf hot path. `execute` only needs
    /// `Borrow<Literal>`, so callers that reuse large parameter sets
    /// (training loops, eval chunks, the serving engine) pass references
    /// instead of deep-copying literals every call.
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run_generic(inputs)
    }

    fn run_generic<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "graph {}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let result = self.exe.execute::<L>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = result.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "graph {}: got {} outputs, expected {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// Build an f32 literal of the given dims (empty = scalar).
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    if dims.is_empty() {
        if data.len() != 1 {
            bail!("scalar literal from {} values", data.len());
        }
        return Ok(xla::Literal::scalar(data[0]));
    }
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_f32: {} values for dims {:?}", data.len(), dims);
    }
    let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&idims)?)
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal_i32: {} values for dims {:?}", data.len(), dims);
    }
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&idims)?)
}

/// Deep-copy a literal (xla::Literal is not Clone; round-trip raw values).
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match l.ty()? {
        xla::ElementType::F32 => literal_f32(&l.to_vec::<f32>()?, &dims),
        xla::ElementType::S32 => literal_i32(&l.to_vec::<i32>()?, &dims),
        other => bail!("clone_literal: unsupported type {other:?}"),
    }
}

/// Deep-copy a parameter set.
pub fn clone_params(ps: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    ps.iter().map(clone_literal).collect()
}

/// Validate a literal against a manifest TensorSpec (element count level).
pub fn check_spec(lit: &xla::Literal, spec: &TensorSpec) -> Result<()> {
    let want = spec.elements();
    if lit.element_count() != want {
        bail!("literal has {} elements, spec wants {want}", lit.element_count());
    }
    let _ = match spec.dtype {
        DType::F32 | DType::I32 => (),
    };
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn literal_builders() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let s = literal_f32(&[7.5], &[]).unwrap();
        assert_eq!(s.get_first_element::<f32>().unwrap(), 7.5);
        let i = literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(literal_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn open_and_compile_infer_graph() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        let g = rt.graph("student_infer").unwrap();
        // compile cache: second fetch is the same Arc
        let g2 = rt.graph("student_infer").unwrap();
        assert!(std::sync::Arc::ptr_eq(&g, &g2));
    }

    #[test]
    fn infer_runs_end_to_end() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let params = rt.load_init("student", "student_init.bin").unwrap();
        let g = rt.graph("student_infer").unwrap();
        let b = rt.manifest.const_usize("infer_batch").unwrap();
        let hw = rt.manifest.const_usize("image_hw").unwrap();
        let x = literal_f32(&vec![0.1; b * 3 * hw * hw], &[b, 3, hw, hw]).unwrap();
        let mut inputs = params;
        inputs.push(x);
        let out = g.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        let logits = out[0].to_vec::<f32>().unwrap();
        assert_eq!(logits.len(), b * rt.manifest.const_usize("num_classes").unwrap());
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn wrong_arity_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let g = rt.graph("student_infer").unwrap();
        assert!(g.run(&[]).is_err());
    }
}
