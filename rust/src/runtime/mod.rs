//! Runtime: PJRT loading/execution of the AOT artifacts, synthetic data,
//! training/eval drivers, and the serving engine. After `make artifacts`,
//! everything here is Python-free.

pub mod data;
pub mod manifest;

// The PJRT execution path needs the external `xla` bindings crate, which is
// unavailable in the offline build environment; it compiles only under
// `--features xla`. Everything else (synthetic data, the artifact manifest,
// the simulator-backed serving path) stays in the default build.
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod executor;
#[cfg(feature = "xla")]
pub mod pipeline;
#[cfg(feature = "xla")]
pub mod training;

pub use data::Synth;
pub use manifest::Manifest;

#[cfg(feature = "xla")]
pub use engine::PjrtEngine;
#[cfg(feature = "xla")]
pub use executor::{literal_f32, literal_i32, Graph, Runtime};
#[cfg(feature = "xla")]
pub use training::{cosine_lr, Session, TrainLog};

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when `make artifacts` has produced a manifest.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}
