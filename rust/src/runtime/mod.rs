//! Runtime: PJRT loading/execution of the AOT artifacts, synthetic data,
//! training/eval drivers, and the serving engine. After `make artifacts`,
//! everything here is Python-free.

pub mod data;
pub mod engine;
pub mod executor;
pub mod manifest;
pub mod pipeline;
pub mod training;

pub use data::Synth;
pub use engine::PjrtEngine;
pub use executor::{literal_f32, literal_i32, Graph, Runtime};
pub use manifest::Manifest;
pub use training::{cosine_lr, Session, TrainLog};

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when `make artifacts` has produced a manifest.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}
