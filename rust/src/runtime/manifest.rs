//! Parser for `artifacts/manifest.txt`, the contract between the Python
//! compile path (aot.py) and this runtime. Line-oriented format:
//!
//! ```text
//! const <key> <value>
//! params <label> <count>
//!   p <name> <d0>x<d1>...
//! graph <name> <filename>
//!   in  <dtype> <dims|scalar>
//!   out <dtype> <dims|scalar>
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub dtype: DType,
    /// Empty = scalar.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub consts: BTreeMap<String, String>,
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub graphs: BTreeMap<String, GraphSpec>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
        .collect()
}

fn parse_dtype(s: &str) -> Result<DType> {
    match s {
        "f32" => Ok(DType::F32),
        "i32" => Ok(DType::I32),
        other => bail!("unsupported dtype {other}"),
    }
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur_graph: Option<String> = None;
        let mut cur_params: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {raw:?}", lineno + 1);
            match toks[0] {
                "const" => {
                    if toks.len() != 3 {
                        bail!("{}: const needs 2 fields", ctx());
                    }
                    m.consts.insert(toks[1].into(), toks[2].into());
                }
                "params" => {
                    cur_params = Some(toks[1].to_string());
                    cur_graph = None;
                    m.params.insert(toks[1].into(), Vec::new());
                }
                "p" => {
                    let label = cur_params.clone().with_context(ctx)?;
                    m.params.get_mut(&label).unwrap().push(ParamSpec {
                        name: toks[1].into(),
                        dims: parse_dims(toks[2]).with_context(ctx)?,
                    });
                }
                "graph" => {
                    cur_graph = Some(toks[1].to_string());
                    cur_params = None;
                    m.graphs.insert(
                        toks[1].into(),
                        GraphSpec {
                            name: toks[1].into(),
                            file: toks[2].into(),
                            inputs: Vec::new(),
                            outputs: Vec::new(),
                        },
                    );
                }
                "in" | "out" => {
                    let g = cur_graph.clone().with_context(ctx)?;
                    let spec = TensorSpec {
                        dtype: parse_dtype(toks[1]).with_context(ctx)?,
                        dims: parse_dims(toks[2]).with_context(ctx)?,
                    };
                    let graph = m.graphs.get_mut(&g).unwrap();
                    if toks[0] == "in" {
                        graph.inputs.push(spec);
                    } else {
                        graph.outputs.push(spec);
                    }
                }
                other => bail!("{}: unknown directive {other}", ctx()),
            }
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn const_usize(&self, key: &str) -> Result<usize> {
        self.consts
            .get(key)
            .ok_or_else(|| anyhow!("missing const {key}"))?
            .parse()
            .map_err(|e| anyhow!("const {key}: {e}"))
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs.get(name).ok_or_else(|| anyhow!("missing graph {name}"))
    }

    pub fn param_specs(&self, label: &str) -> Result<&Vec<ParamSpec>> {
        self.params.get(label).ok_or_else(|| anyhow!("missing params {label}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
const image_hw 32
const num_classes 10
params teacher 2
  p stem.w 16x3x3x3
  p fc.b 10
graph infer infer.hlo.txt
  in f32 8x3x32x32
  in f32 scalar
  out f32 8x10
graph step step.hlo.txt
  in i32 16
  out f32 scalar
";

    #[test]
    fn parses_consts_params_graphs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.const_usize("image_hw").unwrap(), 32);
        let ps = m.param_specs("teacher").unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].dims, vec![16, 3, 3, 3]);
        assert_eq!(ps[0].elements(), 432);
        let g = m.graph("infer").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[1].dims, Vec::<usize>::new());
        assert_eq!(g.outputs[0].dims, vec![8, 10]);
        assert_eq!(m.graph("step").unwrap().inputs[0].dtype, DType::I32);
    }

    #[test]
    fn scalar_spec_has_one_element() {
        let t = TensorSpec { dtype: DType::F32, dims: vec![] };
        assert_eq!(t.elements(), 1);
    }

    #[test]
    fn unknown_directive_rejected() {
        assert!(Manifest::parse("bogus x y").is_err());
    }

    #[test]
    fn missing_lookups_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.graph("nope").is_err());
        assert!(m.const_usize("nope").is_err());
        assert!(m.param_specs("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.graphs.contains_key("student_infer"));
            assert!(m.graphs.contains_key("nos_train_step"));
            let nt = m.const_usize("num_teacher_params").unwrap();
            assert_eq!(m.param_specs("teacher").unwrap().len(), nt);
        }
    }
}
