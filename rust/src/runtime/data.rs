//! Synthetic classification corpus (DESIGN.md substitution #1).
//!
//! Class-conditioned multi-orientation sinusoid textures ("Gabor-ish"):
//! each class k has a characteristic (frequency, orientation, phase,
//! channel-mix) tuple, plus additive noise. The task is learnable by a
//! small CNN but not trivial: classes share frequency bands and differ in
//! orientation/phase, so spatial operators (depthwise vs FuSe) matter —
//! exactly the regime where the in-place accuracy drop and the NOS
//! recovery are visible at small scale.

use crate::rng::Rng;

pub const CHANNELS: usize = 3;

/// Deterministic dataset generator.
pub struct Synth {
    pub hw: usize,
    pub num_classes: usize,
    rng: Rng,
}

impl Synth {
    pub fn new(hw: usize, num_classes: usize, seed: u64) -> Synth {
        Synth { hw, num_classes, rng: Rng::new(seed) }
    }

    /// Class-k texture parameters (fixed per class).
    fn class_params(&self, k: usize) -> (f32, f32, f32) {
        // frequency in [0.25, 0.9], orientation in [0, π), phase offset
        let kf = k as f32;
        let n = self.num_classes as f32;
        let freq = 0.25 + 0.65 * ((kf * 2.0 + 1.0) % n) / n;
        let theta = std::f32::consts::PI * kf / n;
        let phase = 2.0 * std::f32::consts::PI * ((kf * 3.0 + 0.5) % n) / n;
        (freq, theta, phase)
    }

    /// One sample of class `k` into `out` (len 3·hw·hw), NCHW layout.
    fn sample_into(&mut self, k: usize, out: &mut [f32]) {
        let hw = self.hw;
        let (freq, theta, phase) = self.class_params(k);
        let (s, c) = theta.sin_cos();
        for ch in 0..CHANNELS {
            // per-channel modulation distinguishes classes with similar
            // orientation
            let chm = 1.0 + 0.35 * (ch as f32 - 1.0) * ((k % 3) as f32 - 1.0);
            for i in 0..hw {
                for j in 0..hw {
                    let u = (i as f32 * c + j as f32 * s) * freq * chm;
                    let v = (u + phase).sin();
                    let noise = (self.rng.normal() as f32) * 0.25;
                    out[ch * hw * hw + i * hw + j] = v + noise;
                }
            }
        }
    }

    /// Generate a batch: (images NCHW flat, labels).
    pub fn batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let n = CHANNELS * self.hw * self.hw;
        let mut xs = vec![0.0f32; b * n];
        let mut ys = Vec::with_capacity(b);
        for i in 0..b {
            let k = self.rng.below(self.num_classes);
            self.sample_into(k, &mut xs[i * n..(i + 1) * n]);
            ys.push(k as i32);
        }
        (xs, ys)
    }

    /// A held-out evaluation set (fresh rng stream, fixed seed).
    pub fn eval(hw: usize, num_classes: usize, count: usize) -> (Vec<f32>, Vec<i32>) {
        let mut s = Synth::new(hw, num_classes, EVAL_SEED);
        s.batch(count)
    }
}

/// Seed of the held-out evaluation stream.
pub const EVAL_SEED: u64 = 0xE7A1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Synth::new(16, 10, 1);
        let mut b = Synth::new(16, 10, 1);
        let (xa, ya) = a.batch(4);
        let (xb, yb) = b.batch(4);
        assert_eq!(ya, yb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn shapes_and_ranges() {
        let mut s = Synth::new(32, 10, 2);
        let (x, y) = s.batch(8);
        assert_eq!(x.len(), 8 * 3 * 32 * 32);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
        // bounded signal + noise
        assert!(x.iter().all(|v| v.is_finite() && v.abs() < 4.0));
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean absolute pixel difference between class templates should be
        // well above the noise floor for at least some class pairs
        let mut s = Synth::new(16, 10, 3);
        let n = 3 * 16 * 16;
        let mut tmpl = vec![vec![0.0f32; n]; 10];
        let reps = 24;
        for k in 0..10 {
            let mut acc = vec![0.0f32; n];
            for _ in 0..reps {
                let mut buf = vec![0.0f32; n];
                s.sample_into(k, &mut buf);
                for (a, b) in acc.iter_mut().zip(&buf) {
                    *a += b / reps as f32;
                }
            }
            tmpl[k] = acc;
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
        };
        let d01 = dist(&tmpl[0], &tmpl[5]);
        assert!(d01 > 0.2, "templates too similar: {d01}");
    }

    #[test]
    fn labels_cover_all_classes() {
        let mut s = Synth::new(8, 10, 4);
        let (_, y) = s.batch(400);
        let mut seen = [false; 10];
        for l in y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn eval_set_fixed() {
        let (xa, ya) = Synth::eval(16, 10, 32);
        let (xb, yb) = Synth::eval(16, 10, 32);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }
}
