//! The end-to-end NOS pipeline (paper §6.2–6.3 at small scale):
//!
//! 1. train the depthwise **teacher** from scratch (CE);
//! 2. train the FuSe student **in-place** from scratch (CE) — the paper's
//!    naive replacement, expected to land below the teacher;
//! 3. build the **scaffold** from the trained teacher (identity adapters)
//!    and train with NOS (operator sampling + KD);
//! 4. **collapse** the scaffold into pure FuSe weights;
//! 5. evaluate all three on the held-out set and measure teacher↔student
//!    feature-map similarity (the Fig 12 quantity) for both students.
//!
//! Everything runs through the AOT-compiled graphs — no Python.

use super::executor::{clone_params, Runtime};
use super::training::{Session, TrainLog};
use anyhow::Result;

/// Pipeline outcome (accuracies in [0,1]).
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub teacher_acc: f64,
    pub inplace_acc: f64,
    pub nos_acc: f64,
    pub feature_sim_inplace: f64,
    pub feature_sim_nos: f64,
    pub teacher_log: TrainLog,
    pub inplace_log: TrainLog,
    pub nos_log: TrainLog,
}

impl PipelineResult {
    /// The paper's §6.3 claim restated for this run: NOS recovers part of
    /// the in-place drop.
    pub fn nos_recovery(&self) -> f64 {
        let drop = self.teacher_acc - self.inplace_acc;
        if drop.abs() < 1e-9 {
            return 1.0;
        }
        (self.nos_acc - self.inplace_acc) / drop
    }
}

/// Run the full pipeline. `steps` applies to each of the three phases.
pub fn run_nos_pipeline(
    artifacts: &str,
    steps: usize,
    lr0: f32,
    seed: u64,
    eval_samples: usize,
    verbose: bool,
) -> Result<PipelineResult> {
    let rt = Runtime::open(artifacts)?;
    let session = Session::new(&rt)?;
    let say = |s: &str| {
        if verbose {
            println!("{s}");
        }
    };

    let nt = rt.manifest.const_usize("num_teacher_params")?;
    let ns = rt.manifest.const_usize("num_student_params")?;
    let nsc = rt.manifest.const_usize("num_scaffold_params")?;
    let blocks = rt.manifest.const_usize("num_blocks")?;
    let k = rt.manifest.const_usize("ksize")?;

    // Phase 1: teacher.
    say(&format!("[1/5] training depthwise teacher ({steps} steps)"));
    let g = rt.graph("teacher_train_step")?;
    let init = rt.load_init("teacher", "teacher_init.bin")?;
    let (teacher_params, teacher_log) =
        session.train_plain(&g, nt, init, steps, lr0, seed)?;

    // Phase 2: in-place student.
    say(&format!("[2/5] training FuSe student in-place ({steps} steps)"));
    let g = rt.graph("student_train_step")?;
    let init = rt.load_init("student", "student_init.bin")?;
    let (inplace_params, inplace_log) =
        session.train_plain(&g, ns, init, steps, lr0, seed ^ 1)?;

    // Phase 3: NOS.
    say(&format!("[3/5] NOS scaffolded training ({steps} steps)"));
    let g = rt.graph("nos_train_step")?;
    let scaffold0 = session.scaffold_init(&teacher_params, blocks, k)?;
    let (scaffold, nos_log) = session.train_nos(
        &g,
        nsc,
        nt,
        blocks,
        scaffold0,
        &teacher_params,
        steps,
        lr0,
        seed ^ 2,
        0.75, // bias sampling toward the (all-FuSe) inference network
    )?;

    // Phase 4: collapse.
    say("[4/5] collapsing scaffold -> FuSe weights");
    let g = rt.graph("collapse")?;
    let nos_params = g.run(&scaffold)?;
    anyhow::ensure!(nos_params.len() == ns, "collapse arity");

    // Phase 5: evaluation.
    say(&format!("[5/5] evaluating on {eval_samples} held-out samples"));
    let teacher_infer = rt.graph("teacher_infer")?;
    let student_infer = rt.graph("student_infer")?;
    let teacher_acc = session.eval_accuracy(&teacher_infer, &teacher_params, eval_samples)?;
    let inplace_acc = session.eval_accuracy(&student_infer, &inplace_params, eval_samples)?;
    let nos_acc = session.eval_accuracy(&student_infer, &nos_params, eval_samples)?;

    let ft = rt.graph("feature_teacher")?;
    let fs = rt.graph("feature_student")?;
    let feature_sim_inplace =
        session.feature_similarity(&ft, &teacher_params, &fs, &inplace_params)?;
    let feature_sim_nos =
        session.feature_similarity(&ft, &teacher_params, &fs, &clone_params(&nos_params)?)?;

    let result = PipelineResult {
        teacher_acc,
        inplace_acc,
        nos_acc,
        feature_sim_inplace,
        feature_sim_nos,
        teacher_log,
        inplace_log,
        nos_log,
    };
    if verbose {
        println!("\n=== NOS pipeline results ===");
        println!("teacher (depthwise)   acc {:.3}", result.teacher_acc);
        println!("student in-place      acc {:.3}", result.inplace_acc);
        println!("student NOS           acc {:.3}", result.nos_acc);
        println!(
            "feature similarity: in-place {:.3}  NOS {:.3}  (Fig 12: NOS >> in-place)",
            result.feature_sim_inplace, result.feature_sim_nos
        );
        println!("NOS recovery of the in-place drop: {:.0}%", 100.0 * result.nos_recovery());
    }
    Ok(result)
}
