//! The PJRT-backed serving engine: wraps the compiled `student_infer`
//! graph + a parameter set behind the coordinator's [`Engine`] trait so
//! the dynamic batcher can drive it (examples/serve.rs).
//!
//! PJRT objects are thread-bound (the xla crate's client is `Rc`-based),
//! so the engine — including its `Runtime` — is built inside the server's
//! dispatcher thread via [`Server::start_with`].

use super::executor::{literal_f32, Graph, Runtime};
use crate::coordinator::server::Engine;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

pub struct PjrtEngine {
    // Runtime kept alive for the graph's client.
    _rt: Runtime,
    graph: Arc<Graph>,
    params: Vec<xla::Literal>,
    in_len: usize,
    out_len: usize,
    batch: usize,
    hw: usize,
}

impl PjrtEngine {
    /// Open artifacts + compile the student inference graph with the given
    /// parameter blob (e.g. `student_init.bin` or a trained checkpoint).
    pub fn from_artifacts(dir: impl AsRef<Path>, params_blob: &str) -> Result<PjrtEngine> {
        let rt = Runtime::open(dir)?;
        let params = rt.load_init("student", params_blob)?;
        PjrtEngine::new(rt, params)
    }

    pub fn new(rt: Runtime, params: Vec<xla::Literal>) -> Result<PjrtEngine> {
        let graph = rt.graph("student_infer")?;
        let hw = rt.manifest.const_usize("image_hw")?;
        let classes = rt.manifest.const_usize("num_classes")?;
        let batch = rt.manifest.const_usize("infer_batch")?;
        Ok(PjrtEngine {
            _rt: rt,
            graph,
            params,
            in_len: 3 * hw * hw,
            out_len: classes,
            batch,
            hw,
        })
    }
}

impl Engine for PjrtEngine {
    fn input_len(&self) -> usize {
        self.in_len
    }

    fn output_len(&self) -> usize {
        self.out_len
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer(&self, inputs: &[f32], n: usize) -> Vec<f32> {
        assert!(n <= self.batch);
        // pad the partial batch up to the compiled batch size
        let mut padded = vec![0.0f32; self.batch * self.in_len];
        padded[..n * self.in_len].copy_from_slice(inputs);
        let x = literal_f32(&padded, &[self.batch, 3, self.hw, self.hw]).expect("batch literal");
        // §Perf: borrow the resident parameter set; only the batch literal
        // is constructed per request.
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&x);
        let out = self.graph.run_refs(&args).expect("infer");
        let logits = out[0].to_vec::<f32>().expect("logits");
        logits[..n * self.out_len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::server::Server;
    use std::path::PathBuf;
    use std::time::Duration;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn serves_through_batcher_e2e() {
        if !artifacts_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let dir = artifacts_dir();
        let server = Server::start_with(
            move || PjrtEngine::from_artifacts(&dir, "student_init.bin").unwrap(),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        // probe the engine's geometry from the manifest directly
        let m = crate::runtime::Manifest::load(&artifacts_dir()).unwrap();
        let hw = m.const_usize("image_hw").unwrap();
        let in_len = 3 * hw * hw;
        let out_len = m.const_usize("num_classes").unwrap();
        let tickets: Vec<_> = (0..12).map(|_| server.submit(vec![0.05; in_len])).collect();
        for t in tickets {
            match t.wait_deadline(Duration::from_secs(120)).result {
                Ok(crate::coordinator::Reply::Infer(r)) => {
                    assert_eq!(r.output.len(), out_len);
                    assert!(r.output.iter().all(|v| v.is_finite()));
                }
                other => panic!("expected infer reply, got {other:?}"),
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 12);
        assert!(stats.mean_batch() >= 1.0);
    }
}
