//! Training drivers: the Rust loop that owns the optimizer state and feeds
//! the AOT-compiled train-step graphs. This is the e2e evidence path for
//! the paper's §6.2/§6.3 claims at small scale (in-place replacement vs
//! NOS), and nothing here touches Python.

use super::data::Synth;
use super::executor::{literal_f32, literal_i32, Graph, Runtime};
use crate::rng::Rng;
use anyhow::{Context, Result};

/// Per-step record: (step, loss, train-batch accuracy).
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub entries: Vec<(usize, f32, f32)>,
}

impl TrainLog {
    pub fn last_loss(&self) -> f32 {
        self.entries.last().map(|e| e.1).unwrap_or(f32::NAN)
    }

    /// Mean loss over the first/last `k` entries (loss-curve trend).
    pub fn head_tail_mean(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.entries.len());
        let head: f32 = self.entries[..k].iter().map(|e| e.1).sum::<f32>() / k as f32;
        let tail: f32 =
            self.entries[self.entries.len() - k..].iter().map(|e| e.1).sum::<f32>() / k as f32;
        (head, tail)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,acc\n");
        for (st, l, a) in &self.entries {
            s.push_str(&format!("{st},{l},{a}\n"));
        }
        s
    }
}

/// Cosine learning-rate schedule (paper §5.3.2 uses cosine for NOS).
pub fn cosine_lr(lr0: f32, step: usize, total: usize) -> f32 {
    let t = step as f32 / total.max(1) as f32;
    lr0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
}

/// Shared bits of a training session against one train-step graph.
pub struct Session<'a> {
    pub rt: &'a Runtime,
    pub hw: usize,
    pub classes: usize,
    pub train_b: usize,
}

impl<'a> Session<'a> {
    pub fn new(rt: &'a Runtime) -> Result<Session<'a>> {
        Ok(Session {
            rt,
            hw: rt.manifest.const_usize("image_hw")?,
            classes: rt.manifest.const_usize("num_classes")?,
            train_b: rt.manifest.const_usize("train_batch")?,
        })
    }

    fn batch_literals(&self, synth: &mut Synth) -> Result<(xla::Literal, xla::Literal)> {
        let (xs, ys) = synth.batch(self.train_b);
        Ok((
            literal_f32(&xs, &[self.train_b, 3, self.hw, self.hw])?,
            literal_i32(&ys, &[self.train_b])?,
        ))
    }

    /// Train a plain (teacher or in-place student) network.
    ///
    /// `graph` must follow the plain-step contract:
    /// (params…, vel…, x, y, lr) → (params…, vel…, loss, acc).
    pub fn train_plain(
        &self,
        graph: &Graph,
        n_params: usize,
        mut params: Vec<xla::Literal>,
        steps: usize,
        lr0: f32,
        data_seed: u64,
    ) -> Result<(Vec<xla::Literal>, TrainLog)> {
        let mut synth = Synth::new(self.hw, self.classes, data_seed);
        let mut vel: Vec<xla::Literal> = params
            .iter()
            .map(|p| zeros_like(p))
            .collect::<Result<Vec<_>>>()?;
        let mut log = TrainLog::default();
        for step in 0..steps {
            let (x, y) = self.batch_literals(&mut synth)?;
            let lr = literal_f32(&[cosine_lr(lr0, step, steps)], &[])?;
            // borrow everything: no literal copies on the step hot path
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 * n_params + 3);
            inputs.extend(params.iter());
            inputs.extend(vel.iter());
            inputs.push(&x);
            inputs.push(&y);
            inputs.push(&lr);
            let mut out = graph.run_refs(&inputs).context("train step")?;
            drop(inputs);
            let acc = out.pop().unwrap().get_first_element::<f32>()?;
            let loss = out.pop().unwrap().get_first_element::<f32>()?;
            vel = out.split_off(n_params);
            params = out;
            log.entries.push((step, loss, acc));
        }
        Ok((params, log))
    }

    /// NOS scaffolded training (paper §4.1): per step, each block is
    /// sampled depthwise (0) or FuSe (1); loss = CE + KD on frozen-teacher
    /// logits.
    #[allow(clippy::too_many_arguments)]
    pub fn train_nos(
        &self,
        graph: &Graph,
        n_scaffold: usize,
        n_teacher: usize,
        num_blocks: usize,
        mut scaffold: Vec<xla::Literal>,
        teacher: &[xla::Literal],
        steps: usize,
        lr0: f32,
        seed: u64,
        fuse_prob: f64,
    ) -> Result<(Vec<xla::Literal>, TrainLog)> {
        let mut synth = Synth::new(self.hw, self.classes, seed);
        let mut mask_rng = Rng::new(seed ^ 0x5ca_f01d);
        let mut vel: Vec<xla::Literal> =
            scaffold.iter().map(|p| zeros_like(p)).collect::<Result<Vec<_>>>()?;
        let mut log = TrainLog::default();
        for step in 0..steps {
            let (x, y) = self.batch_literals(&mut synth)?;
            // OFA-style operator sampling. The inference network is
            // all-FuSe, so sampling is biased toward the student path
            // (`fuse_prob`); the depthwise path still appears often enough
            // to keep distilling teacher structure.
            let mask: Vec<f32> = (0..num_blocks)
                .map(|_| if mask_rng.chance(fuse_prob) { 1.0 } else { 0.0 })
                .collect();
            let mask_l = literal_f32(&mask, &[num_blocks])?;
            let lr = literal_f32(&[cosine_lr(lr0, step, steps)], &[])?;
            // frozen teacher params are *borrowed* every step (§Perf: the
            // previous version deep-copied ~350 kB of literals per step)
            let mut inputs: Vec<&xla::Literal> =
                Vec::with_capacity(2 * n_scaffold + n_teacher + 4);
            inputs.extend(scaffold.iter());
            inputs.extend(vel.iter());
            inputs.extend(teacher.iter());
            inputs.push(&x);
            inputs.push(&y);
            inputs.push(&mask_l);
            inputs.push(&lr);
            let mut out = graph.run_refs(&inputs).context("nos step")?;
            drop(inputs);
            let acc = out.pop().unwrap().get_first_element::<f32>()?;
            let loss = out.pop().unwrap().get_first_element::<f32>()?;
            vel = out.split_off(n_scaffold);
            scaffold = out;
            log.entries.push((step, loss, acc));
        }
        Ok((scaffold, log))
    }

    /// Evaluate accuracy of an infer graph over the held-out set.
    pub fn eval_accuracy(
        &self,
        infer: &Graph,
        params: &[xla::Literal],
        samples: usize,
    ) -> Result<f64> {
        let b = self.rt.manifest.const_usize("infer_batch")?;
        let (xs, ys) = Synth::eval(self.hw, self.classes, samples);
        let n = self.hw * self.hw * 3;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut chunk = 0;
        while (chunk + 1) * b <= samples {
            let lo = chunk * b;
            let x = literal_f32(&xs[lo * n..(lo + b) * n], &[b, 3, self.hw, self.hw])?;
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&x);
            let out = infer.run_refs(&inputs)?;
            let logits = out[0].to_vec::<f32>()?;
            for i in 0..b {
                let row = &logits[i * self.classes..(i + 1) * self.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 == ys[lo + i] {
                    correct += 1;
                }
                total += 1;
            }
            chunk += 1;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Build the scaffold init: trained teacher params + identity adapters.
    pub fn scaffold_init(
        &self,
        teacher: &[xla::Literal],
        num_blocks: usize,
        k: usize,
    ) -> Result<Vec<xla::Literal>> {
        let mut out: Vec<xla::Literal> =
            teacher.iter().map(clone_literal).collect::<Result<Vec<_>>>()?;
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        for _ in 0..num_blocks {
            out.push(literal_f32(&eye, &[k, k])?);
        }
        Ok(out)
    }

    /// Cosine similarity between teacher and student block-feature maps on
    /// one probe image (Fig 12's quantitative counterpart).
    pub fn feature_similarity(
        &self,
        feat_a: &Graph,
        params_a: &[xla::Literal],
        feat_b: &Graph,
        params_b: &[xla::Literal],
    ) -> Result<f64> {
        let (xs, _) = Synth::eval(self.hw, self.classes, 1);
        let x = literal_f32(&xs, &[1, 3, self.hw, self.hw])?;
        let run = |g: &Graph, ps: &[xla::Literal]| -> Result<Vec<f32>> {
            let mut inputs: Vec<&xla::Literal> = ps.iter().collect();
            inputs.push(&x);
            Ok(g.run_refs(&inputs)?[0].to_vec::<f32>()?)
        };
        let a = run(feat_a, params_a)?;
        let b = run(feat_b, params_b)?;
        anyhow::ensure!(a.len() == b.len(), "feature shapes differ");
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        Ok(dot / (na * nb).max(1e-12))
    }
}

pub use super::executor::clone_literal;

fn zeros_like(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n: usize = dims.iter().product::<usize>().max(1);
    literal_f32(&vec![0.0; n], &dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::{Path, PathBuf};

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        if artifacts_dir().join("manifest.txt").exists() {
            Some(Runtime::open(artifacts_dir()).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_lr(0.1, 0, 100) - 0.1).abs() < 1e-7);
        assert!(cosine_lr(0.1, 100, 100) < 1e-7);
        assert!(cosine_lr(0.1, 50, 100) > 0.04 && cosine_lr(0.1, 50, 100) < 0.06);
    }

    #[test]
    fn train_log_trend() {
        let mut log = TrainLog::default();
        for i in 0..10 {
            log.entries.push((i, 10.0 - i as f32, 0.1 * i as f32));
        }
        let (head, tail) = log.head_tail_mean(3);
        assert!(tail < head);
        assert!(log.to_csv().lines().count() == 11);
    }

    #[test]
    fn plain_training_reduces_loss_e2e() {
        let Some(rt) = runtime() else { return };
        let session = Session::new(&rt).unwrap();
        let graph = rt.graph("teacher_train_step").unwrap();
        let n = rt.manifest.const_usize("num_teacher_params").unwrap();
        let init = rt.load_init("teacher", "teacher_init.bin").unwrap();
        let (_params, log) =
            session.train_plain(&graph, n, init, 60, 0.04, 11).unwrap();
        let (head, tail) = log.head_tail_mean(10);
        assert!(
            tail < head - 0.05,
            "loss did not fall: head {head} tail {tail} (last {:?})",
            &log.entries[log.entries.len().saturating_sub(5)..]
        );
    }

    #[test]
    fn clone_literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let c = clone_literal(&l).unwrap();
        assert_eq!(c.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i = literal_i32(&[7, 8], &[2]).unwrap();
        let ci = clone_literal(&i).unwrap();
        assert_eq!(ci.to_vec::<i32>().unwrap(), vec![7, 8]);
    }
}
