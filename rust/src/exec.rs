//! Scoped thread-pool executor (tokio is unavailable offline; the
//! coordinator's parallelism needs — EA population evaluation, batch-sweep
//! simulation, serving workers — are CPU-bound fork/join, so a small
//! work-queue pool over std threads is the right tool anyway).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Fixed-size thread pool executing boxed jobs; `scope_map` provides the
/// fork/join pattern used across the coordinator.
pub struct Pool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl Pool {
    /// `threads == 0` means "number of available CPUs".
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 { available_parallelism() } else { threads };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("fuseconv-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { workers, tx: Some(tx) }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget: enqueue one job. Used by the sim server, where
    /// requests complete out-of-band via their own reply channels rather
    /// than through `scope_map`'s fork/join collection.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("pool send");
    }

    /// Apply `f` to every item, in parallel, preserving order of results.
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.tx
                .as_ref()
                .expect("pool alive")
                .send(Box::new(move || {
                    let r = f(item);
                    // Receiver outlives all jobs within this call; a send
                    // failure would mean scope_map returned early (it can't).
                    let _ = rtx.send((i, r));
                }))
                .expect("pool send");
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("all slots filled")).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Cooperative cancellation flag shared between a long-running job (a
/// sweep grid, a search loop) and whoever can stop it (an explicit
/// `cancel` request, a disconnect-detecting frame sink). Cheap to clone;
/// workers poll [`CancelToken::is_cancelled`] at their natural
/// checkpoints (between grid cells, between generations) and wind down
/// instead of burning pool cycles nobody will read.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<std::sync::atomic::AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the flag. Idempotent; safe from any thread.
    pub fn cancel(&self) {
        self.0.store(true, std::sync::atomic::Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Do these two handles share one flag? (Used by registries that
    /// must remove exactly the entry they inserted.)
    pub fn same(&self, other: &CancelToken) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(4);
        let out = pool.scope_map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn actually_parallel_workers() {
        let pool = Pool::new(3);
        assert_eq!(pool.threads(), 3);
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        let out = pool.scope_map((0..10).collect(), |_x: usize| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            thread::sleep(std::time::Duration::from_millis(1));
            1usize
        });
        assert_eq!(out.iter().sum::<usize>(), 10);
        assert_eq!(COUNT.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn empty_input_ok() {
        let pool = Pool::new(2);
        let out: Vec<i32> = pool.scope_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn zero_means_auto() {
        let pool = Pool::new(0);
        assert!(pool.threads() >= 1);
    }

    #[test]
    fn spawn_fire_and_forget() {
        let pool = Pool::new(2);
        let (tx, rx) = mpsc::channel();
        for i in 0..10u64 {
            let tx = tx.clone();
            pool.spawn(move || tx.send(i * i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn cancel_token_is_shared_and_idempotent() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!t.is_cancelled());
        clone.cancel();
        clone.cancel(); // idempotent
        assert!(t.is_cancelled());
        assert!(t.same(&clone));
        assert!(!t.same(&CancelToken::new()));
    }

    #[test]
    fn pool_reusable_across_calls() {
        let pool = Pool::new(2);
        for round in 0..5 {
            let out = pool.scope_map(vec![round; 8], |x: usize| x + 1);
            assert_eq!(out, vec![round + 1; 8]);
        }
    }
}
