//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so search (EA/NAS),
//! synthetic data generation, and the property-test kit all draw from this
//! xoshiro256++ implementation (Blackman & Vigna). Determinism is a feature:
//! every experiment in EXPERIMENTS.md records its seed and is exactly
//! reproducible.

/// xoshiro256++ PRNG. Not cryptographic; excellent statistical quality and
/// a 2^256-1 period, far beyond anything the simulator or search needs.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds yield uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> double mantissa
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n; // 2^64 mod n
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box-Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(8);
        for _ in 0..200 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
