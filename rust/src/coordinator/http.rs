//! HTTP/1.1 + SSE frontend: the same [`Service`] the TCP frontend
//! serves, reachable from `curl`, dashboards, and anything else that
//! speaks HTTP — zero dependencies, `std` networking only. Runs
//! standalone or alongside the TCP listener on one shared
//! [`Router`](super::server::Router) and [`StopLatch`]
//! (`fuseconv serve --http-port`), and mounts the multi-node
//! [`ShardRouter`](super::shard::ShardRouter) identically
//! (`fuseconv shard --http-port`).
//!
//! Endpoint map (`PROTOCOL.md` §HTTP mapping is the normative spec):
//!
//! | endpoint | traffic |
//! |---|---|
//! | `POST /v1/infer` | one-shot JSON (the reply's terminal frame is the body) |
//! | `POST /v1/simulate` | one-shot JSON |
//! | `POST /v1/sweep` | SSE stream — one `progress`/`row`/`final` event per frame |
//! | `POST /v1/search` | SSE stream — `progress`/`search_row`/`final` events |
//! | `POST /v1/cancel` | one-shot JSON; trips the target stream's cancel token |
//! | `GET /v1/stats` | one-shot JSON |
//! | `GET /v1/zoo` | one-shot JSON |
//! | `GET /healthz` | liveness: `200` while serving, `503` once shutdown latches |
//! | `POST /v1/shutdown` | one-shot JSON; trips the shared stop latch |
//!
//! The HTTP rendering reuses the wire codec wholesale: a request body is
//! the TCP envelope minus `v`/`op` (the URL carries both), a one-shot
//! response body is the reply's terminal `final` frame, and each SSE
//! `data:` line is the byte-identical frame JSON the TCP framing would
//! send — so both transports share [`decode_frame`] and must agree
//! cycle-for-cycle. Status codes are part of the contract (see
//! [`status_of`]): `200` success, `400` [`ServeError::BadRequest`],
//! `401` [`ServeError::Unauthorized`], `429` [`ServeError::Busy`],
//! `503` [`ServeError::Shutdown`], `504` [`ServeError::Deadline`], plus
//! `404`/`405` for unknown endpoints and methods. Deadlines ride a
//! `timeout-ms` request header (or a `deadline_ms` body field),
//! admission goes through the same priority lanes as TCP traffic, and
//! `--max-requests-per-conn` counts decoded requests per kept-alive
//! connection exactly as the TCP budget does.
//!
//! Auth (`--auth-token`): the token rides an `authorization: Bearer
//! <token>` request header — never the body — and is required on every
//! `/v1/*` endpoint once configured; `/healthz` stays open for probes.
//! Failures answer `401` with a terminal `unauthorized` frame. The
//! comparison is constant-time (see `net::token_eq`).
//!
//! ```
//! use fuseconv::coordinator::http::status_of;
//! use fuseconv::coordinator::ServeError;
//! assert_eq!(status_of(&Err(ServeError::Busy)).0, 429);
//! ```

use super::net::{
    accept_loop, authorized, is_timeout, GaugeGuard, RequestBudget, StopLatch, Transport,
    TransportGauges, MAX_TICKET_WAIT,
};
use super::protocol::{
    collapse_stream, Frame, RecvError, Reply, Request, RequestBody, Response, ServeError,
    Service, SweepRow, Ticket, PROTOCOL_VERSION,
};
use super::reactor::{self, ConnCx, Driver};
use super::wire::{
    decode_frame, decode_request_body, encode_response, encode_sse_event, parse_json, Json,
    WireError,
};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Largest accepted HTTP request body. Inline-model simulate requests
/// are the biggest legitimate payload; this is far above any of them.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Read-poll interval: how often an idle kept-alive connection wakes to
/// check the shutdown latch.
const READ_POLL: Duration = Duration::from_millis(500);

/// Once a request's first byte has arrived, the rest of its head and
/// body must land within this window (a dribbling client cannot hold a
/// handler hostage). Idle kept-alive connections are exempt.
const REQUEST_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Server-side socket write timeout (mirrors the TCP frontend): a
/// client that accepts zero bytes for this long is declared dead.
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(600);

/// How long the sweep path waits for the stream's first frame before
/// committing to a `200` SSE response. An admission-time error
/// (`busy`, `shutdown`) is always already buffered and maps to its
/// proper status instead of a one-event error stream.
const SSE_FIRST_FRAME_WAIT: Duration = Duration::from_millis(100);

/// Wait bound for `/healthz`'s internal stats probe.
const HEALTH_WAIT: Duration = Duration::from_secs(5);

/// HTTP status line for a protocol result — the transport's half of the
/// error taxonomy (`PROTOCOL.md` §Error taxonomy).
pub fn status_of(result: &Result<Reply, ServeError>) -> (u16, &'static str) {
    match result {
        Ok(_) => (200, "OK"),
        Err(ServeError::BadRequest(_)) => (400, "Bad Request"),
        Err(ServeError::Unauthorized) => (401, "Unauthorized"),
        Err(ServeError::Busy) => (429, "Too Many Requests"),
        Err(ServeError::Shutdown) => (503, "Service Unavailable"),
        Err(ServeError::Deadline) => (504, "Gateway Timeout"),
    }
}

/// A bound HTTP frontend. `bind` then `run`; `run` returns once the
/// stop latch trips (a `POST /v1/shutdown` here, or a `Shutdown` served
/// by any frontend sharing the latch).
pub struct HttpServer {
    listener: TcpListener,
    addr: SocketAddr,
    service: Arc<dyn Service>,
    /// Per-connection request budget; `None` = unlimited.
    max_requests_per_conn: Option<u64>,
    /// When set, every `/v1/*` request must present it as a bearer
    /// token; failures answer `401`. `/healthz` stays open.
    auth_token: Option<Arc<str>>,
    stop: StopLatch,
    transport: Transport,
    gauges: TransportGauges,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) in front
    /// of `service`, with no per-connection limits and a private stop
    /// latch.
    pub fn bind(addr: &str, service: Arc<dyn Service>) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(HttpServer {
            listener,
            addr,
            service,
            max_requests_per_conn: None,
            auth_token: None,
            stop: StopLatch::new(),
            transport: Transport::default(),
            gauges: TransportGauges::default(),
        })
    }

    /// Require an `authorization: Bearer <token>` header on every
    /// `/v1/*` request (`None` = open); `/healthz` is exempt so
    /// liveness probes keep working. Checked after body decode and
    /// before the budget, mirroring the TCP frontend.
    pub fn with_auth_token(mut self, token: Option<String>) -> HttpServer {
        self.auth_token = token.map(Arc::from);
        self
    }

    /// Cap how many requests one kept-alive connection may submit; the
    /// request that exceeds the budget is answered `429` and the
    /// connection closes — identical accounting to the TCP frontend.
    pub fn with_request_budget(mut self, budget: Option<u64>) -> HttpServer {
        self.max_requests_per_conn = budget;
        self
    }

    /// Select the concurrency model (`Threaded` is the default).
    pub fn with_transport(mut self, transport: Transport) -> HttpServer {
        self.transport = transport;
        self
    }

    /// Share live gauges with other frontends (and the service's
    /// `Stats` reply) instead of keeping private ones.
    pub fn with_gauges(mut self, gauges: TransportGauges) -> HttpServer {
        self.gauges = gauges;
        self
    }

    /// Share a shutdown latch with other frontends: a shutdown served
    /// by any of them stops all of them.
    pub fn with_stop(mut self, stop: StopLatch) -> HttpServer {
        self.stop = stop;
        self
    }

    /// The actual bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept-and-serve until the stop latch trips. The threaded
    /// transport joins every connection handler before returning; the
    /// epoll transport returns once every connection has drained.
    pub fn run(self) -> std::io::Result<()> {
        self.stop.register(self.addr);
        let service = self.service;
        let budget = self.max_requests_per_conn;
        let auth = self.auth_token;
        let gauges = self.gauges;
        match self.transport {
            Transport::Threaded => {
                let stop = self.stop.clone();
                let _accept_thread = gauges.thread_started();
                let conn_gauges = gauges.clone();
                accept_loop(self.listener, self.stop, "fuseconv-http-conn", move |stream| {
                    handle_http_conn(
                        stream,
                        Arc::clone(&service),
                        stop.clone(),
                        budget,
                        auth.clone(),
                        conn_gauges.clone(),
                    )
                })
            }
            Transport::Epoll => {
                let driver_gauges = gauges.clone();
                reactor::serve_event_loop(self.listener, self.stop, gauges, move || {
                    Box::new(HttpDriver::new(
                        Arc::clone(&service),
                        budget,
                        auth.clone(),
                        driver_gauges.clone(),
                    )) as Box<dyn Driver>
                })
            }
        }
    }
}

/// One parsed request head.
struct HttpHead {
    method: String,
    path: String,
    body_len: usize,
    /// `timeout-ms` header (deadline in milliseconds from admission).
    timeout_ms: Option<u64>,
    /// Close after this request (HTTP/1.0 default, or `connection: close`).
    close: bool,
    /// A `transfer-encoding` header was present (unsupported on requests).
    has_transfer_encoding: bool,
    /// An `expect: 100-continue` header was present — curl sends it for
    /// bodies past ~1 KiB and waits for the interim response.
    expect_continue: bool,
    /// Token from an `authorization: Bearer <token>` header.
    auth_token: Option<String>,
}

enum HeadRead {
    Head(Box<HttpHead>),
    /// EOF / stop latch / dead socket: close silently.
    Closed,
    /// Unparsable head: answer 400 and close.
    Malformed(String),
}

/// Parse the request line into a fresh [`HttpHead`] — shared by the
/// threaded reader and the epoll driver so both transports accept the
/// byte-identical grammar.
fn parse_request_line(request_line: &str) -> Result<HttpHead, String> {
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Err(format!("bad request line {request_line:?}"));
    };
    Ok(HttpHead {
        method: method.to_string(),
        // the endpoint map takes no query strings; drop one if present
        path: target.split('?').next().unwrap_or(target).to_string(),
        body_len: 0,
        timeout_ms: None,
        close: version.eq_ignore_ascii_case("HTTP/1.0"),
        has_transfer_encoding: false,
        expect_continue: false,
        auth_token: None,
    })
}

/// Fold one (already-trimmed, non-empty) header line into `head`.
fn apply_header(head: &mut HttpHead, line: &str) -> Result<(), String> {
    if let Some((name, value)) = line.split_once(':') {
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) => head.body_len = n,
                Err(_) => return Err(format!("bad content-length {value:?}")),
            },
            "timeout-ms" => match value.parse::<u64>() {
                Ok(ms) => head.timeout_ms = Some(ms),
                Err(_) => return Err(format!("bad timeout-ms {value:?}")),
            },
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    head.close = true;
                } else if v.contains("keep-alive") {
                    head.close = false;
                }
            }
            "transfer-encoding" => head.has_transfer_encoding = true,
            "expect" => {
                head.expect_continue = value.to_ascii_lowercase().contains("100-continue");
            }
            "authorization" => {
                // only the Bearer scheme is recognized (case-insensitive
                // scheme, per RFC 7235); other schemes present no token
                if let Some((scheme, token)) = value.split_once(' ') {
                    if scheme.eq_ignore_ascii_case("bearer") {
                        head.auth_token = Some(token.trim().to_string());
                    }
                }
            }
            _ => {}
        }
    }
    Ok(())
}

fn read_head(reader: &mut BufReader<TcpStream>, stop: &StopLatch) -> HeadRead {
    // --- request line (tolerate blank lines between requests) ---
    let mut line = String::new();
    let mut started: Option<Instant> = None;
    let request_line = loop {
        match reader.read_line(&mut line) {
            Ok(0) => return HeadRead::Closed,
            Ok(_) => {
                if !line.ends_with('\n') {
                    return HeadRead::Closed; // EOF mid-line
                }
                let t = line.trim();
                if t.is_empty() {
                    line.clear();
                    continue;
                }
                break t.to_string();
            }
            Err(e) if is_timeout(&e) => {
                if line.is_empty() {
                    // idle between requests: only the latch closes us
                    if stop.stopped() {
                        return HeadRead::Closed;
                    }
                } else {
                    // mid-request dribble: bounded patience
                    let t0 = *started.get_or_insert_with(Instant::now);
                    if t0.elapsed() > REQUEST_READ_TIMEOUT {
                        return HeadRead::Malformed("request head timed out".into());
                    }
                }
            }
            Err(_) => return HeadRead::Closed,
        }
    };
    let mut head = match parse_request_line(&request_line) {
        Ok(h) => h,
        Err(msg) => return HeadRead::Malformed(msg),
    };
    // --- headers, until the blank line ---
    let deadline = Instant::now() + REQUEST_READ_TIMEOUT;
    line.clear();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return HeadRead::Closed,
            Ok(_) => {
                if !line.ends_with('\n') {
                    return HeadRead::Closed;
                }
                let t = line.trim();
                if t.is_empty() {
                    return HeadRead::Head(Box::new(head));
                }
                if let Err(msg) = apply_header(&mut head, t) {
                    return HeadRead::Malformed(msg);
                }
                line.clear();
            }
            Err(e) if is_timeout(&e) => {
                if Instant::now() > deadline {
                    return HeadRead::Malformed("request head timed out".into());
                }
            }
            Err(_) => return HeadRead::Closed,
        }
    }
}

/// Read exactly `len` body bytes, tolerating read-timeout polls; gives
/// up on EOF, a dead socket, or a dribble past the request timeout.
fn read_request_body(
    reader: &mut BufReader<TcpStream>,
    len: usize,
    stop: &StopLatch,
) -> Result<Vec<u8>, ()> {
    let mut buf = vec![0u8; len];
    let mut filled = 0;
    let deadline = Instant::now() + REQUEST_READ_TIMEOUT;
    while filled < len {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(()),
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => {
                if stop.stopped() || Instant::now() > deadline {
                    return Err(());
                }
            }
            Err(_) => return Err(()),
        }
    }
    Ok(buf)
}

enum Route {
    /// A protocol operation; `sse` marks the streaming endpoint.
    Op { op: &'static str, sse: bool },
    Health,
    NotFound,
    MethodNotAllowed { allow: &'static str },
}

fn route(method: &str, path: &str) -> Route {
    let need = |want: &'static str, op: &'static str, sse: bool| {
        if method == want {
            Route::Op { op, sse }
        } else {
            Route::MethodNotAllowed { allow: want }
        }
    };
    match path {
        "/healthz" => {
            if method == "GET" {
                Route::Health
            } else {
                Route::MethodNotAllowed { allow: "GET" }
            }
        }
        "/v1/infer" => need("POST", "infer", false),
        "/v1/simulate" => need("POST", "simulate", false),
        "/v1/sweep" => need("POST", "sweep", true),
        "/v1/search" => need("POST", "search", true),
        "/v1/cancel" => need("POST", "cancel", false),
        "/v1/add-backend" => need("POST", "add-backend", false),
        "/v1/drain-backend" => need("POST", "drain-backend", false),
        "/v1/shutdown" => need("POST", "shutdown", false),
        "/v1/stats" => need("GET", "stats", false),
        "/v1/zoo" => need("GET", "zoo", false),
        _ => Route::NotFound,
    }
}

/// Render one complete JSON response (head + body) as text; `close`
/// adds `connection: close`, and `extra` is verbatim additional header
/// lines (each `\r\n`-terminated, e.g. `allow: POST\r\n`). Both
/// transports emit exactly this text — the threaded writers and the
/// epoll driver's output buffer share it byte for byte.
fn json_response_text(
    status: u16,
    phrase: &str,
    id: u64,
    body: &str,
    close: bool,
    extra: &str,
) -> String {
    format!(
        "HTTP/1.1 {status} {phrase}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nx-request-id: {id}\r\n{extra}{}\r\n{body}",
        body.len(),
        if close { "connection: close\r\n" } else { "" },
    )
}

/// Render a one-shot response: the mapped status plus the terminal
/// `final` frame as the JSON body.
fn oneshot_text(resp: &Response, close: bool) -> String {
    let (status, phrase) = status_of(&resp.result);
    let mut body = encode_response(resp);
    body.push('\n');
    json_response_text(status, phrase, resp.id, &body, close, "")
}

/// The SSE response head committing the connection to a chunked
/// `text/event-stream` reply.
fn sse_head_text(id: u64) -> String {
    format!(
        "HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\ncache-control: no-cache\r\n\
         transfer-encoding: chunked\r\nx-request-id: {id}\r\n\r\n"
    )
}

/// One chunked-transfer chunk around `payload`.
fn chunk_text(payload: &str) -> String {
    format!("{:x}\r\n{payload}\r\n", payload.len())
}

/// The chunked-transfer terminator (no trailers).
const CHUNKS_END: &str = "0\r\n\r\n";

/// Write one JSON response with explicit status (threaded transport).
fn write_json(
    out: &mut TcpStream,
    status: u16,
    phrase: &str,
    id: u64,
    body: &str,
    close: bool,
    extra: &str,
) -> std::io::Result<()> {
    out.write_all(json_response_text(status, phrase, id, body, close, extra).as_bytes())?;
    out.flush()
}

/// Write a one-shot response (threaded transport).
fn write_oneshot(out: &mut TcpStream, resp: &Response, close: bool) -> std::io::Result<()> {
    out.write_all(oneshot_text(resp, close).as_bytes())?;
    out.flush()
}

/// An error frame body for the plain-HTTP failure statuses (404/405).
fn error_body(detail: String) -> String {
    let mut body = encode_response(&Response::err(0, ServeError::BadRequest(detail)));
    body.push('\n');
    body
}

fn write_chunk(out: &mut TcpStream, payload: &str) -> std::io::Result<()> {
    out.write_all(chunk_text(payload).as_bytes())?;
    out.flush()
}

fn finish_chunks(out: &mut TcpStream) -> bool {
    out.write_all(CHUNKS_END.as_bytes()).and_then(|_| out.flush()).is_ok()
}

/// Stream a ticket as chunked SSE. Returns `false` once the connection
/// is unusable.
fn stream_sse(out: &mut TcpStream, mut ticket: Ticket, id: u64, first: Option<Frame>) -> bool {
    if out.write_all(sse_head_text(id).as_bytes()).is_err() {
        return false;
    }
    if let Some(frame) = first {
        let last = frame.is_final();
        if write_chunk(out, &encode_sse_event(id, &frame)).is_err() {
            return false;
        }
        if last {
            return finish_chunks(out);
        }
    }
    loop {
        // Mirror the TCP stream forwarder: a wedged service becomes a
        // typed `deadline`, a dropped sink a typed `shutdown` — the
        // stream always ends with exactly one `final` event.
        let frame = match ticket.recv_deadline(MAX_TICKET_WAIT) {
            Ok(f) => f,
            Err(RecvError::Deadline) => Frame::Final(Err(ServeError::Deadline)),
            Err(RecvError::Disconnected) => Frame::Final(Err(ServeError::Shutdown)),
        };
        let last = frame.is_final();
        if write_chunk(out, &encode_sse_event(id, &frame)).is_err() {
            return false;
        }
        if last {
            return finish_chunks(out);
        }
    }
}

/// Serve the streaming endpoint: admission-time terminal errors answer
/// as plain JSON with their mapped status (`429` for a full batch
/// lane); anything live becomes a `200` SSE stream.
fn serve_sse(out: &mut TcpStream, mut ticket: Ticket, id: u64, close: bool) -> bool {
    match ticket.recv_deadline(SSE_FIRST_FRAME_WAIT) {
        Ok(Frame::Final(result)) => write_oneshot(out, &Response { id, result }, close).is_ok(),
        Ok(first) => stream_sse(out, ticket, id, Some(first)),
        Err(RecvError::Deadline) => stream_sse(out, ticket, id, None),
        Err(RecvError::Disconnected) => {
            write_oneshot(out, &Response::err(id, ServeError::Shutdown), close).is_ok()
        }
    }
}

/// The `GET /healthz` success body.
fn health_ok_body() -> String {
    format!("{{\"status\":\"ok\",\"protocol_version\":{PROTOCOL_VERSION}}}\n")
}

/// `GET /healthz`: probe the service with a `Stats` call so the status
/// reflects its real state (`503` once the shutdown latch has tripped).
fn serve_health(out: &mut TcpStream, service: &Arc<dyn Service>, close: bool) -> bool {
    let resp = service.call(Request::new(0, RequestBody::Stats)).wait_deadline(HEALTH_WAIT);
    if resp.is_ok() {
        write_json(out, 200, "OK", 0, &health_ok_body(), close, "").is_ok()
    } else {
        write_oneshot(out, &resp, close).is_ok()
    }
}

fn handle_http_conn(
    stream: TcpStream,
    service: Arc<dyn Service>,
    stop: StopLatch,
    cap: Option<u64>,
    auth: Option<Arc<str>>,
    gauges: TransportGauges,
) {
    let _conn_gauge = gauges.conn_opened();
    let _thread_gauge = gauges.thread_started();
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut out = stream;
    let mut budget = RequestBudget::new(cap);
    // Requests whose body carries no `id` get a per-connection counter.
    let mut next_auto_id: u64 = 1;
    let mut saw_shutdown = false;
    loop {
        let head = match read_head(&mut reader, &stop) {
            HeadRead::Head(h) => *h,
            HeadRead::Closed => break,
            HeadRead::Malformed(msg) => {
                let _ = write_json(&mut out, 400, "Bad Request", 0, &error_body(msg), true, "");
                break;
            }
        };
        if head.has_transfer_encoding {
            let msg = "chunked request bodies are unsupported; send content-length".to_string();
            let _ = write_json(&mut out, 400, "Bad Request", 0, &error_body(msg), true, "");
            break;
        }
        if head.body_len > MAX_BODY_BYTES {
            let msg = format!("body of {} bytes exceeds the {MAX_BODY_BYTES} limit", head.body_len);
            let _ = write_json(&mut out, 400, "Bad Request", 0, &error_body(msg), true, "");
            break;
        }
        // curl sends `Expect: 100-continue` for bodies past ~1 KiB and
        // waits ~1 s for the interim response before transmitting; ack
        // it so large inline-model POSTs don't eat that stall.
        if head.expect_continue && head.body_len > 0 {
            let _ = out.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").and_then(|_| out.flush());
        }
        // Consume the body before routing so keep-alive framing survives
        // 404s and bad methods.
        let Ok(body_bytes) = read_request_body(&mut reader, head.body_len, &stop) else {
            break;
        };
        let (op, sse) = match route(&head.method, &head.path) {
            Route::Op { op, sse } => (op, sse),
            Route::Health => {
                if !serve_health(&mut out, &service, head.close) || head.close {
                    break;
                }
                continue;
            }
            Route::NotFound => {
                let msg = format!("no such endpoint: {} {}", head.method, head.path);
                if write_json(&mut out, 404, "Not Found", 0, &error_body(msg), head.close, "")
                    .is_err()
                    || head.close
                {
                    break;
                }
                continue;
            }
            Route::MethodNotAllowed { allow } => {
                let msg = format!("{} only accepts {allow}", head.path);
                if write_json(
                    &mut out,
                    405,
                    "Method Not Allowed",
                    0,
                    &error_body(msg),
                    head.close,
                    &format!("allow: {allow}\r\n"),
                )
                .is_err()
                    || head.close
                {
                    break;
                }
                continue;
            }
        };
        // --- body decode (shared with the TCP framing via wire.rs) ---
        let parsed = String::from_utf8(body_bytes)
            .map_err(|_| WireError("body is not utf-8".into()))
            .and_then(|text| {
                if text.trim().is_empty() {
                    Ok(Json::Obj(Vec::new()))
                } else {
                    parse_json(text.trim())
                }
            });
        let json = match parsed {
            Ok(j) => j,
            Err(e) => {
                let resp = Response::err(0, ServeError::BadRequest(e.to_string()));
                if write_oneshot(&mut out, &resp, head.close).is_err() || head.close {
                    break;
                }
                continue;
            }
        };
        let id = match json.get("id").and_then(Json::as_u64) {
            Some(i) => i,
            None => {
                let i = next_auto_id;
                next_auto_id += 1;
                i
            }
        };
        let deadline_ms = json.get("deadline_ms").and_then(Json::as_u64).or(head.timeout_ms);
        let body = match decode_request_body(op, &json) {
            Ok(b) => b,
            Err(e) => {
                let resp = Response::err(id, ServeError::BadRequest(e.to_string()));
                if write_oneshot(&mut out, &resp, head.close).is_err() || head.close {
                    break;
                }
                continue;
            }
        };
        // Auth gate, mirroring the TCP reader: after decode (so the 401
        // correlates with the request's id), before the budget (an
        // unauthorized request consumes no slot, and cannot shut the
        // deployment down). The token rides the Authorization header,
        // never the body.
        if !authorized(auth.as_deref(), head.auth_token.as_deref()) {
            let resp = Response::err(id, ServeError::Unauthorized);
            if write_oneshot(&mut out, &resp, head.close).is_err() || head.close {
                break;
            }
            continue;
        }
        // Only decoded requests count against the budget, exactly like
        // the TCP frontend; the over-budget request is answered 429 and
        // the connection closes.
        if !budget.admit() {
            let _ = write_oneshot(&mut out, &Response::err(id, ServeError::Busy), true);
            break;
        }
        saw_shutdown = matches!(body, RequestBody::Shutdown);
        let mut req = Request::new(id, body);
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        let ok = {
            // forwarding a reply stream — one-shot waits included —
            // shows up on the `active_streams` gauge on both transports
            let _stream_gauge = gauges.stream_started();
            if sse {
                serve_sse(&mut out, service.call(req), id, head.close)
            } else {
                let wait = deadline_ms.map(Duration::from_millis).unwrap_or(MAX_TICKET_WAIT);
                let resp = service.call(req).wait_deadline(wait);
                write_oneshot(&mut out, &resp, head.close || saw_shutdown).is_ok()
            }
        };
        if !ok || saw_shutdown || head.close {
            break;
        }
    }
    let _ = out.shutdown(std::net::Shutdown::Both);
    if saw_shutdown {
        stop.trip();
    }
}

// ---------------------------------------------------------------------------
// Epoll transport: HTTP/1.1 + SSE driver
// ---------------------------------------------------------------------------

/// Index just past the head terminator — `\r\n\r\n`, or the lenient
/// `\n\n` / `\n\r\n` forms the line-based threaded reader also accepts.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            let mut j = i + 1;
            if j < buf.len() && buf[j] == b'\r' {
                j += 1;
            }
            if j < buf.len() && buf[j] == b'\n' {
                return Some(j + 1);
            }
        }
        i += 1;
    }
    None
}

/// Parse a complete request head (request line + header lines) with the
/// same grammar as the threaded [`read_head`].
fn parse_head_text(bytes: &[u8]) -> Result<HttpHead, String> {
    let text =
        std::str::from_utf8(bytes).map_err(|_| "request head is not utf-8".to_string())?;
    let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
    let request_line = lines.next().ok_or_else(|| "empty request head".to_string())?;
    let mut head = parse_request_line(request_line)?;
    for line in lines {
        apply_header(&mut head, line)?;
    }
    Ok(head)
}

/// Merge a wanted wake-up into the connection's timer request.
fn wake_min(cx: &mut ConnCx<'_>, at: Instant) {
    if cx.wake_at.is_none_or(|w| at < w) {
        *cx.wake_at = Some(at);
    }
}

/// A one-shot endpoint's in-flight ticket on an epoll connection.
struct OneShotWait {
    ticket: Ticket,
    id: u64,
    /// Absolute reply deadline: `deadline_ms`/`timeout-ms`, else
    /// [`MAX_TICKET_WAIT`] ([`HEALTH_WAIT`] for `/healthz`).
    deadline: Instant,
    close: bool,
    /// `/healthz` probe: an `Ok` reply renders the health body instead
    /// of the terminal frame.
    health: bool,
    /// A decoded `Shutdown`: trip the latch once the ack flushes.
    shutdown: bool,
    /// Rows streamed before the final frame, collapsed into the
    /// one-shot reply exactly like [`Ticket::wait_deadline`].
    rows: Vec<SweepRow>,
    _gauge: GaugeGuard,
}

/// The sweep endpoint inside its [`SSE_FIRST_FRAME_WAIT`] window: an
/// admission-time terminal error still becomes a plain JSON reply with
/// its mapped status instead of a one-event stream.
struct SseWait {
    ticket: Ticket,
    id: u64,
    until: Instant,
    close: bool,
    _gauge: GaugeGuard,
}

/// A committed (head already written) chunked SSE stream.
struct SseStream {
    ticket: Ticket,
    id: u64,
    /// Last frame arrival — the [`MAX_TICKET_WAIT`] clock.
    last_frame: Instant,
    close: bool,
    _gauge: GaugeGuard,
}

enum HttpState {
    /// Between requests / accumulating a request head.
    Head,
    /// Head parsed; waiting for the `content-length` body bytes.
    Body(Box<HttpHead>),
    OneShot(Box<OneShotWait>),
    SsePending(Box<SseWait>),
    Sse(Box<SseStream>),
    /// No further requests will be read; pending output flushes, then
    /// the event loop closes the connection.
    Closed,
}

/// The HTTP/1.1 + SSE frontend as a nonblocking [`Driver`]: the same
/// endpoint map, status mapping, budget accounting, and byte-identical
/// response text as [`handle_http_conn`], with the blocking waits
/// replaced by a per-connection state machine the event loop pumps.
struct HttpDriver {
    service: Arc<dyn Service>,
    budget: RequestBudget,
    auth: Option<Arc<str>>,
    gauges: TransportGauges,
    /// Requests whose body carries no `id` get a per-connection counter.
    next_auto_id: u64,
    state: HttpState,
    /// First byte of the current request arrived here — the
    /// [`REQUEST_READ_TIMEOUT`] clock; `None` while idle between
    /// requests (idle kept-alive connections are exempt).
    request_started: Option<Instant>,
    /// Peer half-closed: an incomplete request can never finish.
    eof: bool,
}

impl HttpDriver {
    fn new(
        service: Arc<dyn Service>,
        budget: Option<u64>,
        auth: Option<Arc<str>>,
        gauges: TransportGauges,
    ) -> HttpDriver {
        HttpDriver {
            service,
            budget: RequestBudget::new(budget),
            auth,
            gauges,
            next_auto_id: 1,
            state: HttpState::Head,
            request_started: None,
            eof: false,
        }
    }

    /// Queue a rendered response and either return to reading the next
    /// request or stop reading for good — the driver's analogue of the
    /// threaded loop's `continue`-vs-`break` after every answer.
    fn answer(&mut self, cx: &mut ConnCx<'_>, text: String, close: bool) {
        cx.out.extend_from_slice(text.as_bytes());
        if close {
            self.state = HttpState::Closed;
            *cx.close_after_flush = true;
        } else {
            self.state = HttpState::Head;
        }
    }

    /// Route one complete request — the nonblocking mirror of the
    /// threaded per-request block in [`handle_http_conn`].
    fn dispatch(&mut self, head: HttpHead, body_bytes: Vec<u8>, cx: &mut ConnCx<'_>, now: Instant) {
        let (op, sse) = match route(&head.method, &head.path) {
            Route::Op { op, sse } => (op, sse),
            Route::Health => {
                self.state = HttpState::OneShot(Box::new(OneShotWait {
                    ticket: self.service.call(Request::new(0, RequestBody::Stats)),
                    id: 0,
                    deadline: now + HEALTH_WAIT,
                    close: head.close,
                    health: true,
                    shutdown: false,
                    rows: Vec::new(),
                    _gauge: self.gauges.stream_started(),
                }));
                return;
            }
            Route::NotFound => {
                let msg = format!("no such endpoint: {} {}", head.method, head.path);
                let text =
                    json_response_text(404, "Not Found", 0, &error_body(msg), head.close, "");
                self.answer(cx, text, head.close);
                return;
            }
            Route::MethodNotAllowed { allow } => {
                let msg = format!("{} only accepts {allow}", head.path);
                let text = json_response_text(
                    405,
                    "Method Not Allowed",
                    0,
                    &error_body(msg),
                    head.close,
                    &format!("allow: {allow}\r\n"),
                );
                self.answer(cx, text, head.close);
                return;
            }
        };
        // --- body decode (shared with the TCP framing via wire.rs) ---
        let parsed = String::from_utf8(body_bytes)
            .map_err(|_| WireError("body is not utf-8".into()))
            .and_then(|text| {
                if text.trim().is_empty() {
                    Ok(Json::Obj(Vec::new()))
                } else {
                    parse_json(text.trim())
                }
            });
        let json = match parsed {
            Ok(j) => j,
            Err(e) => {
                let resp = Response::err(0, ServeError::BadRequest(e.to_string()));
                self.answer(cx, oneshot_text(&resp, head.close), head.close);
                return;
            }
        };
        let id = match json.get("id").and_then(Json::as_u64) {
            Some(i) => i,
            None => {
                let i = self.next_auto_id;
                self.next_auto_id += 1;
                i
            }
        };
        let deadline_ms = json.get("deadline_ms").and_then(Json::as_u64).or(head.timeout_ms);
        let body = match decode_request_body(op, &json) {
            Ok(b) => b,
            Err(e) => {
                let resp = Response::err(id, ServeError::BadRequest(e.to_string()));
                self.answer(cx, oneshot_text(&resp, head.close), head.close);
                return;
            }
        };
        // Auth gate (threaded parity): after decode, before the budget;
        // an unauthorized request consumes no slot.
        if !authorized(self.auth.as_deref(), head.auth_token.as_deref()) {
            let resp = Response::err(id, ServeError::Unauthorized);
            self.answer(cx, oneshot_text(&resp, head.close), head.close);
            return;
        }
        // Only decoded requests count against the budget; the
        // over-budget request is answered 429 and the connection closes.
        if !self.budget.admit() {
            self.answer(cx, oneshot_text(&Response::err(id, ServeError::Busy), true), true);
            return;
        }
        let shutdown = matches!(body, RequestBody::Shutdown);
        let mut req = Request::new(id, body);
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        let ticket = self.service.call(req);
        if sse {
            self.state = HttpState::SsePending(Box::new(SseWait {
                ticket,
                id,
                until: now + SSE_FIRST_FRAME_WAIT,
                close: head.close,
                _gauge: self.gauges.stream_started(),
            }));
        } else {
            let wait = deadline_ms.map(Duration::from_millis).unwrap_or(MAX_TICKET_WAIT);
            self.state = HttpState::OneShot(Box::new(OneShotWait {
                ticket,
                id,
                deadline: now + wait,
                close: head.close || shutdown,
                health: false,
                shutdown,
                rows: Vec::new(),
                _gauge: self.gauges.stream_started(),
            }));
        }
    }

    /// Make all possible progress: consume buffered input, poll any
    /// in-flight ticket, and queue output. Idempotent; every blocking
    /// point either waits for more bytes (reactor read readiness) or
    /// registers a wake-up through `cx.wake_at`.
    fn advance(&mut self, cx: &mut ConnCx<'_>, now: Instant) {
        loop {
            match std::mem::replace(&mut self.state, HttpState::Closed) {
                HttpState::Head => {
                    // tolerate blank lines between requests
                    let skip =
                        cx.inbuf.iter().take_while(|&&b| b == b'\r' || b == b'\n').count();
                    if skip > 0 {
                        cx.inbuf.drain(..skip);
                    }
                    if cx.inbuf.is_empty() {
                        // idle between requests: only EOF/latch closes us
                        self.request_started = None;
                        self.state = HttpState::Head;
                        return;
                    }
                    let Some(end) = find_head_end(cx.inbuf) else {
                        if self.eof {
                            // EOF mid-head: close silently (threaded parity)
                            cx.inbuf.clear();
                            *cx.close_after_flush = true;
                            return;
                        }
                        // mid-request dribble: bounded patience
                        let t0 = *self.request_started.get_or_insert(now);
                        if now.duration_since(t0) > REQUEST_READ_TIMEOUT {
                            let body = error_body("request head timed out".into());
                            let text = json_response_text(400, "Bad Request", 0, &body, true, "");
                            self.answer(cx, text, true);
                            continue;
                        }
                        wake_min(cx, t0 + REQUEST_READ_TIMEOUT);
                        self.state = HttpState::Head;
                        return;
                    };
                    let head_bytes: Vec<u8> = cx.inbuf.drain(..end).collect();
                    let head = match parse_head_text(&head_bytes) {
                        Ok(h) => h,
                        Err(msg) => {
                            let text = json_response_text(
                                400,
                                "Bad Request",
                                0,
                                &error_body(msg),
                                true,
                                "",
                            );
                            self.answer(cx, text, true);
                            continue;
                        }
                    };
                    if head.has_transfer_encoding {
                        let msg =
                            "chunked request bodies are unsupported; send content-length"
                                .to_string();
                        let text =
                            json_response_text(400, "Bad Request", 0, &error_body(msg), true, "");
                        self.answer(cx, text, true);
                        continue;
                    }
                    if head.body_len > MAX_BODY_BYTES {
                        let msg = format!(
                            "body of {} bytes exceeds the {MAX_BODY_BYTES} limit",
                            head.body_len
                        );
                        let text =
                            json_response_text(400, "Bad Request", 0, &error_body(msg), true, "");
                        self.answer(cx, text, true);
                        continue;
                    }
                    // ack `Expect: 100-continue` so large POSTs don't
                    // stall on curl's interim-response wait
                    if head.expect_continue && head.body_len > 0 {
                        cx.out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                    }
                    self.state = HttpState::Body(Box::new(head));
                }
                HttpState::Body(head) => {
                    if cx.inbuf.len() < head.body_len {
                        if self.eof {
                            // truncated body: close silently
                            cx.inbuf.clear();
                            *cx.close_after_flush = true;
                            return;
                        }
                        let t0 = *self.request_started.get_or_insert(now);
                        if now.duration_since(t0) > REQUEST_READ_TIMEOUT {
                            // dribbling body: close silently (threaded parity)
                            cx.inbuf.clear();
                            *cx.close_after_flush = true;
                            return;
                        }
                        wake_min(cx, t0 + REQUEST_READ_TIMEOUT);
                        self.state = HttpState::Body(head);
                        return;
                    }
                    let body_bytes: Vec<u8> = cx.inbuf.drain(..head.body_len).collect();
                    self.request_started = None;
                    self.dispatch(*head, body_bytes, cx, now);
                }
                HttpState::OneShot(mut w) => {
                    let done = loop {
                        match w.ticket.try_recv() {
                            Ok(Some(Frame::Final(result))) => break Some(result),
                            Ok(Some(Frame::Row(row))) => w.rows.push(row),
                            Ok(Some(Frame::Progress { .. })) => {}
                            Ok(Some(Frame::SearchRow(_))) => {}
                            Ok(None) => {
                                if now >= w.deadline {
                                    break Some(Err(ServeError::Deadline));
                                }
                                break None;
                            }
                            Err(_) => break Some(Err(ServeError::Shutdown)),
                        }
                    };
                    let Some(result) = done else {
                        wake_min(cx, w.deadline);
                        self.state = HttpState::OneShot(w);
                        return;
                    };
                    let result = collapse_stream(result, std::mem::take(&mut w.rows));
                    let text = if w.health && result.is_ok() {
                        json_response_text(200, "OK", 0, &health_ok_body(), w.close, "")
                    } else {
                        oneshot_text(&Response { id: w.id, result }, w.close)
                    };
                    if w.shutdown {
                        *cx.trip_after_flush = true;
                        self.answer(cx, text, true);
                    } else {
                        self.answer(cx, text, w.close);
                    }
                }
                HttpState::SsePending(mut w) => match w.ticket.try_recv() {
                    Ok(Some(Frame::Final(result))) => {
                        let close = w.close;
                        let text = oneshot_text(&Response { id: w.id, result }, close);
                        self.answer(cx, text, close);
                    }
                    Ok(Some(first)) => {
                        let SseWait { ticket, id, close, _gauge, .. } = *w;
                        cx.out.extend_from_slice(sse_head_text(id).as_bytes());
                        cx.out.extend_from_slice(
                            chunk_text(&encode_sse_event(id, &first)).as_bytes(),
                        );
                        self.state = HttpState::Sse(Box::new(SseStream {
                            ticket,
                            id,
                            last_frame: now,
                            close,
                            _gauge,
                        }));
                    }
                    Ok(None) => {
                        if now >= w.until {
                            // commit to the SSE response; frames follow
                            let SseWait { ticket, id, close, _gauge, .. } = *w;
                            cx.out.extend_from_slice(sse_head_text(id).as_bytes());
                            self.state = HttpState::Sse(Box::new(SseStream {
                                ticket,
                                id,
                                last_frame: now,
                                close,
                                _gauge,
                            }));
                        } else {
                            wake_min(cx, w.until);
                            self.state = HttpState::SsePending(w);
                            return;
                        }
                    }
                    Err(_) => {
                        let close = w.close;
                        let text =
                            oneshot_text(&Response::err(w.id, ServeError::Shutdown), close);
                        self.answer(cx, text, close);
                    }
                },
                HttpState::Sse(mut s) => loop {
                    if cx.out.len() >= reactor::OUT_BOUND {
                        // Backpressure maps onto write readiness: park
                        // the stream (its producer parks on the bounded
                        // ticket buffer) until the socket drains.
                        self.state = HttpState::Sse(s);
                        return;
                    }
                    let frame = match s.ticket.try_recv() {
                        Ok(Some(f)) => f,
                        Ok(None) => {
                            if now.duration_since(s.last_frame) > MAX_TICKET_WAIT {
                                Frame::Final(Err(ServeError::Deadline))
                            } else {
                                wake_min(cx, s.last_frame + MAX_TICKET_WAIT);
                                self.state = HttpState::Sse(s);
                                return;
                            }
                        }
                        Err(_) => Frame::Final(Err(ServeError::Shutdown)),
                    };
                    s.last_frame = now;
                    let last = frame.is_final();
                    cx.out
                        .extend_from_slice(chunk_text(&encode_sse_event(s.id, &frame)).as_bytes());
                    if last {
                        cx.out.extend_from_slice(CHUNKS_END.as_bytes());
                        if s.close {
                            self.state = HttpState::Closed;
                            *cx.close_after_flush = true;
                        } else {
                            self.state = HttpState::Head;
                        }
                        break;
                    }
                },
                HttpState::Closed => {
                    // no further requests; discard pipelined input so the
                    // event loop's EOF close condition can fire
                    cx.inbuf.clear();
                    *cx.close_after_flush = true;
                    return;
                }
            }
        }
    }
}

impl Driver for HttpDriver {
    fn on_data(&mut self, cx: &mut ConnCx<'_>, now: Instant) {
        self.advance(cx, now);
    }

    fn on_eof(&mut self, _cx: &mut ConnCx<'_>) {
        // In-flight replies still flush to a half-closed peer; advance
        // observes the flag at its next blocking point.
        self.eof = true;
    }

    fn pump(&mut self, cx: &mut ConnCx<'_>, now: Instant) {
        self.advance(cx, now);
    }

    fn is_streaming(&self) -> bool {
        matches!(
            self.state,
            HttpState::OneShot(_) | HttpState::SsePending(_) | HttpState::Sse(_)
        )
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// One-shot HTTP reply: the status code plus the (de-chunked) body.
#[derive(Debug)]
pub struct HttpReply {
    pub status: u16,
    pub body: String,
}

impl HttpReply {
    /// Decode the body as the terminal protocol frame every one-shot
    /// endpoint returns.
    pub fn response(&self) -> Result<Response, WireError> {
        super::wire::decode_response(self.body.trim())
    }
}

fn http_connect(addr: &str, timeout: Duration) -> Result<TcpStream, WireError> {
    let sockaddr = addr
        .to_socket_addrs()
        .map_err(|e| WireError(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| WireError(format!("unresolvable address {addr:?}")))?;
    let stream = if timeout.is_zero() {
        TcpStream::connect(sockaddr)
    } else {
        TcpStream::connect_timeout(&sockaddr, timeout)
    }
    .map_err(|e| WireError(format!("connect {addr}: {e}")))?;
    if !timeout.is_zero() {
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
    }
    Ok(stream)
}

fn send_http_request(
    stream: &mut TcpStream,
    host: &str,
    path: &str,
    body: Option<&str>,
    timeout_ms: Option<u64>,
    bearer: Option<&str>,
) -> Result<(), WireError> {
    let mut req = String::new();
    let method = if body.is_some() { "POST" } else { "GET" };
    let _ = write!(req, "{method} {path} HTTP/1.1\r\nhost: {host}\r\nconnection: close\r\n");
    if let Some(ms) = timeout_ms {
        let _ = write!(req, "timeout-ms: {ms}\r\n");
    }
    if let Some(token) = bearer {
        let _ = write!(req, "authorization: Bearer {token}\r\n");
    }
    match body {
        Some(payload) => {
            let _ = write!(
                req,
                "content-type: application/json\r\ncontent-length: {}\r\n\r\n{payload}",
                payload.len()
            );
        }
        None => req.push_str("\r\n"),
    }
    stream.write_all(req.as_bytes()).map_err(|e| WireError(format!("send: {e}")))
}

fn read_line_full(reader: &mut BufReader<TcpStream>, line: &mut String) -> Result<(), WireError> {
    match reader.read_line(line) {
        Ok(0) => Err(WireError("connection closed by server".into())),
        Ok(_) => Ok(()),
        Err(e) => Err(WireError(format!("read: {e}"))),
    }
}

fn read_reply_head(
    reader: &mut BufReader<TcpStream>,
) -> Result<(u16, Vec<(String, String)>), WireError> {
    let mut line = String::new();
    read_line_full(reader, &mut line)?;
    let status = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| WireError(format!("bad status line {:?}", line.trim())))?;
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        read_line_full(reader, &mut h)?;
        let t = h.trim();
        if t.is_empty() {
            return Ok((status, headers));
        }
        if let Some((name, value)) = t.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// Read one chunk of a chunked body; `None` on the terminating 0-chunk.
fn read_chunk_payload(reader: &mut BufReader<TcpStream>) -> Result<Option<String>, WireError> {
    let mut line = String::new();
    read_line_full(reader, &mut line)?;
    let size_str = line.trim().split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .map_err(|_| WireError(format!("bad chunk size {size_str:?}")))?;
    if size == 0 {
        let mut end = String::new();
        let _ = reader.read_line(&mut end); // trailing CRLF (no trailers)
        return Ok(None);
    }
    let mut buf = vec![0u8; size + 2]; // payload + CRLF
    reader
        .read_exact(&mut buf)
        .map_err(|e| WireError(format!("read chunk: {e}")))?;
    buf.truncate(size);
    String::from_utf8(buf).map(Some).map_err(|_| WireError("chunk is not utf-8".into()))
}

fn read_reply_body(
    reader: &mut BufReader<TcpStream>,
    headers: &[(String, String)],
) -> Result<String, WireError> {
    if header(headers, "transfer-encoding")
        .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    {
        let mut body = String::new();
        while let Some(chunk) = read_chunk_payload(reader)? {
            body.push_str(&chunk);
        }
        return Ok(body);
    }
    if let Some(len) = header(headers, "content-length") {
        let len: usize =
            len.parse().map_err(|_| WireError(format!("bad content-length {len:?}")))?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf).map_err(|e| WireError(format!("read body: {e}")))?;
        return String::from_utf8(buf).map_err(|_| WireError("body is not utf-8".into()));
    }
    // no framing: connection-close delimited
    let mut body = String::new();
    reader
        .read_to_string(&mut body)
        .map_err(|e| WireError(format!("read body: {e}")))?;
    Ok(body)
}

/// One-shot HTTP call: `Some(body)` ⇒ `POST`, `None` ⇒ `GET`. A
/// `timeout_ms` is sent as the `timeout-ms` deadline header; `timeout`
/// bounds the client's own socket operations (`Duration::ZERO`
/// disables it).
pub fn http_call(
    addr: &str,
    path: &str,
    body: Option<&str>,
    timeout_ms: Option<u64>,
    timeout: Duration,
) -> Result<HttpReply, WireError> {
    http_call_auth(addr, path, body, timeout_ms, None, timeout)
}

/// [`http_call`] with an optional bearer token, sent as an
/// `authorization: Bearer <token>` header (tokens never ride the body).
pub fn http_call_auth(
    addr: &str,
    path: &str,
    body: Option<&str>,
    timeout_ms: Option<u64>,
    bearer: Option<&str>,
    timeout: Duration,
) -> Result<HttpReply, WireError> {
    let mut stream = http_connect(addr, timeout)?;
    send_http_request(&mut stream, addr, path, body, timeout_ms, bearer)?;
    let mut reader = BufReader::new(stream);
    let (status, headers) = read_reply_head(&mut reader)?;
    let body = read_reply_body(&mut reader, &headers)?;
    Ok(HttpReply { status, body })
}

/// `POST` an SSE endpoint (`/v1/sweep`) and invoke `on_frame` for every
/// event as it arrives, including the terminal one. Returns the
/// collapsed [`Response`] (streamed rows merged, mirroring
/// [`Ticket::wait`]); a non-streaming answer — an admission-time error
/// with its mapped status — decodes its one-shot body instead and
/// surfaces it through `on_frame` as the final frame.
pub fn http_sse<F>(
    addr: &str,
    path: &str,
    body: &str,
    timeout_ms: Option<u64>,
    timeout: Duration,
    on_frame: F,
) -> Result<Response, WireError>
where
    F: FnMut(u64, &Frame),
{
    http_sse_auth(addr, path, body, timeout_ms, None, timeout, on_frame)
}

/// [`http_sse`] with an optional bearer token (see [`http_call_auth`]).
#[allow(clippy::too_many_arguments)]
pub fn http_sse_auth<F>(
    addr: &str,
    path: &str,
    body: &str,
    timeout_ms: Option<u64>,
    bearer: Option<&str>,
    timeout: Duration,
    mut on_frame: F,
) -> Result<Response, WireError>
where
    F: FnMut(u64, &Frame),
{
    let mut stream = http_connect(addr, timeout)?;
    send_http_request(&mut stream, addr, path, Some(body), timeout_ms, bearer)?;
    let mut reader = BufReader::new(stream);
    let (_status, headers) = read_reply_head(&mut reader)?;
    let is_sse = header(&headers, "content-type")
        .is_some_and(|v| v.starts_with("text/event-stream"));
    if !is_sse {
        let body = read_reply_body(&mut reader, &headers)?;
        let resp = super::wire::decode_response(body.trim())?;
        on_frame(resp.id, &Frame::Final(resp.result.clone()));
        return Ok(resp);
    }
    let mut buf = String::new();
    let mut rows: Vec<SweepRow> = Vec::new();
    loop {
        let Some(chunk) = read_chunk_payload(&mut reader)? else {
            return Err(WireError("SSE stream ended without a final frame".into()));
        };
        buf.push_str(&chunk);
        // events may span chunks; a blank line terminates each one
        while let Some(pos) = buf.find("\n\n") {
            let event: String = buf.drain(..pos + 2).collect();
            let Some(data) = event.lines().find_map(|l| l.strip_prefix("data:")) else {
                continue;
            };
            let (id, frame) = decode_frame(data.trim())?;
            on_frame(id, &frame);
            match frame {
                Frame::Progress { .. } => {}
                Frame::Row(row) => rows.push(row),
                // display stream; the terminal Search reply carries the
                // converged frontier
                Frame::SearchRow(_) => {}
                Frame::Final(result) => {
                    return Ok(Response { id, result: collapse_stream(result, rows) });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping_covers_every_error() {
        assert_eq!(status_of(&Ok(Reply::Done)).0, 200);
        assert_eq!(status_of(&Err(ServeError::BadRequest("x".into()))).0, 400);
        assert_eq!(status_of(&Err(ServeError::Unauthorized)).0, 401);
        assert_eq!(status_of(&Err(ServeError::Busy)).0, 429);
        assert_eq!(status_of(&Err(ServeError::Shutdown)).0, 503);
        assert_eq!(status_of(&Err(ServeError::Deadline)).0, 504);
    }

    #[test]
    fn authorization_header_parses_bearer_only() {
        let mut head = parse_request_line("POST /v1/search HTTP/1.1").unwrap();
        apply_header(&mut head, "authorization: Bearer s3cret").unwrap();
        assert_eq!(head.auth_token.as_deref(), Some("s3cret"));
        // scheme is case-insensitive
        let mut head = parse_request_line("POST /v1/search HTTP/1.1").unwrap();
        apply_header(&mut head, "Authorization: bearer tok").unwrap();
        assert_eq!(head.auth_token.as_deref(), Some("tok"));
        // other schemes present no token
        let mut head = parse_request_line("POST /v1/search HTTP/1.1").unwrap();
        apply_header(&mut head, "authorization: Basic dXNlcjpwdw==").unwrap();
        assert_eq!(head.auth_token, None);
    }

    #[test]
    fn route_table_matches_the_endpoint_map() {
        assert!(matches!(route("POST", "/v1/infer"), Route::Op { op: "infer", sse: false }));
        assert!(matches!(
            route("POST", "/v1/simulate"),
            Route::Op { op: "simulate", sse: false }
        ));
        assert!(matches!(route("POST", "/v1/sweep"), Route::Op { op: "sweep", sse: true }));
        assert!(matches!(route("POST", "/v1/search"), Route::Op { op: "search", sse: true }));
        assert!(matches!(route("POST", "/v1/cancel"), Route::Op { op: "cancel", sse: false }));
        assert!(matches!(route("GET", "/v1/stats"), Route::Op { op: "stats", sse: false }));
        assert!(matches!(route("GET", "/v1/zoo"), Route::Op { op: "zoo", sse: false }));
        assert!(matches!(
            route("POST", "/v1/shutdown"),
            Route::Op { op: "shutdown", sse: false }
        ));
        assert!(matches!(route("GET", "/healthz"), Route::Health));
        // query strings are stripped before routing
        assert!(matches!(route("GET", "/v1/stats"), Route::Op { .. }));
        assert!(matches!(route("GET", "/v1/sweep"), Route::MethodNotAllowed { allow: "POST" }));
        assert!(matches!(route("POST", "/v1/stats"), Route::MethodNotAllowed { allow: "GET" }));
        assert!(matches!(route("GET", "/nope"), Route::NotFound));
    }

    #[test]
    fn one_shot_bodies_are_terminal_frames() {
        let reply = HttpReply {
            status: 429,
            body: "{\"v\":2,\"id\":7,\"frame\":\"final\",\"err\":{\"code\":\"busy\"}}\n".into(),
        };
        let resp = reply.response().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.result, Err(ServeError::Busy));
    }
}
