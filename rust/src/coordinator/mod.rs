//! L3 coordinator: network evaluation over the simulator, hybrid-network
//! search (EA + OFA-NAS), and the unified serving surface — typed
//! protocol ([`protocol`]), batched inference + simulation services
//! behind one [`Service`] trait ([`server`]), the JSON wire codec
//! ([`wire`]), and the TCP frontend ([`net`]).

pub mod batcher;
pub mod evaluator;
pub mod mapping;
pub mod net;
pub mod protocol;
pub mod search;
pub mod server;
pub mod wire;

pub use evaluator::{Evaluator, HybridSpace, NetEval};
pub use net::{request_once, WireClient, WireServer};
pub use protocol::{
    ConfigPatch, Frame, FrameSink, ModelSpec, Priority, RecvError, Reply, Request,
    RequestBody, Response, ServeError, Service, SweepRow, Ticket, PROTOCOL_VERSION,
};
pub use server::{Engine, MockEngine, Router, Server, SimServer};
