//! L3 coordinator: network evaluation over the simulator, hybrid-network
//! search (EA + OFA-NAS), and the unified serving surface — typed
//! protocol ([`protocol`]), batched inference + simulation services
//! behind one [`Service`] trait ([`server`]), the JSON wire codec
//! ([`wire`]), and two transports over the same service: the TCP frame
//! frontend ([`net`]) and the HTTP/SSE frontend ([`http`]). Each
//! transport runs on either of two concurrency models selected at bind
//! time ([`Transport`]): classic thread-per-connection, or a
//! single-threaded epoll event loop (the `reactor` module) that holds
//! thread count flat while connections scale. Deployments
//! scale out horizontally through the shard-router front tier
//! ([`shard`]), which implements the same [`Service`] trait over many
//! `fuseconv serve` backends, so both transports mount it unchanged.
//! The wire contract every transport renders is specified normatively
//! in `PROTOCOL.md` at the repository root.

pub mod batcher;
pub mod evaluator;
pub mod http;
pub mod mapping;
pub mod net;
pub mod protocol;
pub(crate) mod reactor;
pub mod search;
pub mod server;
pub mod shard;
pub mod wire;

pub use evaluator::{Evaluator, HybridSpace, NetEval};
pub use http::{http_call, http_call_auth, http_sse, http_sse_auth, HttpReply, HttpServer};
pub use net::{
    request_once, GaugeGuard, StopLatch, Transport, TransportGauges, WireClient, WireServer,
};
pub use protocol::{
    ConfigPatch, Frame, FrameSink, ModelSpec, Priority, RecvError, Reply, Request,
    RequestBody, Response, SearchPoint, SearchReply, SearchSpec, ServeError, Service,
    StatsReply, SweepRow, Ticket, PROTOCOL_VERSION,
};
pub use server::{Engine, MockEngine, Router, Server, SimServer};
pub use shard::ShardRouter;
