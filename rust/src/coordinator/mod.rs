//! L3 coordinator: network evaluation over the simulator, hybrid-network
//! search (EA + OFA-NAS), block-selection policies, and the inference
//! serving loop.

pub mod batcher;
pub mod evaluator;
pub mod mapping;
pub mod search;
pub mod server;

pub use evaluator::{Evaluator, HybridSpace, NetEval};
pub use server::{Engine, Server, SimRequest, SimServer};
