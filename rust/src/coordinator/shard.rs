//! Shard-router front tier: one [`Service`] that partitions traffic
//! across several `fuseconv serve` backends (`fuseconv shard
//! --backends addr1,addr2,...`).
//!
//! The paper's ST-OS argument — map *independent* work onto rows of the
//! array so every resource stays busy — has a direct serving analogue:
//! simulation traffic partitions cleanly by (model, price-relevant
//! config), so a front tier can pin each shard to one backend and keep
//! that backend's two-level layer cache permanently hot on its slice of
//! the keyspace. The router implements the same [`Service`] trait as
//! the single-node [`Router`](super::server::Router), so both wire
//! frontends (TCP in [`net`](super::net), HTTP/SSE in
//! [`http`](super::http)) mount it unchanged and the wire contract of
//! `PROTOCOL.md` §Sharded deployment holds on every transport.
//!
//! Routing:
//! * `Simulate` pins to one backend by [`shard_key`] of
//!   (model name, price-relevant config fields) — a stable FNV-1a fold
//!   with an avalanche finish, deliberately *not* std's hasher, so the
//!   mapping survives process restarts and never depends on hasher
//!   seeding;
//! * `Sweep` splits the grid into per-backend **sub-plans** (for one
//!   model the configs partition across backends; every non-empty
//!   (backend, model) pair becomes one sub-sweep), fans them out
//!   concurrently, and re-multiplexes the backends' `row` streams back
//!   into **plan order** under the client's original request id with
//!   one consolidated `progress` counter — the reorder-buffer pattern
//!   of [`run_sweep_with`](crate::sim::run_sweep_with) — so a sharded
//!   sweep is frame-for-frame identical to a single-node sweep;
//! * `Stats` aggregates every backend's counters (and reports how many
//!   backends contributed via [`StatsReply::backends`]); `Shutdown`
//!   fans out to every backend before the ack; `Infer`/`Zoo` are
//!   unsharded and round-robin across backends.
//!
//! Failure mapping: a backend that refuses a connection, drops a stream
//! mid-sweep, or goes silent past the configured timeout terminates the
//! client's stream with a typed `final` + `err:shutdown` — never a
//! hang. Typed errors from a backend (`busy`, `bad_request`,
//! `deadline`) pass through verbatim.
//!
//! ```
//! use fuseconv::coordinator::shard::{route, shard_key};
//! use fuseconv::sim::SimConfig;
//! let cfg = SimConfig::with_size(16);
//! // the routing key is a pure function: same (model, config) → same backend
//! assert_eq!(shard_key("mobilenet-v2", &cfg), shard_key("mobilenet-v2", &cfg));
//! assert!(route("mobilenet-v2", &cfg, 4) < 4);
//! ```

use super::net::{request_once, TransportGauges, WireClient};
use super::protocol::{
    ConfigPatch, Frame, FrameSink, ModelSpec, Reply, Request, RequestBody, Response,
    ServeError, Service, StatsReply, SweepRow, Ticket, PROTOCOL_VERSION, STREAM_BOUND,
};
use super::server::{Lane, LaneSlot};
use crate::sim::{FuseVariant, SimConfig, SweepPlan};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Default backend connect/receive timeout (matches the stream-forwarder
/// bound of the wire frontends: a silent backend becomes a typed error,
/// not a wedged stream).
pub const DEFAULT_BACKEND_TIMEOUT: Duration = Duration::from_secs(600);

/// Default bound on concurrently in-flight front-tier requests. The
/// router spawns one relay thread (plus backend connections) per
/// admitted request, so admission must shed load past a bound — a
/// request past it answers [`ServeError::Busy`], exactly like the
/// single node's bounded lanes — instead of growing threads and file
/// descriptors without limit.
pub const DEFAULT_SHARD_INFLIGHT: usize = 1024;

/// Cap on each backend's shutdown round-trip: the fan-out is
/// best-effort and concurrent, and one hung (accepted-but-silent)
/// backend must not stall the client's shutdown ack for the full
/// backend timeout.
const SHUTDOWN_FANOUT_TIMEOUT: Duration = Duration::from_secs(10);

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Final avalanche (splitmix64's mixer). FNV-1a alone is too regular to
/// route on: its low bit is a pure XOR-parity of the input bytes, so
/// `key % 2` would collapse (e.g. every *square* geometry of one model
/// on the same backend — rows and cols contribute identical bytes and
/// their parity cancels). The mixer diffuses every input bit into every
/// output bit before the modulo.
fn mix(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Stable routing hash of one (model, config) shard: an FNV-1a fold
/// over the model name and exactly the price-relevant config fields
/// (the fields behind [`SimConfig::price_key`] — geometry, SRAM sizes,
/// element width, dataflow, ST-OS, mapping, and the memory model;
/// frequency is excluded because it never changes a backend's cached
/// pricing), finished with an avalanche mix. The whole computation is
/// self-contained — no `std` hasher — so the key is deterministic
/// across processes, restarts, and deployments of the same config
/// vocabulary.
pub fn shard_key(model: &str, cfg: &SimConfig) -> u64 {
    let mut h = fnv1a(0xcbf2_9ce4_8422_2325, model.as_bytes());
    for n in [
        cfg.rows as u64,
        cfg.cols as u64,
        cfg.ifmap_sram_kb as u64,
        cfg.weight_sram_kb as u64,
        cfg.ofmap_sram_kb as u64,
        cfg.bytes_per_elem as u64,
        cfg.dram_bw.to_bits(),
        cfg.dataflow as u64,
        cfg.stos as u64,
        cfg.mapping as u64,
        cfg.enforce_dram_bw as u64,
    ] {
        h = fnv1a(h, &n.to_le_bytes());
    }
    mix(h)
}

/// Which of `backends` serves the (model, config) shard.
pub fn route(model: &str, cfg: &SimConfig, backends: usize) -> usize {
    (shard_key(model, cfg) % backends.max(1) as u64) as usize
}

/// The display name a [`ModelSpec`] routes by (zoo name or inline name).
fn model_name(m: &ModelSpec) -> &str {
    match m {
        ModelSpec::Zoo(name) => name,
        ModelSpec::Inline { name, .. } => name,
    }
}

/// The shard-router front tier. Holds backend addresses plus its own
/// bounded admission lane — every admitted request opens its own
/// backend connection(s) from a relay thread, so `call` never blocks
/// (all backend I/O happens off the admission path, exactly like the
/// single-node servers), and load past the lane bound sheds as
/// [`ServeError::Busy`].
pub struct ShardRouter {
    backends: Vec<String>,
    timeout: Duration,
    /// Round-robin cursor for the unsharded ops (`Infer`, `Zoo`).
    rr: AtomicUsize,
    /// The front tier's own bounded admission (one slot per in-flight
    /// relay) — the same primitive as the single node's lanes.
    lane: Lane,
    /// Latched once a `Shutdown` has been accepted; later calls answer
    /// [`ServeError::Shutdown`], mirroring the single-node `Router`.
    closing: AtomicBool,
    /// The front tier's own live transport gauges, stamped onto
    /// aggregated stats replies. Backend gauges are deliberately *not*
    /// summed — gauges always describe the answering process.
    gauges: Option<TransportGauges>,
}

impl ShardRouter {
    /// Front `backends` (at least one `host:port` address) with
    /// `timeout` bounding every backend connect/read/write.
    pub fn new(backends: Vec<String>, timeout: Duration) -> ShardRouter {
        assert!(!backends.is_empty(), "shard router needs at least one backend");
        ShardRouter {
            backends,
            timeout,
            rr: AtomicUsize::new(0),
            lane: Lane::new(DEFAULT_SHARD_INFLIGHT),
            closing: AtomicBool::new(false),
            gauges: None,
        }
    }

    /// Report the frontends' live transport gauges in aggregated stats
    /// replies (the single-node `Router::with_gauges` counterpart).
    pub fn with_gauges(mut self, gauges: TransportGauges) -> ShardRouter {
        self.gauges = Some(gauges);
        self
    }

    /// Bound the front tier's own admission: once `capacity` requests
    /// are in flight, further calls answer [`ServeError::Busy`].
    /// Clamped to ≥ 1 — admission is always bounded.
    pub fn with_inflight(mut self, capacity: usize) -> ShardRouter {
        self.lane = Lane::new(capacity);
        self
    }

    pub fn backends(&self) -> &[String] {
        &self.backends
    }

    /// Has a `Shutdown` request been accepted?
    pub fn closing(&self) -> bool {
        self.closing.load(Ordering::Acquire)
    }

    /// Forward `req` to backend `b` verbatim from a fresh thread,
    /// streaming every reply frame into `sink`.
    fn spawn_proxy(&self, b: usize, req: Request, sink: FrameSink, slot: Option<LaneSlot>) {
        let addr = self.backends[b].clone();
        let timeout = self.timeout;
        thread::Builder::new()
            .name("fuseconv-shard-proxy".into())
            .spawn(move || {
                let _slot = slot;
                proxy(&addr, timeout, &req, &sink)
            })
            .expect("spawn shard proxy");
    }
}

impl Service for ShardRouter {
    fn call(&self, req: Request) -> Ticket {
        let id = req.id;
        let deadline_ms = req.deadline_ms;
        if self.closing() {
            return Ticket::immediate(Response::err(id, ServeError::Shutdown));
        }
        // Bounded admission (everything but `Shutdown`, which must stay
        // reachable): past `capacity` in-flight relays, shed load with a
        // typed Busy instead of spawning threads without limit.
        let slot = if matches!(req.body, RequestBody::Shutdown) {
            None
        } else if let Some(s) = self.lane.admit_slot() {
            Some(s)
        } else {
            return Ticket::immediate(Response::err(id, ServeError::Busy));
        };
        // Rebuild the forwarded request (same id + deadline) after the
        // routing decision; the body round-trips untouched.
        let forward = |body: RequestBody| {
            let mut fwd = Request::new(id, body);
            if let Some(ms) = deadline_ms {
                fwd = fwd.with_deadline_ms(ms);
            }
            fwd
        };
        match req.body {
            RequestBody::Simulate { model, variant, config } => {
                // Resolve the config up front: routing needs the
                // price-relevant fields, and a bad config answers
                // `bad_request` at admission exactly like a single node.
                let cfg = match config.to_config() {
                    Ok(c) => c,
                    Err(e) => return Ticket::immediate(Response::err(id, e)),
                };
                let b = route(model_name(&model), &cfg, self.backends.len());
                let (ticket, sink) = Ticket::pending(id);
                let body = RequestBody::Simulate { model, variant, config };
                self.spawn_proxy(b, forward(body), sink, slot);
                ticket
            }
            // `Search` is a single long-lived job, not a partitionable
            // grid: round-robin it onto one backend whole (its layer
            // traffic is spread across the whole OFA space, so no
            // backend's cache has an affinity edge) and relay the frame
            // stream — progress, live pareto rows, terminal reply —
            // verbatim. The relay also passes *disconnect* through: a
            // front-tier client that hangs up kills the proxy's backend
            // connection, and the backend cancels within a generation.
            body @ (RequestBody::Infer { .. } | RequestBody::Zoo | RequestBody::Search { .. }) => {
                let b = self.rr.fetch_add(1, Ordering::Relaxed) % self.backends.len();
                let (ticket, sink) = Ticket::pending(id);
                self.spawn_proxy(b, forward(body), sink, slot);
                ticket
            }
            RequestBody::Cancel { target } => {
                // The target stream was pinned to *one* backend, but the
                // front tier doesn't track which: fan the cancel out to
                // all of them. Cancel is idempotent (`Done` on unknown
                // ids), so the non-owners ack harmlessly.
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.backends.clone();
                let timeout = self.timeout;
                thread::Builder::new()
                    .name("fuseconv-shard-cancel".into())
                    .spawn(move || {
                        let _slot = slot;
                        thread::scope(|s| {
                            for addr in &backends {
                                s.spawn(move || {
                                    let cancel =
                                        Request::new(id, RequestBody::Cancel { target });
                                    let _ = request_once(addr, &cancel, timeout);
                                });
                            }
                        });
                        sink.finish(Ok(Reply::Done));
                    })
                    .expect("spawn shard cancel");
                ticket
            }
            RequestBody::Stats => {
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.backends.clone();
                let timeout = self.timeout;
                let gauges = self.gauges.clone();
                thread::Builder::new()
                    .name("fuseconv-shard-stats".into())
                    .spawn(move || {
                        let _slot = slot;
                        let mut result = aggregate_stats(&backends, timeout, id);
                        // counters are summed from the backends; the
                        // gauges describe this front tier
                        if let (Ok(Reply::Stats(s)), Some(g)) = (&mut result, &gauges) {
                            g.overlay(s);
                        }
                        sink.finish(result);
                    })
                    .expect("spawn shard stats");
                ticket
            }
            RequestBody::Shutdown => {
                // Latch first so no new traffic is admitted while the
                // fan-out is in flight, then stop every backend —
                // concurrently and with a capped per-node round-trip,
                // so an already-dead or hung backend cannot stall the
                // ack for the rest — and ack. The frontend mounting
                // this router trips its own stop latch on the ack,
                // exactly as it does for the single-node router.
                self.closing.store(true, Ordering::Release);
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.backends.clone();
                let timeout = if self.timeout.is_zero() {
                    SHUTDOWN_FANOUT_TIMEOUT
                } else {
                    self.timeout.min(SHUTDOWN_FANOUT_TIMEOUT)
                };
                thread::Builder::new()
                    .name("fuseconv-shard-shutdown".into())
                    .spawn(move || {
                        thread::scope(|s| {
                            for addr in &backends {
                                s.spawn(move || {
                                    let shutdown = Request::new(id, RequestBody::Shutdown);
                                    let _ = request_once(addr, &shutdown, timeout);
                                });
                            }
                        });
                        sink.finish(Ok(Reply::Done));
                    })
                    .expect("spawn shard shutdown");
                ticket
            }
            RequestBody::Sweep { models, variants, configs } => {
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.backends.clone();
                let timeout = self.timeout;
                let job = move || {
                    let _slot = slot;
                    sweep_fanout(backends, timeout, models, variants, configs, deadline_ms, sink)
                };
                thread::Builder::new()
                    .name("fuseconv-shard-sweep".into())
                    .spawn(job)
                    .expect("spawn shard sweep");
                ticket
            }
        }
    }
}

/// The sweep thread's whole job: run the sharded sweep, translate a
/// panic into a typed error, and always terminate the stream.
fn sweep_fanout(
    backends: Vec<String>,
    timeout: Duration,
    models: Vec<String>,
    variants: Vec<FuseVariant>,
    configs: Vec<ConfigPatch>,
    deadline_ms: Option<u64>,
    sink: FrameSink,
) {
    let result = catch_unwind(AssertUnwindSafe(|| {
        sweep_sharded(&backends, timeout, models, variants, configs, deadline_ms, &sink)
    }))
    .unwrap_or_else(|_| Err(ServeError::BadRequest("sharded sweep panicked".into())));
    sink.finish(result);
}

/// Forward one request over its own backend connection, relaying every
/// frame of the reply stream into `sink`. Transport failures (refused
/// connection, dropped stream, silence past the timeout) become a typed
/// terminal `shutdown`; a typed backend error passes through verbatim.
fn proxy(addr: &str, timeout: Duration, req: &Request, sink: &FrameSink) {
    let mut client = match WireClient::connect(addr, timeout) {
        Ok(c) => c,
        Err(_) => {
            sink.finish(Err(ServeError::Shutdown));
            return;
        }
    };
    if client.send(req).is_err() {
        sink.finish(Err(ServeError::Shutdown));
        return;
    }
    loop {
        match client.recv_frame(req.id) {
            Ok(Frame::Final(result)) => {
                sink.finish(result);
                return;
            }
            // A failed send means the front-tier client hung up. Stop
            // relaying and drop the backend connection: the backend's
            // transport sees the disconnect and cancels its stream, so
            // an abandoned search stops burning a whole node's pool.
            Ok(Frame::Progress { done, total }) => {
                if !sink.progress(done, total) {
                    return;
                }
            }
            Ok(Frame::Row(row)) => {
                if !sink.row(row) {
                    return;
                }
            }
            Ok(Frame::SearchRow(point)) => {
                if !sink.search_row(point) {
                    return;
                }
            }
            Err(_) => {
                sink.finish(Err(ServeError::Shutdown));
                return;
            }
        }
    }
}

/// `Stats` fan-out: the sum of every backend's counters, stamped with
/// how many backends contributed. Backends are probed concurrently —
/// aggregate latency is one round-trip (and at worst one timeout), not
/// a sum over nodes — which also keeps `/healthz` probes through a
/// front tier cheap. A backend that cannot answer fails the aggregate
/// with a typed error (partial counters would silently under-report).
fn aggregate_stats(
    backends: &[String],
    timeout: Duration,
    id: u64,
) -> Result<Reply, ServeError> {
    let results: Vec<Result<Reply, ServeError>> = thread::scope(|s| {
        let probes: Vec<_> = backends
            .iter()
            .map(|addr| {
                s.spawn(move || {
                    let req = Request::new(id, RequestBody::Stats);
                    let resp = request_once(addr, &req, timeout)
                        .map_err(|_| ServeError::Shutdown)?;
                    resp.result
                })
            })
            .collect();
        probes.into_iter().map(|p| p.join().expect("stats probe")).collect()
    });
    let mut agg = StatsReply {
        protocol_version: PROTOCOL_VERSION,
        backends: backends.len() as u64,
        ..StatsReply::default()
    };
    for result in results {
        match result? {
            Reply::Stats(s) => {
                agg.infer_served += s.infer_served;
                agg.infer_batches += s.infer_batches;
                agg.sim_submitted += s.sim_submitted;
                agg.sim_completed += s.sim_completed;
                agg.cache_hits += s.cache_hits;
                agg.cache_misses += s.cache_misses;
                agg.cache_entries += s.cache_entries;
                // global result cache: counters sum like the layer
                // cache's; entries/bytes sum into fleet-wide residency
                // (hash-pinned keys make per-backend caches disjoint)
                agg.result_hits += s.result_hits;
                agg.result_misses += s.result_misses;
                agg.result_coalesced += s.result_coalesced;
                agg.result_evicted += s.result_evicted;
                agg.result_entries += s.result_entries;
                agg.result_bytes += s.result_bytes;
                agg.search_started += s.search_started;
                agg.search_completed += s.search_completed;
                agg.search_cancelled += s.search_cancelled;
            }
            _ => {
                return Err(ServeError::BadRequest(
                    "backend answered stats with a non-stats reply".into(),
                ))
            }
        }
    }
    Ok(Reply::Stats(agg))
}

/// One per-backend sub-sweep: the request to send plus the *global*
/// plan positions its rows will fill, in the order the backend will
/// emit them (the backend streams its own plan order — variant-major,
/// then config — which maps 1:1 onto these precomputed slots).
struct SubSweep {
    req: Request,
    slots: VecDeque<usize>,
}

enum Msg {
    /// One row landed, destined for global plan position `usize`.
    Row(usize, SweepRow),
    /// A backend failed; the whole sharded sweep fails with this error.
    Fail(ServeError),
}

/// One streamed sharded `Sweep`: validate the grid exactly like a
/// single node, split it into per-backend sub-plans, fan out, and merge
/// the backends' row streams back into plan order with one consolidated
/// progress counter. Returns the terminal reply (`Done`; rows already
/// left through the sink).
fn sweep_sharded(
    backends: &[String],
    timeout: Duration,
    models: Vec<String>,
    variants: Vec<FuseVariant>,
    configs: Vec<ConfigPatch>,
    deadline_ms: Option<u64>,
    sink: &FrameSink,
) -> Result<Reply, ServeError> {
    // Validation mirrors the single-node sweep path, so error replies
    // (unknown model, bad config, empty grid) are identical on the wire.
    let networks = models
        .iter()
        .map(|m| ModelSpec::Zoo(m.clone()).resolve())
        .collect::<Result<Vec<_>, _>>()?;
    let cfgs = configs
        .iter()
        .map(|p| p.to_config())
        .collect::<Result<Vec<_>, _>>()?;
    let plan = SweepPlan::new(networks, variants.clone(), cfgs);
    if plan.is_empty() {
        return Err(ServeError::BadRequest("empty sweep grid".into()));
    }
    let total = plan.len();
    let n = backends.len();

    // --- sub-plan construction -------------------------------------
    // Cells route by (model, config); variants never affect routing, so
    // for one model the config list partitions across backends and each
    // non-empty (backend, model) pair is one cross-product sub-sweep.
    let mut subs: Vec<Vec<SubSweep>> = (0..n).map(|_| Vec::new()).collect();
    for (m, name) in models.iter().enumerate() {
        let mut per_backend: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (c, cfg) in plan.configs.iter().enumerate() {
            per_backend[route(name, cfg, n)].push(c);
        }
        for (b, cs) in per_backend.into_iter().enumerate() {
            if cs.is_empty() {
                continue;
            }
            let mut slots = VecDeque::with_capacity(variants.len() * cs.len());
            for v in 0..variants.len() {
                for &c in &cs {
                    slots.push_back(plan.index_of(m, v, c));
                }
            }
            // Sub-request ids only need to be unique per backend
            // connection; the merge re-keys every frame under the
            // client's original id.
            let mut req = Request::new(
                subs[b].len() as u64 + 1,
                RequestBody::Sweep {
                    models: vec![name.clone()],
                    variants: variants.clone(),
                    configs: cs.iter().map(|&c| configs[c].clone()).collect(),
                },
            );
            if let Some(ms) = deadline_ms {
                req = req.with_deadline_ms(ms);
            }
            subs[b].push(SubSweep { req, slots });
        }
    }

    // Up-front progress: the client learns the full grid size before
    // any backend answers, identical to the single-node stream.
    let _ = sink.progress(0, total as u64);

    // --- fan out ----------------------------------------------------
    // The merge channel is bounded so backpressure stays end to end: a
    // slow client pauses the merge, the merge pauses the workers, the
    // workers stop draining their backend sockets, and each backend's
    // own bounded writer pauses its sweep — no tier buffers unboundedly.
    let (tx, rx) = mpsc::sync_channel::<Msg>(STREAM_BOUND);
    for (b, backend_subs) in subs.into_iter().enumerate() {
        if backend_subs.is_empty() {
            continue;
        }
        let addr = backends[b].clone();
        let tx = tx.clone();
        thread::Builder::new()
            .name("fuseconv-shard-fanout".into())
            .spawn(move || backend_worker(&addr, timeout, backend_subs, &tx))
            .expect("spawn shard fan-out");
    }
    drop(tx);

    // --- plan-order merge (the run_sweep_with reorder buffer) -------
    let mut slots: Vec<Option<SweepRow>> = (0..total).map(|_| None).collect();
    let mut next = 0usize;
    let mut done = 0usize;
    while done < total {
        match rx.recv() {
            Ok(Msg::Row(i, row)) => {
                slots[i] = Some(row);
                done += 1;
                let _ = sink.progress(done as u64, total as u64);
                // Flush the ready plan-order prefix.
                while next < total {
                    let Some(row) = slots[next].take() else { break };
                    let _ = sink.row(row);
                    next += 1;
                }
            }
            Ok(Msg::Fail(e)) => return Err(e),
            // Every worker hung up without delivering the full grid.
            Err(_) => return Err(ServeError::Shutdown),
        }
    }
    Ok(Reply::Done)
}

/// Drive one backend's sub-sweeps over a single connection — strictly
/// one at a time, so a client's sharded sweep consumes at most *one*
/// batch-lane admission slot per backend (exactly like the single
/// `Sweep` request it replaces; pipelining them would make a grid that
/// one node admits bounce `busy` behind a narrow `--batch-capacity`) —
/// translating rows to global plan positions. Any transport failure or
/// early stream end fails the whole sweep (a typed error, reported
/// once through the merge channel).
fn backend_worker(
    addr: &str,
    timeout: Duration,
    subs: Vec<SubSweep>,
    tx: &mpsc::SyncSender<Msg>,
) {
    let fail = |e: ServeError| {
        let _ = tx.send(Msg::Fail(e));
    };
    let mut client = match WireClient::connect(addr, timeout) {
        Ok(c) => c,
        Err(_) => return fail(ServeError::Shutdown),
    };
    for sub in subs {
        if client.send(&sub.req).is_err() {
            return fail(ServeError::Shutdown);
        }
        let mut slots = sub.slots;
        loop {
            match client.recv_frame(sub.req.id) {
                Ok(Frame::Row(row)) => {
                    let Some(slot) = slots.pop_front() else {
                        return fail(ServeError::BadRequest(
                            "backend emitted an unexpected sweep row".into(),
                        ));
                    };
                    if tx.send(Msg::Row(slot, row)).is_err() {
                        return; // merge already ended (failure elsewhere)
                    }
                }
                Ok(Frame::Progress { .. }) => {
                    // Per-backend progress is consolidated at the merge;
                    // the client sees one counter over the whole grid.
                }
                Ok(Frame::SearchRow(_)) => {
                    return fail(ServeError::BadRequest(
                        "backend emitted a search row during a sweep".into(),
                    ));
                }
                Ok(Frame::Final(Ok(_))) => {
                    if !slots.is_empty() {
                        return fail(ServeError::BadRequest(
                            "backend ended a sub-sweep before streaming every row".into(),
                        ));
                    }
                    break;
                }
                Ok(Frame::Final(Err(e))) => return fail(e),
                Err(_) => return fail(ServeError::Shutdown),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;
    use crate::sim::grid_configs;
    use crate::sim::Dataflow;

    #[test]
    fn shard_key_is_deterministic_and_price_relevant() {
        let cfg = SimConfig::with_size(16);
        // Pure function of its arguments: identical across calls (and,
        // because it never touches std's seeded hashers, across
        // processes of any build of this vocabulary).
        assert_eq!(shard_key("mobilenet-v2", &cfg), shard_key("mobilenet-v2", &cfg));
        let from_thread = std::thread::spawn({
            let cfg = cfg.clone();
            move || shard_key("mobilenet-v2", &cfg)
        })
        .join()
        .unwrap();
        assert_eq!(from_thread, shard_key("mobilenet-v2", &cfg));

        // Model identity and price-relevant fields move the key…
        assert_ne!(shard_key("mobilenet-v2", &cfg), shard_key("mnasnet-b1", &cfg));
        assert_ne!(shard_key("m", &cfg), shard_key("m", &SimConfig::with_size(32)));
        let throttled =
            SimConfig { enforce_dram_bw: true, dram_bw: 2.0, ..SimConfig::with_size(16) };
        assert_ne!(shard_key("m", &cfg), shard_key("m", &throttled));
        // …but frequency does not (it never changes cached pricing, so
        // frequency-only what-ifs stay on their warm backend).
        let fast = SimConfig { freq_mhz: 500, ..SimConfig::with_size(16) };
        assert_eq!(shard_key("m", &cfg), shard_key("m", &fast));
    }

    #[test]
    fn zoo_grid_distribution_never_starves_a_backend() {
        // Satellite acceptance: a zoo×config grid spreads across 2–4
        // backends with every shard taking a meaningful share.
        let grid = grid_configs(
            &[8, 16, 32, 64],
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
            &[true, false],
        );
        for n in 2..=4usize {
            let mut counts = vec![0usize; n];
            for name in models::ZOO_NAMES {
                for cfg in &grid {
                    counts[route(name, cfg, n)] += 1;
                }
            }
            let cells = models::ZOO_NAMES.len() * grid.len();
            for (b, &count) in counts.iter().enumerate() {
                assert!(
                    count * n * 4 >= cells,
                    "backend {b}/{n} starved: {count} of {cells} cells ({counts:?})"
                );
            }
        }
    }

    #[test]
    fn route_is_stable_under_backend_count() {
        let cfg = SimConfig::with_size(8);
        for n in 1..=8 {
            let b = route("mobilenet-v2", &cfg, n);
            assert!(b < n);
            // same inputs → same backend, every time
            assert_eq!(b, route("mobilenet-v2", &cfg, n));
        }
    }
}
