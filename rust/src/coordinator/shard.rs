//! Shard-router front tier: one [`Service`] that partitions traffic
//! across several `fuseconv serve` backends (`fuseconv shard
//! --backends addr1,addr2,...`).
//!
//! The paper's ST-OS argument — map *independent* work onto rows of the
//! array so every resource stays busy — has a direct serving analogue:
//! simulation traffic partitions cleanly by (model, price-relevant
//! config), so a front tier can pin each shard to one backend and keep
//! that backend's two-level layer cache permanently hot on its slice of
//! the keyspace. The router implements the same [`Service`] trait as
//! the single-node [`Router`](super::server::Router), so both wire
//! frontends (TCP in [`net`](super::net), HTTP/SSE in
//! [`http`](super::http)) mount it unchanged and the wire contract of
//! `PROTOCOL.md` §Sharded deployment + §Health, failover & membership
//! holds on every transport.
//!
//! Routing:
//! * `Simulate` pins to one backend by [`shard_key`] of
//!   (model name, price-relevant config fields) — a stable FNV-1a fold
//!   with an avalanche finish, deliberately *not* std's hasher, so the
//!   mapping survives process restarts and never depends on hasher
//!   seeding. The key picks its backend by **rendezvous hashing**
//!   ([`route`]): every (key, backend-address) pair scores
//!   independently and the highest score wins, so adding or removing
//!   one backend moves *only* the keys that score highest on the
//!   changed node — every other backend's layer/result caches stay
//!   warm across membership changes;
//! * `Sweep` splits the grid into per-backend **sub-plans** (each cell
//!   routes like the `Simulate` it replaces), fans them out
//!   concurrently, and re-multiplexes the backends' `row` streams back
//!   into **plan order** under the client's original request id with
//!   one consolidated `progress` counter — the reorder-buffer pattern
//!   of [`run_sweep_with`](crate::sim::run_sweep_with) — so a sharded
//!   sweep is frame-for-frame identical to a single-node sweep;
//! * `Stats` aggregates every live backend's counters (and reports how
//!   many backends contributed via [`StatsReply::backends`], plus the
//!   fleet view in [`StatsReply::backend_state`]); `Shutdown` fans out
//!   to every backend before the ack; `Infer`/`Zoo`/`Search` are
//!   unsharded and round-robin across backends.
//!
//! Self-healing: the fleet is *elastic*. Each backend carries a health
//! state (`Up`/`Suspect`/`Down`) driven by two signals — periodic
//! lightweight stats probes ([`ShardRouter::with_probes`]) and hard
//! transport failures observed by in-flight relays. A backend that dies
//! mid-sweep has its **remaining** sub-grid re-planned onto the
//! survivors mid-stream (the reorder-buffer merge tolerates rows from
//! anywhere; the deterministic simulator makes re-simulated rows
//! byte-identical), counted in [`StatsReply::failover_resteered`]; a
//! `Simulate` on a dead backend retries once on a survivor; an
//! in-flight `Search` on a dead backend fails typed (`err:shutdown`),
//! never hangs. Membership changes at runtime via the `add-backend` /
//! `drain-backend` admin ops (drain: stop routing new work, finish
//! in-flight, then remove). Only when *no* eligible backend remains
//! does traffic fail with a typed `shutdown` error — still never a
//! hang. Typed errors from a backend (`busy`, `bad_request`,
//! `deadline`) pass through verbatim and are never retried.
//!
//! ```
//! use fuseconv::coordinator::shard::{route, shard_key};
//! use fuseconv::sim::SimConfig;
//! let cfg = SimConfig::with_size(16);
//! // the routing key is a pure function: same (model, config) → same backend
//! assert_eq!(shard_key("mobilenet-v2", &cfg), shard_key("mobilenet-v2", &cfg));
//! let fleet = vec!["10.0.0.1:4242".to_string(), "10.0.0.2:4242".to_string()];
//! assert!(route("mobilenet-v2", &cfg, &fleet) < fleet.len());
//! ```

use super::net::{request_once, TransportGauges, WireClient};
use super::protocol::{
    ConfigPatch, Frame, FrameSink, ModelSpec, Reply, Request, RequestBody, Response,
    ServeError, Service, StatsReply, SweepRow, Ticket, PROTOCOL_VERSION, STREAM_BOUND,
};
use super::server::{Lane, LaneSlot};
use crate::sim::{FuseVariant, SimConfig, SweepPlan};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::thread;
use std::time::{Duration, Instant};

/// Default backend connect/receive timeout (matches the stream-forwarder
/// bound of the wire frontends: a silent backend becomes a typed error,
/// not a wedged stream).
pub const DEFAULT_BACKEND_TIMEOUT: Duration = Duration::from_secs(600);

/// Default bound on concurrently in-flight front-tier requests. The
/// router spawns one relay thread (plus backend connections) per
/// admitted request, so admission must shed load past a bound — a
/// request past it answers [`ServeError::Busy`], exactly like the
/// single node's bounded lanes — instead of growing threads and file
/// descriptors without limit.
pub const DEFAULT_SHARD_INFLIGHT: usize = 1024;

/// Default health-probe cadence (`fuseconv shard --probe-interval-ms`).
pub const DEFAULT_PROBE_INTERVAL: Duration = Duration::from_millis(1000);

/// Default consecutive probe failures before `Suspect` hardens into
/// `Down` (`fuseconv shard --probe-failures`).
pub const DEFAULT_PROBE_FAILURES: u32 = 3;

/// Cap on each backend's shutdown round-trip: the fan-out is
/// best-effort and concurrent, and one hung (accepted-but-silent)
/// backend must not stall the client's shutdown ack for the full
/// backend timeout.
const SHUTDOWN_FANOUT_TIMEOUT: Duration = Duration::from_secs(10);

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Final avalanche (splitmix64's mixer). FNV-1a alone is too regular to
/// route on: its low bit is a pure XOR-parity of the input bytes, so
/// routing on raw FNV would collapse (e.g. every *square* geometry of
/// one model on the same backend — rows and cols contribute identical
/// bytes and their parity cancels). The mixer diffuses every input bit
/// into every output bit before the rendezvous comparison.
fn mix(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Stable routing hash of one (model, config) shard: an FNV-1a fold
/// over the model name and exactly the price-relevant config fields
/// (the fields behind [`SimConfig::price_key`] — geometry, SRAM sizes,
/// element width, dataflow, ST-OS, mapping, and the memory model;
/// frequency is excluded because it never changes a backend's cached
/// pricing), finished with an avalanche mix. The whole computation is
/// self-contained — no `std` hasher — so the key is deterministic
/// across processes, restarts, and deployments of the same config
/// vocabulary.
pub fn shard_key(model: &str, cfg: &SimConfig) -> u64 {
    let mut h = fnv1a(0xcbf2_9ce4_8422_2325, model.as_bytes());
    for n in [
        cfg.rows as u64,
        cfg.cols as u64,
        cfg.ifmap_sram_kb as u64,
        cfg.weight_sram_kb as u64,
        cfg.ofmap_sram_kb as u64,
        cfg.bytes_per_elem as u64,
        cfg.dram_bw.to_bits(),
        cfg.dataflow as u64,
        cfg.stos as u64,
        cfg.mapping as u64,
        cfg.enforce_dram_bw as u64,
    ] {
        h = fnv1a(h, &n.to_le_bytes());
    }
    mix(h)
}

/// Rendezvous (highest-random-weight) pick: which of `backends` owns
/// `key`. Every (key, address) pair scores independently, so removing
/// one address re-homes *only* the keys it owned, and adding one steals
/// only the keys that score highest on it — ~1/n of the keyspace moves
/// per membership change instead of the (n-1)/n a modulo would move.
/// Ties break toward the lower index (deterministic for duplicate
/// addresses). Panics on an empty slice — membership emptiness is the
/// caller's typed-error case, not a routing case.
pub fn rendezvous_pick(key: u64, backends: &[String]) -> usize {
    assert!(!backends.is_empty(), "rendezvous over an empty backend set");
    let mut best = 0usize;
    let mut best_score = 0u64;
    for (i, addr) in backends.iter().enumerate() {
        let score = mix(key ^ mix(fnv1a(0xcbf2_9ce4_8422_2325, addr.as_bytes())));
        if i == 0 || score > best_score {
            best = i;
            best_score = score;
        }
    }
    best
}

/// Which of `backends` serves the (model, config) shard.
pub fn route(model: &str, cfg: &SimConfig, backends: &[String]) -> usize {
    rendezvous_pick(shard_key(model, cfg), backends)
}

/// The display name a [`ModelSpec`] routes by (zoo name or inline name).
fn model_name(m: &ModelSpec) -> &str {
    match m {
        ModelSpec::Zoo(name) => name,
        ModelSpec::Inline { name, .. } => name,
    }
}

// ---------------------------------------------------------------------------
// Fleet state
// ---------------------------------------------------------------------------

/// Health of one fleet member, as the front tier sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendState {
    /// Answering probes (or not yet observed to fail).
    Up,
    /// Failed recent probe(s), below the `Down` threshold. Still
    /// routed to — a suspect earns `Down` only through the threshold
    /// or a hard transport failure on live traffic.
    Suspect,
    /// Failed `--probe-failures` consecutive probes, or killed a live
    /// relay. Excluded from routing and stats aggregation until a
    /// probe succeeds again (recovery flips it straight back to `Up`).
    Down,
}

impl BackendState {
    fn label(self) -> &'static str {
        match self {
            BackendState::Up => "up",
            BackendState::Suspect => "suspect",
            BackendState::Down => "down",
        }
    }
}

/// One fleet member. `inflight` counts live relays (sweep workers,
/// simulate retries, proxies) so a draining member is removed exactly
/// when its last in-flight request finishes.
struct Member {
    addr: String,
    state: BackendState,
    draining: bool,
    consecutive_failures: u32,
    inflight: usize,
}

/// The mutable fleet: membership + health, shared by the service path,
/// the probe thread, and every in-flight relay. All mutation goes
/// through the one `RwLock`, so `inflight` is a plain counter.
struct FleetState {
    members: RwLock<Vec<Member>>,
    /// Sweep cells re-planned onto survivors + simulate retries.
    failover_resteered: AtomicU64,
    /// Failed health-probe round-trips.
    probe_failures: AtomicU64,
}

impl FleetState {
    fn new(addrs: Vec<String>) -> FleetState {
        let members = addrs
            .into_iter()
            .map(|addr| Member {
                addr,
                state: BackendState::Up,
                draining: false,
                consecutive_failures: 0,
                inflight: 0,
            })
            .collect();
        FleetState {
            members: RwLock::new(members),
            failover_resteered: AtomicU64::new(0),
            probe_failures: AtomicU64::new(0),
        }
    }

    fn lock_read(&self) -> std::sync::RwLockReadGuard<'_, Vec<Member>> {
        self.members.read().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_write(&self) -> std::sync::RwLockWriteGuard<'_, Vec<Member>> {
        self.members.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Addresses new work may route to: not `Down`, not draining.
    fn eligible(&self) -> Vec<String> {
        self.lock_read()
            .iter()
            .filter(|m| m.state != BackendState::Down && !m.draining)
            .map(|m| m.addr.clone())
            .collect()
    }

    /// Addresses believed alive (stats fan-out, cancel fan-out):
    /// everything not `Down` — draining members still answer.
    fn alive(&self) -> Vec<String> {
        self.lock_read()
            .iter()
            .filter(|m| m.state != BackendState::Down)
            .map(|m| m.addr.clone())
            .collect()
    }

    /// Every member address, regardless of state (probes, shutdown).
    fn all(&self) -> Vec<String> {
        self.lock_read().iter().map(|m| m.addr.clone()).collect()
    }

    /// The `backend_state` stats rendering: one `addr=state` entry per
    /// member, `draining` overriding the health label.
    fn render(&self) -> Vec<String> {
        self.lock_read()
            .iter()
            .map(|m| {
                let label = if m.draining { "draining" } else { m.state.label() };
                format!("{}={}", m.addr, label)
            })
            .collect()
    }

    /// Register one in-flight relay against `addr`; the guard's drop
    /// releases it (and completes a drain if it was the last one).
    fn track(self: &Arc<Self>, addr: &str) -> InflightGuard {
        if let Some(m) = self.lock_write().iter_mut().find(|m| m.addr == addr) {
            m.inflight += 1;
        }
        InflightGuard { fleet: Arc::clone(self), addr: addr.to_string() }
    }

    fn release(&self, addr: &str) {
        let mut members = self.lock_write();
        if let Some(i) = members.iter().position(|m| m.addr == addr) {
            members[i].inflight = members[i].inflight.saturating_sub(1);
            if members[i].draining && members[i].inflight == 0 {
                members.remove(i);
            }
        }
    }

    /// A live relay observed a hard transport failure on `addr`: take
    /// it out of routing immediately (probes may later revive it).
    fn mark_down(&self, addr: &str) {
        if let Some(m) = self.lock_write().iter_mut().find(|m| m.addr == addr) {
            m.state = BackendState::Down;
        }
    }

    /// Fold one probe round-trip into `addr`'s health: success resets
    /// straight to `Up` (recovery); failure counts toward `Suspect`,
    /// hardening into `Down` at `threshold` consecutive failures.
    fn record_probe(&self, addr: &str, ok: bool, threshold: u32) {
        let mut members = self.lock_write();
        let Some(m) = members.iter_mut().find(|m| m.addr == addr) else { return };
        if ok {
            m.consecutive_failures = 0;
            m.state = BackendState::Up;
        } else {
            self.probe_failures.fetch_add(1, Ordering::Relaxed);
            m.consecutive_failures = m.consecutive_failures.saturating_add(1);
            m.state = if m.consecutive_failures >= threshold.max(1) {
                BackendState::Down
            } else if m.state == BackendState::Up {
                BackendState::Suspect
            } else {
                m.state
            };
        }
    }

    /// `add-backend`: join (or rejoin) `addr`. Idempotent — an existing
    /// member is un-drained and reset to `Up` (the next probe or relay
    /// re-judges it).
    fn add(&self, addr: &str) {
        let mut members = self.lock_write();
        match members.iter_mut().find(|m| m.addr == addr) {
            Some(m) => {
                m.draining = false;
                m.state = BackendState::Up;
                m.consecutive_failures = 0;
            }
            None => members.push(Member {
                addr: addr.to_string(),
                state: BackendState::Up,
                draining: false,
                consecutive_failures: 0,
                inflight: 0,
            }),
        }
    }

    /// `drain-backend`: stop routing new work to `addr`; the member is
    /// removed when its in-flight count reaches zero (immediately, if
    /// idle). Idempotent; unknown addresses are a no-op.
    fn drain(&self, addr: &str) {
        let mut members = self.lock_write();
        if let Some(i) = members.iter().position(|m| m.addr == addr) {
            if members[i].inflight == 0 {
                members.remove(i);
            } else {
                members[i].draining = true;
            }
        }
    }

    fn resteered(&self, cells: u64) {
        self.failover_resteered.fetch_add(cells, Ordering::Relaxed);
    }
}

/// RAII in-flight marker for one (relay, backend) pair.
struct InflightGuard {
    fleet: Arc<FleetState>,
    addr: String,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.fleet.release(&self.addr);
    }
}

/// The probe thread: every `interval`, one lightweight `stats`
/// round-trip per member (capped at the interval so a black-holed
/// backend costs one cycle, not the full backend timeout), folded into
/// the fleet's health. Runs until `stop` trips (shutdown or drop).
fn probe_loop(fleet: Arc<FleetState>, stop: Arc<AtomicBool>, interval: Duration, threshold: u32) {
    let probe_timeout = interval.max(Duration::from_millis(10));
    loop {
        // Sleep in small chunks so shutdown never waits a full interval.
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::Acquire) {
                return;
            }
            let chunk = (interval - slept).min(Duration::from_millis(25));
            thread::sleep(chunk);
            slept += chunk;
        }
        for addr in fleet.all() {
            if stop.load(Ordering::Acquire) {
                return;
            }
            let probe = Request::new(0, RequestBody::Stats);
            let ok =
                matches!(request_once(&addr, &probe, probe_timeout), Ok(resp) if resp.result.is_ok());
            fleet.record_probe(&addr, ok, threshold);
        }
    }
}

// ---------------------------------------------------------------------------
// The router
// ---------------------------------------------------------------------------

/// The shard-router front tier. Holds the elastic fleet plus its own
/// bounded admission lane — every admitted request opens its own
/// backend connection(s) from a relay thread, so `call` never blocks
/// (all backend I/O happens off the admission path, exactly like the
/// single-node servers), and load past the lane bound sheds as
/// [`ServeError::Busy`].
pub struct ShardRouter {
    fleet: Arc<FleetState>,
    timeout: Duration,
    /// Round-robin cursor for the unsharded ops (`Infer`, `Zoo`,
    /// `Search`).
    rr: AtomicUsize,
    /// The front tier's own bounded admission (one slot per in-flight
    /// relay) — the same primitive as the single node's lanes.
    lane: Lane,
    /// Latched once a `Shutdown` has been accepted; later calls answer
    /// [`ServeError::Shutdown`], mirroring the single-node `Router`.
    closing: AtomicBool,
    /// The front tier's own live transport gauges, stamped onto
    /// aggregated stats replies. Backend gauges are deliberately *not*
    /// summed — gauges always describe the answering process.
    gauges: Option<TransportGauges>,
    /// Trips the probe thread (if one was started) on shutdown/drop.
    probe_stop: Arc<AtomicBool>,
}

impl ShardRouter {
    /// Front `backends` (at least one `host:port` address) with
    /// `timeout` bounding every backend connect/read/write.
    pub fn new(backends: Vec<String>, timeout: Duration) -> ShardRouter {
        assert!(!backends.is_empty(), "shard router needs at least one backend");
        ShardRouter {
            fleet: Arc::new(FleetState::new(backends)),
            timeout,
            rr: AtomicUsize::new(0),
            lane: Lane::new(DEFAULT_SHARD_INFLIGHT),
            closing: AtomicBool::new(false),
            gauges: None,
            probe_stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Report the frontends' live transport gauges in aggregated stats
    /// replies (the single-node `Router::with_gauges` counterpart).
    pub fn with_gauges(mut self, gauges: TransportGauges) -> ShardRouter {
        self.gauges = Some(gauges);
        self
    }

    /// Bound the front tier's own admission: once `capacity` requests
    /// are in flight, further calls answer [`ServeError::Busy`].
    /// Clamped to ≥ 1 — admission is always bounded.
    pub fn with_inflight(mut self, capacity: usize) -> ShardRouter {
        self.lane = Lane::new(capacity);
        self
    }

    /// Start the background health prober: every `interval`, one
    /// lightweight `stats` ping per member (round-trip capped at the
    /// interval), `threshold` consecutive failures hardening `Suspect`
    /// into `Down`. A zero `interval` disables probing (health then
    /// moves only on live-traffic transport failures). The thread stops
    /// when the router shuts down or is dropped.
    pub fn with_probes(self, interval: Duration, threshold: u32) -> ShardRouter {
        if interval.is_zero() {
            return self;
        }
        let fleet = Arc::clone(&self.fleet);
        let stop = Arc::clone(&self.probe_stop);
        thread::Builder::new()
            .name("fuseconv-shard-probe".into())
            .spawn(move || probe_loop(fleet, stop, interval, threshold))
            .expect("spawn shard probe");
        self
    }

    /// Current member addresses (any state, including draining).
    pub fn backends(&self) -> Vec<String> {
        self.fleet.all()
    }

    /// Has a `Shutdown` request been accepted?
    pub fn closing(&self) -> bool {
        self.closing.load(Ordering::Acquire)
    }

    /// Forward `req` to backend `addr` verbatim from a fresh thread,
    /// streaming every reply frame into `sink`. A hard transport
    /// failure additionally marks the backend `Down`.
    fn spawn_proxy(&self, addr: String, req: Request, sink: FrameSink, slot: Option<LaneSlot>) {
        let timeout = self.timeout;
        let fleet = Arc::clone(&self.fleet);
        thread::Builder::new()
            .name("fuseconv-shard-proxy".into())
            .spawn(move || {
                let _slot = slot;
                let _guard = fleet.track(&addr);
                if !proxy(&addr, timeout, &req, &sink) {
                    fleet.mark_down(&addr);
                }
            })
            .expect("spawn shard proxy");
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.probe_stop.store(true, Ordering::Release);
    }
}

impl Service for ShardRouter {
    fn call(&self, req: Request) -> Ticket {
        let id = req.id;
        let deadline_ms = req.deadline_ms;
        if self.closing() {
            return Ticket::immediate(Response::err(id, ServeError::Shutdown));
        }
        // Bounded admission (everything but `Shutdown`, which must stay
        // reachable): past `capacity` in-flight relays, shed load with a
        // typed Busy instead of spawning threads without limit.
        let slot = if matches!(req.body, RequestBody::Shutdown) {
            None
        } else if let Some(s) = self.lane.admit_slot() {
            Some(s)
        } else {
            return Ticket::immediate(Response::err(id, ServeError::Busy));
        };
        // Rebuild the forwarded request (same id + deadline) after the
        // routing decision; the body round-trips untouched.
        let forward = |body: RequestBody| {
            let mut fwd = Request::new(id, body);
            if let Some(ms) = deadline_ms {
                fwd = fwd.with_deadline_ms(ms);
            }
            fwd
        };
        match req.body {
            RequestBody::Simulate { model, variant, config } => {
                // Resolve the config up front: routing needs the
                // price-relevant fields, and a bad config answers
                // `bad_request` at admission exactly like a single node.
                let cfg = match config.to_config() {
                    Ok(c) => c,
                    Err(e) => return Ticket::immediate(Response::err(id, e)),
                };
                let name = model_name(&model).to_string();
                let (ticket, sink) = Ticket::pending(id);
                let fwd = forward(RequestBody::Simulate { model, variant, config });
                let fleet = Arc::clone(&self.fleet);
                let timeout = self.timeout;
                thread::Builder::new()
                    .name("fuseconv-shard-proxy".into())
                    .spawn(move || {
                        let _slot = slot;
                        simulate_failover(&fleet, timeout, &name, &cfg, &fwd, &sink);
                    })
                    .expect("spawn shard simulate");
                ticket
            }
            // `Search` is a single long-lived job, not a partitionable
            // grid: round-robin it onto one backend whole (its layer
            // traffic is spread across the whole OFA space, so no
            // backend's cache has an affinity edge) and relay the frame
            // stream — progress, live pareto rows, terminal reply —
            // verbatim. The relay also passes *disconnect* through: a
            // front-tier client that hangs up kills the proxy's backend
            // connection, and the backend cancels within a generation.
            // A backend that dies mid-search fails the stream typed
            // (`err:shutdown`, bounded by the timeout) — a search's
            // stream is stateful on its node, so it is never resteered.
            body @ (RequestBody::Infer { .. } | RequestBody::Zoo | RequestBody::Search { .. }) => {
                let (ticket, sink) = Ticket::pending(id);
                let eligible = self.fleet.eligible();
                if eligible.is_empty() {
                    sink.finish(Err(ServeError::Shutdown));
                    return ticket;
                }
                let b = self.rr.fetch_add(1, Ordering::Relaxed) % eligible.len();
                self.spawn_proxy(eligible[b].clone(), forward(body), sink, slot);
                ticket
            }
            RequestBody::Cancel { target } => {
                // The target stream was pinned to *one* backend, but the
                // front tier doesn't track which: fan the cancel out to
                // every live member. Cancel is idempotent (`Done` on
                // unknown ids), so the non-owners ack harmlessly.
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.fleet.alive();
                let timeout = self.timeout;
                thread::Builder::new()
                    .name("fuseconv-shard-cancel".into())
                    .spawn(move || {
                        let _slot = slot;
                        thread::scope(|s| {
                            for addr in &backends {
                                s.spawn(move || {
                                    let cancel =
                                        Request::new(id, RequestBody::Cancel { target });
                                    let _ = request_once(addr, &cancel, timeout);
                                });
                            }
                        });
                        sink.finish(Ok(Reply::Done));
                    })
                    .expect("spawn shard cancel");
                ticket
            }
            RequestBody::AddBackend { addr } => {
                if addr.is_empty() {
                    return Ticket::immediate(Response::err(
                        id,
                        ServeError::BadRequest("add-backend needs a non-empty address".into()),
                    ));
                }
                // Join immediately; membership is optimistic — if the
                // node is dead, probes (or the first relay) will mark it
                // Down and routing heals around it.
                self.fleet.add(&addr);
                Ticket::immediate(Response::ok(id, Reply::Done))
            }
            RequestBody::DrainBackend { addr } => {
                self.fleet.drain(&addr);
                Ticket::immediate(Response::ok(id, Reply::Done))
            }
            RequestBody::Stats => {
                let (ticket, sink) = Ticket::pending(id);
                let fleet = Arc::clone(&self.fleet);
                let timeout = self.timeout;
                let gauges = self.gauges.clone();
                thread::Builder::new()
                    .name("fuseconv-shard-stats".into())
                    .spawn(move || {
                        let _slot = slot;
                        // Aggregate over the members believed alive; a
                        // Down backend would only fail the fan-out.
                        let mut result = aggregate_stats(&fleet.alive(), timeout, id);
                        if let Ok(Reply::Stats(s)) = &mut result {
                            // counters are summed from the backends; the
                            // gauges + fleet view describe this front tier
                            if let Some(g) = &gauges {
                                g.overlay(s);
                            }
                            s.backend_state = fleet.render();
                            s.failover_resteered +=
                                fleet.failover_resteered.load(Ordering::Relaxed);
                            s.probe_failures += fleet.probe_failures.load(Ordering::Relaxed);
                        }
                        sink.finish(result);
                    })
                    .expect("spawn shard stats");
                ticket
            }
            RequestBody::Shutdown => {
                // Latch first so no new traffic is admitted while the
                // fan-out is in flight, then stop every backend —
                // concurrently and with a capped per-node round-trip,
                // so an already-dead or hung backend cannot stall the
                // ack for the rest — and ack. The frontend mounting
                // this router trips its own stop latch on the ack,
                // exactly as it does for the single-node router.
                self.closing.store(true, Ordering::Release);
                self.probe_stop.store(true, Ordering::Release);
                let (ticket, sink) = Ticket::pending(id);
                let backends = self.fleet.all();
                let timeout = if self.timeout.is_zero() {
                    SHUTDOWN_FANOUT_TIMEOUT
                } else {
                    self.timeout.min(SHUTDOWN_FANOUT_TIMEOUT)
                };
                thread::Builder::new()
                    .name("fuseconv-shard-shutdown".into())
                    .spawn(move || {
                        thread::scope(|s| {
                            for addr in &backends {
                                s.spawn(move || {
                                    let shutdown = Request::new(id, RequestBody::Shutdown);
                                    let _ = request_once(addr, &shutdown, timeout);
                                });
                            }
                        });
                        sink.finish(Ok(Reply::Done));
                    })
                    .expect("spawn shard shutdown");
                ticket
            }
            RequestBody::Sweep { models, variants, configs } => {
                let (ticket, sink) = Ticket::pending(id);
                let fleet = Arc::clone(&self.fleet);
                let timeout = self.timeout;
                let job = move || {
                    let _slot = slot;
                    sweep_fanout(fleet, timeout, models, variants, configs, deadline_ms, sink)
                };
                thread::Builder::new()
                    .name("fuseconv-shard-sweep".into())
                    .spawn(job)
                    .expect("spawn shard sweep");
                ticket
            }
        }
    }
}

/// One pinned `Simulate`, with single-retry failover: a hard transport
/// failure marks the backend `Down` and re-routes the request once onto
/// whichever survivor now owns the key (rendezvous re-pick). A second
/// transport failure — or an empty fleet — answers the typed
/// `shutdown` error; typed backend errors pass through unretried.
fn simulate_failover(
    fleet: &Arc<FleetState>,
    timeout: Duration,
    name: &str,
    cfg: &SimConfig,
    req: &Request,
    sink: &FrameSink,
) {
    let eligible = fleet.eligible();
    if eligible.is_empty() {
        sink.finish(Err(ServeError::Shutdown));
        return;
    }
    let addr = eligible[route(name, cfg, &eligible)].clone();
    {
        let _guard = fleet.track(&addr);
        if let Ok(resp) = request_once(&addr, req, timeout) {
            sink.finish(resp.result);
            return;
        }
    }
    fleet.mark_down(&addr);
    fleet.resteered(1);
    let survivors = fleet.eligible();
    if survivors.is_empty() {
        sink.finish(Err(ServeError::Shutdown));
        return;
    }
    let retry = survivors[route(name, cfg, &survivors)].clone();
    let _guard = fleet.track(&retry);
    match request_once(&retry, req, timeout) {
        Ok(resp) => sink.finish(resp.result),
        Err(_) => {
            fleet.mark_down(&retry);
            sink.finish(Err(ServeError::Shutdown));
        }
    }
}

/// The sweep thread's whole job: run the sharded sweep, translate a
/// panic into a typed error, and always terminate the stream.
fn sweep_fanout(
    fleet: Arc<FleetState>,
    timeout: Duration,
    models: Vec<String>,
    variants: Vec<FuseVariant>,
    configs: Vec<ConfigPatch>,
    deadline_ms: Option<u64>,
    sink: FrameSink,
) {
    let result = catch_unwind(AssertUnwindSafe(|| {
        sweep_sharded(&fleet, timeout, models, variants, configs, deadline_ms, &sink)
    }))
    .unwrap_or_else(|_| Err(ServeError::BadRequest("sharded sweep panicked".into())));
    sink.finish(result);
}

/// Forward one request over its own backend connection, relaying every
/// frame of the reply stream into `sink`. Returns `false` on a hard
/// transport failure (refused connection, dropped stream, silence past
/// the timeout — reported to the client as a typed terminal
/// `shutdown`); a typed backend error passes through verbatim and still
/// counts as a healthy transport.
fn proxy(addr: &str, timeout: Duration, req: &Request, sink: &FrameSink) -> bool {
    let mut client = match WireClient::connect(addr, timeout) {
        Ok(c) => c,
        Err(_) => {
            sink.finish(Err(ServeError::Shutdown));
            return false;
        }
    };
    if client.send(req).is_err() {
        sink.finish(Err(ServeError::Shutdown));
        return false;
    }
    loop {
        match client.recv_frame(req.id) {
            Ok(Frame::Final(result)) => {
                sink.finish(result);
                return true;
            }
            // A failed send means the front-tier client hung up. Stop
            // relaying and drop the backend connection: the backend's
            // transport sees the disconnect and cancels its stream, so
            // an abandoned search stops burning a whole node's pool.
            Ok(Frame::Progress { done, total }) => {
                if !sink.progress(done, total) {
                    return true;
                }
            }
            Ok(Frame::Row(row)) => {
                if !sink.row(row) {
                    return true;
                }
            }
            Ok(Frame::SearchRow(point)) => {
                if !sink.search_row(point) {
                    return true;
                }
            }
            Err(_) => {
                sink.finish(Err(ServeError::Shutdown));
                return false;
            }
        }
    }
}

/// `Stats` fan-out: the sum of every live backend's counters, stamped
/// with how many backends contributed. Backends are probed concurrently
/// — aggregate latency is one round-trip (and at worst one timeout),
/// not a sum over nodes — which also keeps `/healthz` probes through a
/// front tier cheap. A live backend that cannot answer fails the
/// aggregate with a typed error (partial counters would silently
/// under-report); `Down` members are excluded by the caller.
fn aggregate_stats(
    backends: &[String],
    timeout: Duration,
    id: u64,
) -> Result<Reply, ServeError> {
    let results: Vec<Result<Reply, ServeError>> = thread::scope(|s| {
        let probes: Vec<_> = backends
            .iter()
            .map(|addr| {
                s.spawn(move || {
                    let req = Request::new(id, RequestBody::Stats);
                    let resp = request_once(addr, &req, timeout)
                        .map_err(|_| ServeError::Shutdown)?;
                    resp.result
                })
            })
            .collect();
        probes.into_iter().map(|p| p.join().expect("stats probe")).collect()
    });
    let mut agg = StatsReply {
        protocol_version: PROTOCOL_VERSION,
        backends: backends.len() as u64,
        ..StatsReply::default()
    };
    for result in results {
        match result? {
            Reply::Stats(s) => {
                agg.infer_served += s.infer_served;
                agg.infer_batches += s.infer_batches;
                agg.sim_submitted += s.sim_submitted;
                agg.sim_completed += s.sim_completed;
                agg.cache_hits += s.cache_hits;
                agg.cache_misses += s.cache_misses;
                agg.cache_entries += s.cache_entries;
                // global result cache: counters sum like the layer
                // cache's; entries/bytes sum into fleet-wide residency
                // (hash-pinned keys make per-backend caches disjoint)
                agg.result_hits += s.result_hits;
                agg.result_misses += s.result_misses;
                agg.result_coalesced += s.result_coalesced;
                agg.result_evicted += s.result_evicted;
                agg.result_entries += s.result_entries;
                agg.result_bytes += s.result_bytes;
                agg.search_started += s.search_started;
                agg.search_completed += s.search_completed;
                agg.search_cancelled += s.search_cancelled;
                // fleet-health counters: direct nodes report 0, but a
                // nested front tier's tally still sums through
                agg.failover_resteered += s.failover_resteered;
                agg.probe_failures += s.probe_failures;
            }
            _ => {
                return Err(ServeError::BadRequest(
                    "backend answered stats with a non-stats reply".into(),
                ))
            }
        }
    }
    Ok(Reply::Stats(agg))
}

/// One grid cell in flight: its *global* plan position plus the
/// (model, variant, config) indices needed to re-plan it onto a
/// survivor if its backend dies before delivering the row.
#[derive(Debug, Clone, Copy)]
struct Cell {
    slot: usize,
    m: usize,
    v: usize,
    c: usize,
}

/// One per-backend sub-sweep: the request to send plus the cells its
/// rows will fill, in the order the backend will emit them (the
/// backend streams its own plan order, which maps 1:1 onto these
/// precomputed cells).
struct SubSweep {
    req: Request,
    cells: VecDeque<Cell>,
}

enum Msg {
    /// One row landed, destined for global plan position `usize`.
    Row(usize, SweepRow),
    /// A backend's transport died; `remaining` is the sub-grid it never
    /// delivered — the merge re-plans it onto the survivors.
    Died { addr: String, remaining: Vec<Cell> },
    /// A backend answered a *typed* error (busy, bad_request, deadline,
    /// …); the whole sharded sweep fails with it verbatim.
    Fail(ServeError),
}

/// Partition `cells` across `eligible` by rendezvous routing and build
/// each backend's sub-sweep requests: cells group by (model, variant)
/// in arrival order, so each group is expressible as one single-model,
/// single-variant `Sweep` whose row order matches the cell order.
fn plan_subs(
    cells: Vec<Cell>,
    models: &[String],
    variants: &[FuseVariant],
    patches: &[ConfigPatch],
    plan: &SweepPlan,
    eligible: &[String],
    deadline_ms: Option<u64>,
) -> Vec<(String, Vec<SubSweep>)> {
    let mut grouped: Vec<Vec<((usize, usize), Vec<Cell>)>> =
        (0..eligible.len()).map(|_| Vec::new()).collect();
    for cell in cells {
        let b = route(&models[cell.m], &plan.configs[cell.c], eligible);
        match grouped[b].iter_mut().find(|(k, _)| *k == (cell.m, cell.v)) {
            Some((_, cs)) => cs.push(cell),
            None => grouped[b].push(((cell.m, cell.v), vec![cell])),
        }
    }
    eligible
        .iter()
        .zip(grouped)
        .filter(|(_, groups)| !groups.is_empty())
        .map(|(addr, groups)| {
            let subs = groups
                .into_iter()
                .enumerate()
                .map(|(i, ((m, v), cs))| {
                    // Sub-request ids only need to be unique per backend
                    // connection; the merge re-keys every frame under
                    // the client's original id.
                    let mut req = Request::new(
                        i as u64 + 1,
                        RequestBody::Sweep {
                            models: vec![models[m].clone()],
                            variants: vec![variants[v]],
                            configs: cs.iter().map(|cell| patches[cell.c].clone()).collect(),
                        },
                    );
                    if let Some(ms) = deadline_ms {
                        req = req.with_deadline_ms(ms);
                    }
                    SubSweep { req, cells: cs.into() }
                })
                .collect();
            (addr.clone(), subs)
        })
        .collect()
}

/// One streamed sharded `Sweep`: validate the grid exactly like a
/// single node, split it into per-backend sub-plans, fan out, and merge
/// the backends' row streams back into plan order with one consolidated
/// progress counter. A backend that dies mid-stream has its undelivered
/// cells re-planned onto the survivors (repeatedly, if survivors keep
/// dying) — the sweep only fails typed when no eligible backend
/// remains, or a backend answers a typed error, or the request's own
/// deadline expires at the merge. Returns the terminal reply (`Done`;
/// rows already left through the sink).
fn sweep_sharded(
    fleet: &Arc<FleetState>,
    timeout: Duration,
    models: Vec<String>,
    variants: Vec<FuseVariant>,
    configs: Vec<ConfigPatch>,
    deadline_ms: Option<u64>,
    sink: &FrameSink,
) -> Result<Reply, ServeError> {
    // Validation mirrors the single-node sweep path, so error replies
    // (unknown model, bad config, empty grid) are identical on the wire.
    let networks = models
        .iter()
        .map(|m| ModelSpec::Zoo(m.clone()).resolve())
        .collect::<Result<Vec<_>, _>>()?;
    let cfgs = configs
        .iter()
        .map(|p| p.to_config())
        .collect::<Result<Vec<_>, _>>()?;
    let plan = SweepPlan::new(networks, variants.clone(), cfgs);
    if plan.is_empty() {
        return Err(ServeError::BadRequest("empty sweep grid".into()));
    }
    let total = plan.len();
    let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));

    // Every cell of the grid, in (model, variant, config) order, each
    // carrying its global plan position.
    let mut cells = Vec::with_capacity(total);
    for m in 0..models.len() {
        for v in 0..variants.len() {
            for c in 0..plan.configs.len() {
                cells.push(Cell { slot: plan.index_of(m, v, c), m, v, c });
            }
        }
    }

    // Up-front progress: the client learns the full grid size before
    // any backend answers, identical to the single-node stream.
    let _ = sink.progress(0, total as u64);

    // --- fan out ----------------------------------------------------
    // The merge channel is bounded so backpressure stays end to end: a
    // slow client pauses the merge, the merge pauses the workers, the
    // workers stop draining their backend sockets, and each backend's
    // own bounded writer pauses its sweep — no tier buffers unboundedly.
    // The merge keeps its own sender alive (workers respawn on
    // failover), so completion is tracked by row count, never by
    // channel hangup.
    let (tx, rx) = mpsc::sync_channel::<Msg>(STREAM_BOUND);
    let spawn_wave = |cells: Vec<Cell>| -> Result<(), ServeError> {
        let eligible = fleet.eligible();
        if eligible.is_empty() {
            return Err(ServeError::Shutdown);
        }
        for (addr, subs) in
            plan_subs(cells, &models, &variants, &configs, &plan, &eligible, deadline_ms)
        {
            let guard = fleet.track(&addr);
            let tx = tx.clone();
            thread::Builder::new()
                .name("fuseconv-shard-fanout".into())
                .spawn(move || {
                    let _guard = guard;
                    backend_worker(&addr, timeout, subs, &tx)
                })
                .expect("spawn shard fan-out");
        }
        Ok(())
    };
    spawn_wave(cells)?;

    // --- plan-order merge (the run_sweep_with reorder buffer) -------
    let mut slots: Vec<Option<SweepRow>> = (0..total).map(|_| None).collect();
    let mut next = 0usize;
    let mut done = 0usize;
    while done < total {
        let msg = match deadline {
            None => rx.recv().map_err(|_| ServeError::Shutdown)?,
            Some(d) => {
                match rx.recv_timeout(d.saturating_duration_since(Instant::now())) {
                    Ok(msg) => msg,
                    Err(mpsc::RecvTimeoutError::Timeout) => return Err(ServeError::Deadline),
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(ServeError::Shutdown)
                    }
                }
            }
        };
        match msg {
            Msg::Row(i, row) => {
                slots[i] = Some(row);
                done += 1;
                let _ = sink.progress(done as u64, total as u64);
                // Flush the ready plan-order prefix.
                while next < total {
                    let Some(row) = slots[next].take() else { break };
                    let _ = sink.row(row);
                    next += 1;
                }
            }
            Msg::Died { addr, remaining } => {
                // Failover: take the dead node out of routing and
                // re-plan everything it never delivered onto whichever
                // survivors now own those keys. Already-delivered cells
                // are not in `remaining`, so nothing duplicates; the
                // deterministic simulator makes the re-simulated rows
                // byte-identical to what the dead node would have sent.
                fleet.mark_down(&addr);
                if remaining.is_empty() {
                    continue;
                }
                fleet.resteered(remaining.len() as u64);
                spawn_wave(remaining)?;
            }
            Msg::Fail(e) => return Err(e),
        }
    }
    Ok(Reply::Done)
}

/// Drive one backend's sub-sweeps over a single connection — strictly
/// one at a time, so a client's sharded sweep consumes at most *one*
/// batch-lane admission slot per backend (exactly like the single
/// `Sweep` request it replaces; pipelining them would make a grid that
/// one node admits bounce `busy` behind a narrow `--batch-capacity`) —
/// translating rows to global plan positions. A hard transport failure
/// reports the undelivered cells as [`Msg::Died`] so the merge can
/// re-steer them; a typed backend error or protocol violation fails the
/// whole sweep via [`Msg::Fail`].
fn backend_worker(
    addr: &str,
    timeout: Duration,
    subs: Vec<SubSweep>,
    tx: &mpsc::SyncSender<Msg>,
) {
    let mut pending: VecDeque<SubSweep> = subs.into();
    let died = |current: VecDeque<Cell>, pending: VecDeque<SubSweep>| {
        let mut remaining: Vec<Cell> = current.into_iter().collect();
        for sub in pending {
            remaining.extend(sub.cells);
        }
        let _ = tx.send(Msg::Died { addr: addr.to_string(), remaining });
    };
    let fail = |e: ServeError| {
        let _ = tx.send(Msg::Fail(e));
    };
    let mut client = match WireClient::connect(addr, timeout) {
        Ok(c) => c,
        Err(_) => return died(VecDeque::new(), pending),
    };
    while let Some(sub) = pending.pop_front() {
        if client.send(&sub.req).is_err() {
            return died(sub.cells, pending);
        }
        let mut cells = sub.cells;
        loop {
            match client.recv_frame(sub.req.id) {
                Ok(Frame::Row(row)) => {
                    let Some(cell) = cells.pop_front() else {
                        return fail(ServeError::BadRequest(
                            "backend emitted an unexpected sweep row".into(),
                        ));
                    };
                    if tx.send(Msg::Row(cell.slot, row)).is_err() {
                        return; // merge already ended (failure elsewhere)
                    }
                }
                Ok(Frame::Progress { .. }) => {
                    // Per-backend progress is consolidated at the merge;
                    // the client sees one counter over the whole grid.
                }
                Ok(Frame::SearchRow(_)) => {
                    return fail(ServeError::BadRequest(
                        "backend emitted a search row during a sweep".into(),
                    ));
                }
                Ok(Frame::Final(Ok(_))) => {
                    if !cells.is_empty() {
                        return fail(ServeError::BadRequest(
                            "backend ended a sub-sweep before streaming every row".into(),
                        ));
                    }
                    break;
                }
                Ok(Frame::Final(Err(e))) => return fail(e),
                Err(_) => return died(cells, pending),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;
    use crate::sim::grid_configs;
    use crate::sim::Dataflow;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{}:4242", i + 1)).collect()
    }

    #[test]
    fn shard_key_is_deterministic_and_price_relevant() {
        let cfg = SimConfig::with_size(16);
        // Pure function of its arguments: identical across calls (and,
        // because it never touches std's seeded hashers, across
        // processes of any build of this vocabulary).
        assert_eq!(shard_key("mobilenet-v2", &cfg), shard_key("mobilenet-v2", &cfg));
        let from_thread = std::thread::spawn({
            let cfg = cfg.clone();
            move || shard_key("mobilenet-v2", &cfg)
        })
        .join()
        .unwrap();
        assert_eq!(from_thread, shard_key("mobilenet-v2", &cfg));

        // Model identity and price-relevant fields move the key…
        assert_ne!(shard_key("mobilenet-v2", &cfg), shard_key("mnasnet-b1", &cfg));
        assert_ne!(shard_key("m", &cfg), shard_key("m", &SimConfig::with_size(32)));
        let throttled =
            SimConfig { enforce_dram_bw: true, dram_bw: 2.0, ..SimConfig::with_size(16) };
        assert_ne!(shard_key("m", &cfg), shard_key("m", &throttled));
        // …but frequency does not (it never changes cached pricing, so
        // frequency-only what-ifs stay on their warm backend).
        let fast = SimConfig { freq_mhz: 500, ..SimConfig::with_size(16) };
        assert_eq!(shard_key("m", &cfg), shard_key("m", &fast));
    }

    #[test]
    fn every_dataflow_keys_a_disjoint_shard_slot() {
        // os / ws / is must rendezvous independently: a backend warm on
        // the os pricing of a model never also answers its is pricing
        // under the same key.
        let keys: Vec<u64> = crate::sim::config::ALL_DATAFLOWS
            .iter()
            .map(|&df| {
                let cfg = SimConfig { dataflow: df, ..SimConfig::with_size(16) };
                shard_key("espnet-c", &cfg)
            })
            .collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j], "dataflows {i} and {j} share a shard key");
            }
        }
    }

    #[test]
    fn zoo_grid_distribution_never_starves_a_backend() {
        // Satellite acceptance: a zoo×config grid spreads across 2–4
        // backends with every shard taking a meaningful share.
        let grid = grid_configs(
            &[8, 16, 32, 64],
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
            &[true, false],
        );
        for n in 2..=4usize {
            let fleet = addrs(n);
            let mut counts = vec![0usize; n];
            for name in models::ZOO_NAMES {
                for cfg in &grid {
                    counts[route(name, cfg, &fleet)] += 1;
                }
            }
            let cells = models::ZOO_NAMES.len() * grid.len();
            for (b, &count) in counts.iter().enumerate() {
                assert!(
                    count * n * 4 >= cells,
                    "backend {b}/{n} starved: {count} of {cells} cells ({counts:?})"
                );
            }
        }
    }

    #[test]
    fn route_is_stable_and_deterministic() {
        let cfg = SimConfig::with_size(8);
        for n in 1..=8 {
            let fleet = addrs(n);
            let b = route("mobilenet-v2", &cfg, &fleet);
            assert!(b < n);
            // same inputs → same backend, every time
            assert_eq!(b, route("mobilenet-v2", &cfg, &fleet));
        }
    }

    #[test]
    fn rendezvous_moves_only_the_changed_shard() {
        // The membership-change contract behind warm-cache resharding:
        // removing one backend re-homes exactly the keys it owned
        // (every other key keeps its backend), and adding one steals
        // keys only *for the new node* — no key moves between two
        // surviving backends.
        let grid = grid_configs(
            &[8, 12, 16, 24, 32, 48, 64, 96],
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
            &[true, false],
        );
        let fleet = addrs(4);
        let shrunk: Vec<String> =
            fleet.iter().filter(|a| **a != fleet[2]).cloned().collect();
        let grown: Vec<String> =
            fleet.iter().cloned().chain(["10.0.0.9:4242".to_string()]).collect();
        let mut moved_on_remove = 0usize;
        let mut moved_to_new = 0usize;
        let mut total = 0usize;
        for name in models::ZOO_NAMES {
            for cfg in &grid {
                total += 1;
                let before = &fleet[route(name, cfg, &fleet)];
                let after_remove = &shrunk[route(name, cfg, &shrunk)];
                if before == &fleet[2] {
                    moved_on_remove += 1; // must move — its owner left
                } else {
                    assert_eq!(
                        before, after_remove,
                        "{name}: key moved between surviving backends on remove"
                    );
                }
                let after_add = &grown[route(name, cfg, &grown)];
                if after_add == "10.0.0.9:4242" {
                    moved_to_new += 1;
                } else {
                    assert_eq!(
                        before, after_add,
                        "{name}: key moved between old backends on add"
                    );
                }
            }
        }
        // Both churn directions touch a real (≈1/n) share of the keys.
        assert!(moved_on_remove > 0 && moved_on_remove < total);
        assert!(moved_to_new > 0 && moved_to_new < total / 2);
    }

    #[test]
    fn fleet_membership_add_drain_and_health() {
        let fleet = Arc::new(FleetState::new(addrs(2)));
        assert_eq!(fleet.eligible().len(), 2);

        // add joins; add again is idempotent
        fleet.add("10.0.0.9:4242");
        fleet.add("10.0.0.9:4242");
        assert_eq!(fleet.eligible().len(), 3);

        // drain with no in-flight work removes immediately
        fleet.drain("10.0.0.9:4242");
        assert_eq!(fleet.eligible().len(), 2);
        assert_eq!(fleet.all().len(), 2);

        // drain with in-flight work: excluded from routing immediately,
        // removed when the last guard drops
        let a0 = fleet.all()[0].clone();
        let guard = fleet.track(&a0);
        fleet.drain(&a0);
        assert_eq!(fleet.eligible().len(), 1);
        assert!(fleet.render().iter().any(|e| e == &format!("{a0}=draining")));
        assert_eq!(fleet.all().len(), 2, "draining member stays until idle");
        drop(guard);
        assert_eq!(fleet.all().len(), 1, "drain completes when in-flight hits zero");

        // probes: below threshold → Suspect (still routed), at
        // threshold → Down (excluded), success → straight back to Up
        let a1 = fleet.all()[0].clone();
        fleet.record_probe(&a1, false, 2);
        assert!(fleet.render().iter().any(|e| e.ends_with("=suspect")));
        assert_eq!(fleet.eligible().len(), 1, "suspect members still route");
        fleet.record_probe(&a1, false, 2);
        assert!(fleet.render().iter().any(|e| e.ends_with("=down")));
        assert_eq!(fleet.eligible().len(), 0);
        assert_eq!(fleet.probe_failures.load(Ordering::Relaxed), 2);
        fleet.record_probe(&a1, true, 2);
        assert!(fleet.render().iter().any(|e| e.ends_with("=up")), "recovery");
        assert_eq!(fleet.eligible().len(), 1);
    }
}
