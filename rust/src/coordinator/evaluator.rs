//! Network evaluation services on top of the simulator.
//!
//! Two layers of reuse make search affordable:
//! * the sweep engine's sharded [`LayerCache`] (identical (op, h, w, cfg)
//!   → same `LayerSim`), shareable across evaluators, configs, and the
//!   worker pool;
//! * `HybridSpace`, which pre-simulates each bottleneck block in both its
//!   depthwise and FuSe form so evaluating one EA genome is a vector sum
//!   instead of a network simulation.

use crate::nn::{fuse_network, Layer, Network, Selection, Variant};
use crate::sim::{simulate_network_cached, LayerCache, LayerSim, NetworkSim, SimConfig};
use std::sync::Arc;

/// Memoizing evaluator for one hardware configuration. The cache is the
/// sweep engine's — pass a shared one via [`Evaluator::with_cache`] to
/// price layers once across every evaluator/config in the process.
pub struct Evaluator {
    pub cfg: SimConfig,
    cache: Arc<LayerCache>,
}

/// Whole-network evaluation summary.
#[derive(Debug, Clone)]
pub struct NetEval {
    pub name: String,
    pub cycles: u64,
    pub latency_ms: f64,
    pub macs: u64,
    pub params: u64,
}

impl Evaluator {
    pub fn new(cfg: SimConfig) -> Evaluator {
        Evaluator::with_cache(cfg, Arc::new(LayerCache::new()))
    }

    /// Share an existing layer cache (e.g. the sweep engine's or the sim
    /// server's) so identical layers are priced once process-wide.
    pub fn with_cache(cfg: SimConfig, cache: Arc<LayerCache>) -> Evaluator {
        Evaluator { cfg, cache }
    }

    /// Cycles for one layer (cached). Uses the clone-free shared-result
    /// path — this is the search hot loop.
    pub fn layer_cycles(&self, l: &Layer) -> u64 {
        self.cache.simulate_shared(l, &self.cfg).total_cycles
    }

    /// Full layer simulation when the detail is needed (also cached).
    pub fn layer_detail(&self, l: &Layer) -> LayerSim {
        self.cache.simulate(l, &self.cfg)
    }

    /// Whole-network simulation through the shared cache — identical to
    /// `simulate_network` but priced once per distinct layer anywhere in
    /// the process. The serving path uses this for detail queries.
    pub fn net_sim(&self, net: &Network) -> NetworkSim {
        simulate_network_cached(net, &self.cfg, &self.cache)
    }

    pub fn eval(&self, net: &Network) -> NetEval {
        let cycles: u64 = net.layers.iter().map(|l| self.layer_cycles(l)).sum();
        NetEval {
            name: net.name.clone(),
            cycles,
            latency_ms: self.cfg.cycles_to_ms(cycles),
            macs: net.total_macs(),
            params: net.total_params(),
        }
    }

    /// Distinct priced layers resident in the underlying cache (spans every
    /// evaluator sharing it).
    pub fn cache_len(&self) -> usize {
        self.cache.stats().entries
    }

    pub fn cache(&self) -> &Arc<LayerCache> {
        &self.cache
    }
}

/// Pre-factored hybrid search space over one base network: per bottleneck
/// block, the cycle/param/mac cost in depthwise form vs FuSe-Half form.
/// Evaluating a genome (bitmask) is O(#blocks).
#[derive(Debug, Clone)]
pub struct HybridSpace {
    pub base: Network,
    pub blocks: Vec<usize>,
    /// Cycles of block b with depthwise / with FuSe-Half.
    pub dw_cycles: Vec<u64>,
    pub fuse_cycles: Vec<u64>,
    pub dw_macs: Vec<u64>,
    pub fuse_macs: Vec<u64>,
    pub dw_params: Vec<u64>,
    pub fuse_params: Vec<u64>,
    /// Everything outside bottleneck blocks.
    pub fixed_cycles: u64,
    pub fixed_macs: u64,
    pub fixed_params: u64,
    pub cfg: SimConfig,
}

impl HybridSpace {
    pub fn new(base: &Network, ev: &Evaluator) -> HybridSpace {
        let fused = fuse_network(base, Variant::Half, &Selection::All);
        let blocks = base.bottleneck_blocks();

        let block_stats = |net: &Network, b: usize| -> (u64, u64, u64) {
            let ls: Vec<&Layer> = net.layers.iter().filter(|l| l.block == Some(b)).collect();
            (
                ls.iter().map(|l| ev.layer_cycles(l)).sum(),
                ls.iter().map(|l| l.macs()).sum(),
                ls.iter().map(|l| l.params()).sum(),
            )
        };

        let mut dw_cycles = Vec::new();
        let mut fuse_cycles = Vec::new();
        let mut dw_macs = Vec::new();
        let mut fuse_macs = Vec::new();
        let mut dw_params = Vec::new();
        let mut fuse_params = Vec::new();
        for &b in &blocks {
            let (c, m, p) = block_stats(base, b);
            dw_cycles.push(c);
            dw_macs.push(m);
            dw_params.push(p);
            let (c, m, p) = block_stats(&fused, b);
            fuse_cycles.push(c);
            fuse_macs.push(m);
            fuse_params.push(p);
        }
        let fixed: Vec<&Layer> = base.layers.iter().filter(|l| l.block.is_none()).collect();
        HybridSpace {
            base: base.clone(),
            blocks,
            dw_cycles,
            fuse_cycles,
            dw_macs,
            fuse_macs,
            dw_params,
            fuse_params,
            fixed_cycles: fixed.iter().map(|l| ev.layer_cycles(l)).sum(),
            fixed_macs: fixed.iter().map(|l| l.macs()).sum(),
            fixed_params: fixed.iter().map(|l| l.params()).sum(),
            cfg: ev.cfg.clone(),
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Cycles of the hybrid selected by `mask` (true = FuSe).
    pub fn cycles(&self, mask: &[bool]) -> u64 {
        assert_eq!(mask.len(), self.num_blocks());
        let mut c = self.fixed_cycles;
        for (i, &m) in mask.iter().enumerate() {
            c += if m { self.fuse_cycles[i] } else { self.dw_cycles[i] };
        }
        c
    }

    pub fn latency_ms(&self, mask: &[bool]) -> f64 {
        self.cfg.cycles_to_ms(self.cycles(mask))
    }

    pub fn macs(&self, mask: &[bool]) -> u64 {
        let mut v = self.fixed_macs;
        for (i, &m) in mask.iter().enumerate() {
            v += if m { self.fuse_macs[i] } else { self.dw_macs[i] };
        }
        v
    }

    pub fn params(&self, mask: &[bool]) -> u64 {
        let mut v = self.fixed_params;
        for (i, &m) in mask.iter().enumerate() {
            v += if m { self.fuse_params[i] } else { self.dw_params[i] };
        }
        v
    }

    /// Realize the mask as an actual network (for reporting/inspection).
    pub fn realize(&self, mask: &[bool]) -> Network {
        fuse_network(&self.base, Variant::Half, &Selection::Mask(mask.to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::mobilenet_v3;
    use crate::sim::simulate_network;

    #[test]
    fn evaluator_matches_direct_simulation() {
        let ev = Evaluator::new(SimConfig::default());
        let net = mobilenet_v3::small();
        let e = ev.eval(&net);
        let s = simulate_network(&net, &SimConfig::default());
        assert_eq!(e.cycles, s.total_cycles);
        assert_eq!(e.macs, net.total_macs());
    }

    #[test]
    fn net_sim_matches_uncached_simulation() {
        let ev = Evaluator::new(SimConfig::default());
        let net = mobilenet_v3::small();
        let cached = ev.net_sim(&net);
        let direct = simulate_network(&net, &SimConfig::default());
        assert_eq!(cached.total_cycles, direct.total_cycles);
        assert_eq!(cached.layers.len(), direct.layers.len());
        assert_eq!(cached.num_pes, direct.num_pes);
        // and it agrees with the fast path
        assert_eq!(cached.total_cycles, ev.eval(&net).cycles);
    }

    #[test]
    fn cache_hits_across_evals() {
        let ev = Evaluator::new(SimConfig::default());
        let net = mobilenet_v3::small();
        ev.eval(&net);
        let n1 = ev.cache_len();
        ev.eval(&net); // second run: all hits
        assert_eq!(ev.cache_len(), n1);
        assert!(n1 <= net.layers.len());
    }

    #[test]
    fn evaluators_share_one_cache_across_configs() {
        use crate::sim::LayerCache;
        use std::sync::Arc;
        let cache = Arc::new(LayerCache::new());
        let ev16 = Evaluator::with_cache(SimConfig::default(), Arc::clone(&cache));
        let ev32 = Evaluator::with_cache(SimConfig::with_size(32), Arc::clone(&cache));
        let net = mobilenet_v3::small();
        ev16.eval(&net);
        let after16 = cache.stats().entries;
        ev32.eval(&net);
        // different config hash ⇒ new entries in the same shared cache
        assert!(cache.stats().entries > after16);
        // and both evaluators report the shared total
        assert_eq!(ev16.cache_len(), ev32.cache_len());
        // re-evaluating is pure hits
        let misses = cache.stats().misses;
        ev16.eval(&net);
        ev32.eval(&net);
        assert_eq!(cache.stats().misses, misses);
    }

    #[test]
    fn hybrid_space_extremes_match_full_networks() {
        let ev = Evaluator::new(SimConfig::default());
        let base = mobilenet_v3::small();
        let space = HybridSpace::new(&base, &ev);
        let n = space.num_blocks();

        // all-false == baseline
        let all_dw = vec![false; n];
        assert_eq!(space.cycles(&all_dw), ev.eval(&base).cycles);
        assert_eq!(space.macs(&all_dw), base.total_macs());
        assert_eq!(space.params(&all_dw), base.total_params());

        // all-true == FuSe-Half
        let all_fuse = vec![true; n];
        let fused = crate::nn::fuse_all(&base, Variant::Half);
        assert_eq!(space.cycles(&all_fuse), ev.eval(&fused).cycles);
        assert_eq!(space.macs(&all_fuse), fused.total_macs());
    }

    #[test]
    fn hybrid_monotone_in_mask() {
        // converting more blocks can only reduce cycles (FuSe ≤ dw per block)
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::small(), &ev);
        let n = space.num_blocks();
        let mut mask = vec![false; n];
        let mut prev = space.cycles(&mask);
        for i in 0..n {
            mask[i] = true;
            let cur = space.cycles(&mask);
            assert!(cur <= prev, "block {i} increased cycles");
            prev = cur;
        }
    }

    #[test]
    fn realize_matches_fast_path() {
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::small(), &ev);
        let n = space.num_blocks();
        let mask: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
        let net = space.realize(&mask);
        assert_eq!(ev.eval(&net).cycles, space.cycles(&mask));
    }
}
