//! Serving loops behind the unified [`Service`] trait.
//!
//! * [`Server`] — batched inference: a dispatcher thread drains the
//!   dynamic batcher and drives an [`Engine`] (the PJRT executable in
//!   production, [`MockEngine`] in tests and `--engine mock` mode).
//!   Admission is a *bounded* queue: a full queue answers
//!   [`ServeError::Busy`] instead of growing without limit.
//! * [`SimServer`] — simulation-as-a-service: scenario requests
//!   (model × variant × config) fan out across the worker pool through
//!   the sweep engine's shared layer cache. Admission is split into two
//!   priority lanes with separate bounds — interactive `Simulate` point
//!   queries and batch `Sweep` grids — so EA/NAS sweep traffic can fill
//!   its lane without ever starving dashboard queries. A `Sweep` is
//!   served as a *stream*: `Progress`/`Row` frames as the sweep engine
//!   completes cells (plan order), then a terminal `Done`.
//! * [`Router`] — one [`Service`] fronting both, shared by every
//!   transport: the TCP frame frontend (`coordinator::net`), the
//!   HTTP/SSE frontend (`coordinator::http`), and `fuseconv serve`
//!   (which can run both listeners on one `Router`).
//!
//! Both halves speak only protocol types: requests arrive as
//! [`Request`]s and leave as [`Frame`](super::protocol::Frame) streams
//! through [`Ticket`]s, whether the caller is in-process or a wire
//! client — so every transport prices a scenario identically.
//!
//! ```
//! use fuseconv::coordinator::batcher::BatchPolicy;
//! use fuseconv::coordinator::{MockEngine, Reply, Server};
//! let server = Server::start(MockEngine::new(2, 1, 4), BatchPolicy::default());
//! let resp = server.submit(vec![1.0, 2.0]).wait();
//! assert!(matches!(resp.result, Ok(Reply::Infer(_))));
//! server.shutdown();
//! ```

use super::batcher::{BatchPolicy, Batcher, Pending};
use super::evaluator::Evaluator;
use super::net::TransportGauges;
use super::protocol::{
    ConfigPatch, FrameSink, InferReply, ModelSpec, Priority, Reply, Request, RequestBody,
    Response, SearchPoint, SearchReply, SearchSpec, ServeError, Service, SimSummary,
    StatsReply, SweepRow, Ticket, ZooEntry, PROTOCOL_VERSION,
};
use super::search::{run_nas_with, NasCandidate, NasConfig, SearchEvent};
use crate::exec::{CancelToken, Pool};
use crate::nn::models;
use crate::sim::{
    run_sweep_coalesced, simulate_network_cached, CacheStats, FuseVariant, LayerCache,
    ResultCache, ResultCacheStats, SweepEvent, SweepOutcome, SweepPlan, SweepRecord,
};
use crate::stats::Summary;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Something that can run a batch of flattened image tensors.
///
/// Implementations need not be `Send` — the PJRT client is thread-bound —
/// so the server constructs the engine *inside* its dispatcher thread via
/// [`Server::start_with`].
pub trait Engine: 'static {
    /// Elements per single input (e.g. 3·H·W).
    fn input_len(&self) -> usize;
    /// Elements per single output (e.g. #classes).
    fn output_len(&self) -> usize;
    /// Largest batch the compiled executable accepts.
    fn max_batch(&self) -> usize;
    /// Run one batch: `inputs.len() == n × input_len()`; must return
    /// `n × output_len()` elements.
    fn infer(&self, inputs: &[f32], n: usize) -> Vec<f32>;
}

/// Deterministic arithmetic engine — no artifacts required:
/// `output[j·out_len + k] = Σ input_j + k`. Backs `fuseconv serve
/// --engine mock`, the wire integration tests, and the unit tests here.
pub struct MockEngine {
    pub in_len: usize,
    pub out_len: usize,
    pub max_b: usize,
    pub delay: Duration,
}

impl MockEngine {
    pub fn new(in_len: usize, out_len: usize, max_b: usize) -> MockEngine {
        MockEngine { in_len, out_len, max_b, delay: Duration::ZERO }
    }
}

impl Engine for MockEngine {
    fn input_len(&self) -> usize {
        self.in_len
    }
    fn output_len(&self) -> usize {
        self.out_len
    }
    fn max_batch(&self) -> usize {
        self.max_b
    }
    fn infer(&self, inputs: &[f32], n: usize) -> Vec<f32> {
        if !self.delay.is_zero() {
            thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(n * self.out_len);
        for j in 0..n {
            let s: f32 = inputs[j * self.in_len..(j + 1) * self.in_len].iter().sum();
            for k in 0..self.out_len {
                out.push(s + k as f32);
            }
        }
        out
    }
}

/// Serving statistics, accumulated by the dispatcher and returned by
/// [`Server::shutdown`]. Live counters for `Stats` requests are kept
/// separately (atomics shared with the [`Server`] handle).
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub latencies_us: Vec<f64>,
    pub batch_sizes: Vec<f64>,
}

impl ServerStats {
    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies_us.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_us))
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<f64>() / self.batch_sizes.len() as f64
        }
    }
}

/// Default bound on the inference admission queue.
pub const DEFAULT_INFER_QUEUE: usize = 1024;

/// One admitted inference job (internal to the dispatcher). The reply
/// sink carries the request id.
struct InferJob {
    input: Vec<f32>,
    deadline: Option<Instant>,
    reply: FrameSink,
    accepted: Instant,
}

enum ServerMsg {
    Req(InferJob),
    Shutdown,
}

/// Handle to a running batched-inference server.
pub struct Server {
    tx: mpsc::SyncSender<ServerMsg>,
    dispatcher: Option<thread::JoinHandle<ServerStats>>,
    next_id: AtomicU64,
    served: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
}

impl Server {
    /// Start with an engine constructed on the dispatcher thread (required
    /// for thread-bound engines like the PJRT one) and the default
    /// admission-queue bound.
    pub fn start_with<E, F>(factory: F, policy: BatchPolicy) -> Server
    where
        E: Engine,
        F: FnOnce() -> E + Send + 'static,
    {
        Server::start_with_queue(factory, policy, DEFAULT_INFER_QUEUE)
    }

    /// As [`Server::start_with`], with an explicit admission-queue bound:
    /// once `queue` requests are admitted-but-undispatched, further calls
    /// answer [`ServeError::Busy`].
    pub fn start_with_queue<E, F>(factory: F, policy: BatchPolicy, queue: usize) -> Server
    where
        E: Engine,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<ServerMsg>(queue.max(1));
        let served = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let (served2, batches2) = (Arc::clone(&served), Arc::clone(&batches));
        let dispatcher = thread::Builder::new()
            .name("fuseconv-dispatch".into())
            .spawn(move || dispatch_loop(factory(), policy, rx, served2, batches2))
            .expect("spawn dispatcher");
        Server { tx, dispatcher: Some(dispatcher), next_id: 0.into(), served, batches }
    }

    /// Convenience for `Send` engines.
    pub fn start<E: Engine + Send>(engine: E, policy: BatchPolicy) -> Server {
        Server::start_with(move || engine, policy)
    }

    /// Submit one input under a server-assigned request id.
    pub fn submit(&self, input: Vec<f32>) -> Ticket {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.call(Request::new(id, RequestBody::Infer { input }))
    }

    /// Requests completed since start (live; for `Stats`).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Batches dispatched since start (live; for `Stats`).
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Stop the dispatcher (draining the queue) and collect statistics.
    pub fn shutdown(mut self) -> ServerStats {
        let _ = self.tx.send(ServerMsg::Shutdown);
        self.dispatcher.take().expect("not yet shut down").join().expect("dispatcher join")
    }
}

impl Service for Server {
    fn call(&self, req: Request) -> Ticket {
        let id = req.id;
        match req.body {
            RequestBody::Infer { input } => {
                let deadline =
                    req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
                let (ticket, reply) = Ticket::pending(id);
                let job = InferJob { input, deadline, reply, accepted: Instant::now() };
                match self.tx.try_send(ServerMsg::Req(job)) {
                    Ok(()) => ticket,
                    Err(mpsc::TrySendError::Full(_)) => {
                        Ticket::immediate(Response::err(id, ServeError::Busy))
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        Ticket::immediate(Response::err(id, ServeError::Shutdown))
                    }
                }
            }
            other => Ticket::immediate(Response::err(
                id,
                ServeError::BadRequest(format!(
                    "inference server cannot serve {:?} requests",
                    other.op()
                )),
            )),
        }
    }
}

fn dispatch_loop<E: Engine>(
    engine: E,
    policy: BatchPolicy,
    rx: mpsc::Receiver<ServerMsg>,
    served: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
) -> ServerStats {
    let mut batcher: Batcher<InferJob> = Batcher::new(BatchPolicy {
        max_batch: policy.max_batch.min(engine.max_batch()).max(1),
        ..policy
    });
    let mut stats = ServerStats::default();
    let mut open = true;

    while open || !batcher.is_empty() {
        // Pull what's available without exceeding the batch deadline.
        let now = Instant::now();
        let wait = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        if open {
            match rx.recv_timeout(wait) {
                // Arrival is stamped at *admission*, so time spent in the
                // bounded channel counts against max_wait too.
                Ok(ServerMsg::Req(j)) => {
                    let at = j.accepted;
                    batcher.push_at(j, at);
                }
                Ok(ServerMsg::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain anything else queued
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    ServerMsg::Req(j) => {
                        let at = j.accepted;
                        batcher.push_at(j, at);
                    }
                    ServerMsg::Shutdown => open = false,
                }
            }
        }

        let now = Instant::now();
        if batcher.ready(now) || (!open && !batcher.is_empty()) {
            let batch = batcher.take_batch();
            // Typed rejections before the engine sees the batch: malformed
            // inputs and expired deadlines never panic the dispatcher.
            let in_len = engine.input_len();
            let mut live: Vec<Pending<InferJob>> = Vec::with_capacity(batch.len());
            for p in batch {
                if p.item.input.len() != in_len {
                    p.item.reply.finish(Err(ServeError::BadRequest(format!(
                        "input length {} != engine input length {}",
                        p.item.input.len(),
                        in_len
                    ))));
                } else if p.item.deadline.is_some_and(|d| now > d) {
                    p.item.reply.finish(Err(ServeError::Deadline));
                } else {
                    live.push(p);
                }
            }
            if live.is_empty() {
                continue;
            }
            let n = live.len();
            let mut flat = Vec::with_capacity(n * in_len);
            for p in &live {
                flat.extend_from_slice(&p.item.input);
            }
            let t0 = Instant::now();
            let out = engine.infer(&flat, n);
            let infer_us = t0.elapsed().as_micros() as u64;
            let out_len = engine.output_len();
            assert_eq!(out.len(), n * out_len, "engine output length");
            let done = Instant::now();
            stats.batches += 1;
            batches.fetch_add(1, Ordering::Relaxed);
            stats.batch_sizes.push(n as f64);
            for (i, p) in live.into_iter().enumerate() {
                let latency_us = done.duration_since(p.arrived).as_micros() as u64;
                // Queue time = everything that wasn't the engine run.
                let queue_us = latency_us.saturating_sub(infer_us);
                let reply = InferReply {
                    output: out[i * out_len..(i + 1) * out_len].to_vec(),
                    queue_us,
                    batch_size: n,
                    latency_us,
                };
                stats.served += 1;
                served.fetch_add(1, Ordering::Relaxed);
                stats.latencies_us.push(latency_us as f64);
                p.item.reply.finish(Ok(Reply::Infer(reply)));
            }
        }
    }
    stats
}

// ---------------------------------------------------------------------------
// Simulation serving
// ---------------------------------------------------------------------------

/// Default bound on concurrently admitted interactive simulation jobs.
pub const DEFAULT_SIM_CAPACITY: usize = 256;

/// Default bound on concurrently admitted batch (`Sweep`) jobs. Each
/// sweep is a whole grid, so the lane is much narrower than the
/// interactive one.
pub const DEFAULT_BATCH_CAPACITY: usize = 32;

/// Default bound on concurrently admitted `Search` jobs. A search is a
/// multi-minute evolutionary run that owns a worker pool for its whole
/// lifetime, so the lane is the narrowest of the three — searches can
/// never starve sweeps or point queries, and vice versa.
pub const DEFAULT_SEARCH_CAPACITY: usize = 4;

/// Cooperative-cancellation registry: client request id → the
/// [`CancelToken`]s of every live stream admitted under that id. A
/// `cancel` request trips all of them (ids are per-connection counters,
/// so distinct clients may collide — tripping both is the safe
/// reading); each stream deregisters its own token (pointer equality)
/// when it finishes, so cancel-after-final is a no-op.
type CancelRegistry = Arc<Mutex<HashMap<u64, Vec<CancelToken>>>>;


/// One bounded admission lane: a capacity plus its in-flight counter.
/// The counter is shared (`Arc`) with worker closures that release the
/// slot on completion (or with a [`LaneSlot`] RAII guard for callers
/// outside this module — the shard front tier bounds its own admission
/// on the same primitive).
pub(crate) struct Lane {
    capacity: usize,
    inflight: Arc<AtomicUsize>,
}

/// RAII admission slot: dropping it releases one unit of its lane's
/// in-flight budget, however the holder finishes.
pub(crate) struct LaneSlot(Arc<AtomicUsize>);

impl Drop for LaneSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

impl Lane {
    pub(crate) fn new(capacity: usize) -> Lane {
        Lane { capacity: capacity.max(1), inflight: Arc::new(AtomicUsize::new(0)) }
    }

    /// Try to take one admission slot (released manually through the
    /// shared `inflight` counter).
    fn admit(&self) -> bool {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.capacity {
                return false;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Try to take one admission slot as an RAII guard.
    pub(crate) fn admit_slot(&self) -> Option<LaneSlot> {
        if self.admit() {
            Some(LaneSlot(Arc::clone(&self.inflight)))
        } else {
            None
        }
    }
}

/// Simulation-serving handle: protocol requests in, [`Ticket`] frame
/// streams out. All workers share one sweep-engine layer cache, so a
/// traffic mix that revisits models/configs (EA populations, dashboard
/// queries, repeated what-if scenarios) degenerates to cache lookups.
///
/// Admission is two-lane (see [`RequestBody::priority`]): interactive
/// `Simulate` point queries and batch `Sweep` grids are bounded
/// separately, so a lane full of sweeps still admits point queries. The
/// isolation holds at *execution* too, not just admission: point
/// queries run on a dedicated pool (`ipool`, half the batch width), so
/// they never queue behind the hundreds of grid cells an admitted sweep
/// fans out onto the batch pool.
pub struct SimServer {
    /// Batch pool: sweep grid cells (and in-process `sweep()` callers).
    pool: Arc<Pool>,
    /// Interactive pool: `Simulate` point queries only.
    ipool: Arc<Pool>,
    cache: Arc<LayerCache>,
    /// Optional cross-request result cache with single-flight dedup
    /// (`serve --cache-entries`; `None` = every request simulates).
    results: Option<Arc<ResultCache>>,
    interactive: Lane,
    batch: Lane,
    /// Third admission lane: long-lived `Search` jobs.
    search: Lane,
    /// Live cancel tokens by client request id (`Cancel` requests).
    cancels: CancelRegistry,
    submitted: AtomicU64,
    completed: Arc<AtomicU64>,
    search_started: AtomicU64,
    search_completed: Arc<AtomicU64>,
    search_cancelled: Arc<AtomicU64>,
}

impl SimServer {
    /// `threads == 0` means one worker per CPU.
    pub fn new(threads: usize) -> SimServer {
        SimServer::with_cache(threads, Arc::new(LayerCache::new()))
    }

    /// Share a cache with other subsystems (sweeps, evaluators).
    pub fn with_cache(threads: usize, cache: Arc<LayerCache>) -> SimServer {
        SimServer::with_lanes(threads, cache, DEFAULT_SIM_CAPACITY, DEFAULT_BATCH_CAPACITY)
    }

    /// Explicit *interactive* admission bound (the batch lane keeps its
    /// default): once `capacity` point queries are in flight, further
    /// `Simulate` calls answer [`ServeError::Busy`].
    pub fn with_capacity(
        threads: usize,
        cache: Arc<LayerCache>,
        capacity: usize,
    ) -> SimServer {
        SimServer::with_lanes(threads, cache, capacity, DEFAULT_BATCH_CAPACITY)
    }

    /// Both lane bounds explicit: `interactive` bounds `Simulate` point
    /// queries, `batch` bounds in-flight `Sweep` grids. A full lane
    /// answers [`ServeError::Busy`] for its own traffic only. Admission
    /// is always bounded — capacities are clamped to ≥ 1, there is no
    /// "unlimited" setting.
    pub fn with_lanes(
        threads: usize,
        cache: Arc<LayerCache>,
        interactive: usize,
        batch: usize,
    ) -> SimServer {
        let pool = Arc::new(Pool::new(threads));
        // Half the batch width (≥2): wide enough that point-query-only
        // traffic keeps real parallelism, small enough that the extra
        // workers are a bounded oversubscription while a sweep runs.
        let ipool = Arc::new(Pool::new((pool.threads() / 2).max(2)));
        SimServer {
            pool,
            ipool,
            cache,
            results: None,
            interactive: Lane::new(interactive),
            batch: Lane::new(batch),
            search: Lane::new(DEFAULT_SEARCH_CAPACITY),
            cancels: Arc::new(Mutex::new(HashMap::new())),
            submitted: 0.into(),
            completed: Arc::new(AtomicU64::new(0)),
            search_started: 0.into(),
            search_completed: Arc::new(AtomicU64::new(0)),
            search_cancelled: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Override the `Search` lane bound (defaults to
    /// [`DEFAULT_SEARCH_CAPACITY`]).
    pub fn with_search_capacity(mut self, capacity: usize) -> SimServer {
        self.search = Lane::new(capacity);
        self
    }

    /// Attach (or share) a cross-request [`ResultCache`]: `Simulate`
    /// and per-cell `Sweep` lookups consult it before pool dispatch,
    /// and concurrent identical scenarios coalesce onto one simulation.
    pub fn with_result_cache(mut self, results: Arc<ResultCache>) -> SimServer {
        self.results = Some(results);
        self
    }

    /// The attached result cache, if any (shared with stats/tests).
    pub fn result_cache(&self) -> Option<&Arc<ResultCache>> {
        self.results.as_ref()
    }

    /// Result-cache counters (zeros when no cache is attached, so the
    /// stats surface is uniform either way).
    pub fn result_cache_stats(&self) -> ResultCacheStats {
        self.results.as_ref().map(|r| r.stats()).unwrap_or_default()
    }

    /// The admission lane for a given request class — [`RequestBody::priority`]
    /// is the protocol's lane-selection contract, and this is its one
    /// consumer, so the two cannot drift.
    fn lane(&self, priority: Priority) -> &Lane {
        match priority {
            Priority::Interactive => &self.interactive,
            Priority::Batch => &self.batch,
            Priority::Search => &self.search,
        }
    }

    /// Register a stream's cancel token under its client request id.
    fn register_cancel(&self, id: u64, token: CancelToken) {
        self.cancels.lock().unwrap().entry(id).or_default().push(token);
    }

    /// Trip every live token registered under `target`. Idempotent:
    /// unknown (or already-finished) ids trip nothing — the reply is
    /// `Done` either way, so cancel-after-final is harmless.
    fn cancel_target(&self, target: u64) {
        if let Some(tokens) = self.cancels.lock().unwrap().get(&target) {
            for t in tokens {
                t.cancel();
            }
        }
    }

    /// In-flight `Search` jobs right now (tests observe slot release).
    pub fn search_inflight(&self) -> usize {
        self.search.inflight.load(Ordering::Acquire)
    }

    /// Run a whole sweep plan synchronously on the server's pool + cache
    /// (in-process callers; wire traffic goes through `Sweep` requests).
    pub fn sweep(&self, plan: &SweepPlan) -> SweepOutcome {
        run_sweep_coalesced(
            plan,
            &self.pool,
            &self.cache,
            self.results.as_ref(),
            &CancelToken::new(),
            |_| {},
        )
    }

    /// Scenario requests admitted since start.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Scenario requests completed since start.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Live statistics (inference counters are zero; the [`Router`]
    /// overlays them when an engine is attached).
    pub fn stats_reply(&self) -> StatsReply {
        let cs = self.cache_stats();
        let rs = self.result_cache_stats();
        StatsReply {
            protocol_version: PROTOCOL_VERSION,
            infer_served: 0,
            infer_batches: 0,
            sim_submitted: self.submitted(),
            sim_completed: self.completed(),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_entries: cs.entries as u64,
            backends: 0,
            result_hits: rs.hits,
            result_misses: rs.misses,
            result_coalesced: rs.coalesced,
            result_evicted: rs.evicted,
            result_entries: rs.entries,
            result_bytes: rs.bytes,
            search_started: self.search_started.load(Ordering::Relaxed),
            search_completed: self.search_completed.load(Ordering::Relaxed),
            search_cancelled: self.search_cancelled.load(Ordering::Relaxed),
            // transport gauges are overlaid by whoever mounts the
            // service behind a frontend (see Router::with_gauges)
            ..StatsReply::default()
        }
    }
}

impl Service for SimServer {
    fn call(&self, req: Request) -> Ticket {
        let id = req.id;
        let deadline = req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        let lane = self.lane(req.body.priority());
        match req.body {
            RequestBody::Simulate { model, variant, config } => {
                // Interactive lane: a full batch lane never bounces this.
                if !lane.admit() {
                    return Ticket::immediate(Response::err(id, ServeError::Busy));
                }
                self.submitted.fetch_add(1, Ordering::Relaxed);
                let (ticket, sink) = Ticket::pending(id);
                let cache = Arc::clone(&self.cache);
                let results = self.results.clone();
                let inflight = Arc::clone(&lane.inflight);
                let completed = Arc::clone(&self.completed);
                // Dedicated interactive pool: never behind sweep cells.
                self.ipool.spawn(move || {
                    // Unwind guard: a panicking scenario must neither kill
                    // the pool worker nor leak its admission slot.
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        simulate_one(&model, variant, &config, deadline, &cache, results.as_deref())
                    }))
                    .unwrap_or_else(|_| {
                        Err(ServeError::BadRequest("simulation panicked".into()))
                    });
                    completed.fetch_add(1, Ordering::Relaxed);
                    inflight.fetch_sub(1, Ordering::Release);
                    // The client may have hung up (dropped the ticket);
                    // that is not the server's problem.
                    sink.finish(result.map(Reply::Sim));
                });
                ticket
            }
            RequestBody::Sweep { models, variants, configs } => {
                // Batch lane: sweeps only compete with other sweeps.
                if !lane.admit() {
                    return Ticket::immediate(Response::err(id, ServeError::Busy));
                }
                self.submitted.fetch_add(1, Ordering::Relaxed);
                let (ticket, sink) = Ticket::pending(id);
                let pool = Arc::clone(&self.pool);
                let cache = Arc::clone(&self.cache);
                let results = self.results.clone();
                let inflight = Arc::clone(&lane.inflight);
                let completed = Arc::clone(&self.completed);
                let token = CancelToken::new();
                self.register_cancel(id, token.clone());
                let cancels = Arc::clone(&self.cancels);
                // A sweep is a whole fork/join grid: run it from a fresh
                // coordinator thread so the pool's workers stay job-sized
                // (a sweep *on* a worker would deadlock the join).
                let _detached = thread::Builder::new()
                    .name("fuseconv-sweep-req".into())
                    .spawn(move || {
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            sweep_request(
                                models,
                                variants,
                                configs,
                                deadline,
                                &pool,
                                &cache,
                                results.as_ref(),
                                &sink,
                                &token,
                            )
                        }))
                        .unwrap_or_else(|_| {
                            Err(ServeError::BadRequest("sweep panicked".into()))
                        });
                        deregister_cancel(&cancels, id, &token);
                        completed.fetch_add(1, Ordering::Relaxed);
                        inflight.fetch_sub(1, Ordering::Release);
                        sink.finish(result);
                    })
                    .expect("spawn sweep thread");
                ticket
            }
            RequestBody::Search { spec } => {
                if let Err(e) = spec.validate() {
                    return Ticket::immediate(Response::err(id, e));
                }
                // Search lane: long jobs only compete with other searches.
                if !lane.admit() {
                    return Ticket::immediate(Response::err(id, ServeError::Busy));
                }
                self.search_started.fetch_add(1, Ordering::Relaxed);
                let (ticket, sink) = Ticket::pending(id);
                let cache = Arc::clone(&self.cache);
                let results = self.results.clone();
                let inflight = Arc::clone(&lane.inflight);
                let completed = Arc::clone(&self.search_completed);
                let cancelled = Arc::clone(&self.search_cancelled);
                let token = CancelToken::new();
                self.register_cancel(id, token.clone());
                let cancels = Arc::clone(&self.cancels);
                // Like a sweep, a search owns a fork/join pool for its
                // whole run — coordinate it from a dedicated thread.
                let _detached = thread::Builder::new()
                    .name("fuseconv-search-req".into())
                    .spawn(move || {
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            search_request(
                                spec,
                                deadline,
                                &cache,
                                results.as_ref(),
                                &sink,
                                &token,
                            )
                        }))
                        .unwrap_or_else(|_| {
                            Err(ServeError::BadRequest("search panicked".into()))
                        });
                        deregister_cancel(&cancels, id, &token);
                        match &result {
                            Ok(Reply::Search(r)) if r.cancelled => {
                                cancelled.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(_) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {}
                        }
                        inflight.fetch_sub(1, Ordering::Release);
                        sink.finish(result);
                    })
                    .expect("spawn search thread");
                ticket
            }
            RequestBody::Cancel { target } => {
                self.cancel_target(target);
                Ticket::immediate(Response::ok(id, Reply::Done))
            }
            RequestBody::Stats => {
                Ticket::immediate(Response::ok(id, Reply::Stats(self.stats_reply())))
            }
            RequestBody::Zoo => Ticket::immediate(Response::ok(id, Reply::Zoo(zoo_entries()))),
            RequestBody::AddBackend { .. } | RequestBody::DrainBackend { .. } => {
                // Fleet membership only means something on a shard front
                // tier; a direct node has no backends to add or drain.
                Ticket::immediate(Response::err(
                    id,
                    ServeError::BadRequest(
                        "membership ops need a shard front tier (this is a direct node)"
                            .into(),
                    ),
                ))
            }
            RequestBody::Shutdown => {
                // Lifecycle belongs to the frontend (Router / listener).
                Ticket::immediate(Response::ok(id, Reply::Done))
            }
            RequestBody::Infer { .. } => Ticket::immediate(Response::err(
                id,
                ServeError::BadRequest(
                    "no inference engine behind the simulation service".into(),
                ),
            )),
        }
    }
}

/// One `Simulate` scenario, start to finish (runs on a pool worker).
/// With a result cache attached the scenario is looked up (and, when
/// another request is already simulating it, coalesced onto that
/// flight) before any simulator work; a follower whose deadline expires
/// mid-wait answers `Deadline` like any other late request.
fn simulate_one(
    model: &ModelSpec,
    variant: FuseVariant,
    config: &ConfigPatch,
    deadline: Option<Instant>,
    cache: &LayerCache,
    results: Option<&ResultCache>,
) -> Result<SimSummary, ServeError> {
    if deadline.is_some_and(|d| Instant::now() > d) {
        return Err(ServeError::Deadline);
    }
    let net = model.resolve()?;
    let cfg = config.to_config()?;
    let realized = variant.apply(&net);
    match results {
        Some(rc) => match rc.simulate(&realized, &cfg, cache, deadline) {
            Some(sim) => Ok(SimSummary::of(&sim)),
            None => Err(ServeError::Deadline),
        },
        None => Ok(SimSummary::of(&simulate_network_cached(&realized, &cfg, cache))),
    }
}

/// One grid cell as its wire row.
pub fn sweep_row_of(r: &SweepRecord) -> SweepRow {
    SweepRow {
        network: r.network.clone(),
        variant: r.variant,
        rows: r.cfg.rows,
        cols: r.cfg.cols,
        dataflow: r.cfg.dataflow,
        stos: r.cfg.stos,
        total_cycles: r.total_cycles(),
        latency_ms: r.latency_ms(),
    }
}

/// One streamed `Sweep` request: resolve the grid, run it with
/// incremental row emission, streaming `Progress` (completion counter)
/// and `Row` (plan-order cells) frames into the sink as the sweep engine
/// finishes cells. The deadline is checked at start; an admitted grid
/// runs to completion. Returns the terminal reply (`Done`; the rows
/// already left through the sink).
fn sweep_request(
    models: Vec<String>,
    variants: Vec<FuseVariant>,
    configs: Vec<ConfigPatch>,
    deadline: Option<Instant>,
    pool: &Pool,
    cache: &Arc<LayerCache>,
    results: Option<&Arc<ResultCache>>,
    sink: &FrameSink,
    cancel: &CancelToken,
) -> Result<Reply, ServeError> {
    if deadline.is_some_and(|d| Instant::now() > d) {
        return Err(ServeError::Deadline);
    }
    let networks = models
        .iter()
        .map(|m| ModelSpec::Zoo(m.clone()).resolve())
        .collect::<Result<Vec<_>, _>>()?;
    let cfgs = configs
        .iter()
        .map(|p| p.to_config())
        .collect::<Result<Vec<_>, _>>()?;
    let plan = SweepPlan::new(networks, variants, cfgs);
    if plan.is_empty() {
        return Err(ServeError::BadRequest("empty sweep grid".into()));
    }
    // Up-front progress frame: the client learns the grid size before
    // the first row lands (and even 1-cell grids stream ≥1 progress).
    if !sink.progress(0, plan.len() as u64) {
        cancel.cancel();
    }
    // A failed send means the client hung up: trip the token so the
    // sweep engine's workers stop pricing the remaining cells instead
    // of burning pool cycles into a closed socket.
    run_sweep_coalesced(&plan, pool, cache, results, cancel, |event| match event {
        SweepEvent::Progress { done, total } => {
            if !sink.progress(done as u64, total as u64) {
                cancel.cancel();
            }
        }
        SweepEvent::Row { record, .. } => {
            if !sink.row(sweep_row_of(record)) {
                cancel.cancel();
            }
        }
    });
    Ok(Reply::Done)
}

/// Wire form of a search candidate: the genome travels as its compact
/// string encoding so the shard tier can relay rows without re-parsing.
fn point_of(c: &NasCandidate, rank: u64) -> SearchPoint {
    SearchPoint {
        genome: c.genome.compact(),
        acc: c.acc,
        latency_ms: c.latency_ms,
        macs_m: c.macs_millions,
        params_m: c.params_millions,
        rank,
    }
}

/// One streamed `Search` request: run evolutionary NAS over the OFA+FuSe
/// space, streaming `Progress` per generation plus the running pareto
/// front as `SearchRow` frames, with per-genome simulation routed through
/// the global result cache. Cancellation is cooperative: an explicit
/// `cancel` frame trips the registered token, and a dead client (any
/// frame send returning `false`) trips it too — either way the run stops
/// within one generation and the terminal reply carries the partial
/// frontier flagged `cancelled`.
fn search_request(
    spec: SearchSpec,
    deadline: Option<Instant>,
    cache: &Arc<LayerCache>,
    results: Option<&Arc<ResultCache>>,
    sink: &FrameSink,
    cancel: &CancelToken,
) -> Result<Reply, ServeError> {
    if deadline.is_some_and(|d| Instant::now() > d) {
        return Err(ServeError::Deadline);
    }
    let cfg = spec.config.to_config()?;
    let ev = Arc::new(Evaluator::with_cache(cfg, Arc::clone(cache)));
    let nas = NasConfig {
        population: spec.population,
        iterations: spec.iterations,
        mutation_p: spec.mutation_p,
        allow_fuse: spec.allow_fuse,
        seed: spec.seed,
        threads: 0,
    };
    if !sink.progress(0, nas.iterations as u64) {
        cancel.cancel();
    }
    let result = run_nas_with(ev, &nas, results, cancel, |event| {
        let SearchEvent::Generation { done, total, front } = event;
        let mut alive = sink.progress(done as u64, total as u64);
        for c in front {
            if !alive {
                break;
            }
            alive = sink.search_row(point_of(c, 0));
        }
        if !alive {
            cancel.cancel();
        }
    });
    Ok(Reply::Search(SearchReply {
        frontier: result.frontier.iter().map(|c| point_of(c, 0)).collect(),
        evaluated: result.evaluated as u64,
        generations: result.generations as u64,
        cancelled: result.cancelled,
    }))
}

/// Drop one finished stream's token from the cancel registry (keyed by
/// client request id; ids can collide across connections, so only the
/// exact token is removed). Free function because the detached request
/// thread outlives its borrow of the server.
fn deregister_cancel(cancels: &CancelRegistry, id: u64, token: &CancelToken) {
    let mut map = cancels.lock().unwrap();
    if let Some(tokens) = map.get_mut(&id) {
        tokens.retain(|t| !t.same(token));
        if tokens.is_empty() {
            map.remove(&id);
        }
    }
}

/// The zoo listing served to `Zoo` requests.
pub fn zoo_entries() -> Vec<ZooEntry> {
    models::zoo_table()
        .into_iter()
        .map(|(name, macs_m, params_m, blocks)| ZooEntry {
            name: name.to_string(),
            macs_m,
            params_m,
            blocks,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// One [`Service`] fronting both serving halves: `Infer` goes to the
/// engine, `Simulate`/`Sweep`/`Zoo` to the simulation pool, `Stats`
/// merges both, `Shutdown` flips the closing latch the TCP frontend
/// polls. After `Shutdown`, every call answers [`ServeError::Shutdown`].
pub struct Router {
    infer: Option<Server>,
    sim: SimServer,
    closing: AtomicBool,
    gauges: Option<TransportGauges>,
}

impl Router {
    /// Simulation-only deployment (no inference engine attached).
    pub fn new(sim: SimServer) -> Router {
        Router { infer: None, sim, closing: AtomicBool::new(false), gauges: None }
    }

    /// Attach a batched inference server for `Infer` traffic.
    pub fn with_engine(mut self, server: Server) -> Router {
        self.infer = Some(server);
        self
    }

    /// Attach the transport gauges its frontends update, so `Stats`
    /// replies carry live `open_conns`/`active_streams`/
    /// `transport_threads` (zeros when unattached).
    pub fn with_gauges(mut self, gauges: TransportGauges) -> Router {
        self.gauges = Some(gauges);
        self
    }

    /// Has a `Shutdown` request been accepted?
    pub fn closing(&self) -> bool {
        self.closing.load(Ordering::Acquire)
    }

    pub fn sim(&self) -> &SimServer {
        &self.sim
    }

    /// Combined live statistics.
    pub fn stats_reply(&self) -> StatsReply {
        let mut s = self.sim.stats_reply();
        if let Some(srv) = &self.infer {
            s.infer_served = srv.served();
            s.infer_batches = srv.batches();
        }
        if let Some(g) = &self.gauges {
            g.overlay(&mut s);
        }
        s
    }

    /// Tear down: stop the inference dispatcher (draining its queue) and
    /// return its final statistics, if an engine was attached.
    pub fn into_stats(mut self) -> Option<ServerStats> {
        self.infer.take().map(Server::shutdown)
    }
}

impl Service for Router {
    fn call(&self, req: Request) -> Ticket {
        let id = req.id;
        if self.closing() {
            return Ticket::immediate(Response::err(id, ServeError::Shutdown));
        }
        match req.body {
            RequestBody::Infer { .. } => match &self.infer {
                Some(srv) => srv.call(req),
                None => Ticket::immediate(Response::err(
                    id,
                    ServeError::BadRequest(
                        "this endpoint has no inference engine (simulation-only)".into(),
                    ),
                )),
            },
            RequestBody::Stats => {
                Ticket::immediate(Response::ok(id, Reply::Stats(self.stats_reply())))
            }
            RequestBody::Shutdown => {
                self.closing.store(true, Ordering::Release);
                Ticket::immediate(Response::ok(id, Reply::Done))
            }
            _ => self.sim.call(req),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Frame;
    use crate::sim::{run_sweep_serial, simulate_network, SimConfig};

    fn mock(delay_ms: u64) -> MockEngine {
        MockEngine {
            in_len: 4,
            out_len: 2,
            max_b: 8,
            delay: Duration::from_millis(delay_ms),
        }
    }

    /// Unwrap an inference reply or panic with the error.
    fn infer_ok(resp: Response) -> InferReply {
        match resp.result {
            Ok(Reply::Infer(r)) => r,
            other => panic!("expected infer reply, got {other:?}"),
        }
    }

    fn sim_ok(resp: Response) -> SimSummary {
        match resp.result {
            Ok(Reply::Sim(s)) => s,
            other => panic!("expected sim reply, got {other:?}"),
        }
    }

    #[test]
    fn serves_single_request() {
        let server = Server::start(mock(0), BatchPolicy::default());
        let t = server.submit(vec![1.0, 2.0, 3.0, 4.0]);
        let r = infer_ok(t.wait_deadline(Duration::from_secs(2)));
        assert_eq!(r.output, vec![10.0, 11.0]);
        assert_eq!(server.served(), 1);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_under_load() {
        let server = Server::start(
            mock(3),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        );
        let tickets: Vec<_> = (0..24).map(|i| server.submit(vec![i as f32; 4])).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = infer_ok(t.wait_deadline(Duration::from_secs(5)));
            assert_eq!(r.output[0], (i * 4) as f32);
            assert!(r.batch_size >= 1);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 24);
        // batching actually happened (fewer batches than requests)
        assert!(stats.batches < 24, "batches {}", stats.batches);
        assert!(stats.mean_batch() > 1.0);
    }

    #[test]
    fn shutdown_drains_full_queue() {
        // queue far beyond one batch, deadline far away: everything is
        // still buffered when shutdown lands, and the drain path must
        // flush it as multiple batches.
        let server = Server::start(
            mock(1),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) },
        );
        let tickets: Vec<_> = (0..11).map(|i| server.submit(vec![i as f32; 4])).collect();
        let stats = server.shutdown(); // deadline far away: drain on shutdown
        assert_eq!(stats.served, 11);
        assert!(stats.batches >= 3, "drain must respect max_batch: {}", stats.batches);
        for mut t in tickets {
            assert!(
                matches!(t.try_recv(), Ok(Some(frame)) if frame.is_final()),
                "drained ticket must hold its final frame"
            );
        }
    }

    #[test]
    fn queue_time_never_exceeds_total_latency() {
        // Regression for the old self-referential `min` expression: with a
        // slow engine and batched arrivals, queue_us must stay ≤ latency_us.
        let server = Server::start(
            mock(10),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let tickets: Vec<_> = (0..12).map(|i| server.submit(vec![i as f32; 4])).collect();
        for t in tickets {
            let r = infer_ok(t.wait_deadline(Duration::from_secs(10)));
            assert!(
                r.queue_us <= r.latency_us,
                "queue {} > latency {}",
                r.queue_us,
                r.latency_us
            );
        }
        server.shutdown();
    }

    #[test]
    fn full_admission_queue_answers_busy() {
        // max_batch 1 + 100 ms engine: the dispatcher picks up the first
        // request and sleeps in infer; the queue (bound 1) then holds one
        // pending request, so a third submission must bounce as Busy.
        let server = Server::start_with_queue(
            || MockEngine {
                in_len: 4,
                out_len: 2,
                max_b: 1,
                delay: Duration::from_millis(100),
            },
            BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            1,
        );
        let t1 = server.submit(vec![0.0; 4]);
        thread::sleep(Duration::from_millis(30)); // let the dispatcher start batch 1
        let t2 = server.submit(vec![1.0; 4]);
        let t3 = server.submit(vec![2.0; 4]);
        let r3 = t3.wait();
        assert_eq!(r3.result, Err(ServeError::Busy), "expected Busy, got {r3:?}");
        // the admitted requests still complete
        infer_ok(t1.wait_deadline(Duration::from_secs(5)));
        infer_ok(t2.wait_deadline(Duration::from_secs(5)));
        server.shutdown();
    }

    #[test]
    fn expired_deadline_returns_typed_error() {
        let server = Server::start_with_queue(
            || MockEngine {
                in_len: 4,
                out_len: 2,
                max_b: 1,
                delay: Duration::from_millis(60),
            },
            BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            8,
        );
        let t1 = server.submit(vec![0.0; 4]); // occupies the engine ~60ms
        let t2 = server.call(
            Request::new(999, RequestBody::Infer { input: vec![1.0; 4] })
                .with_deadline_ms(5),
        );
        infer_ok(t1.wait_deadline(Duration::from_secs(5)));
        let r2 = t2.wait_deadline(Duration::from_secs(5));
        assert_eq!(r2.id, 999);
        assert_eq!(r2.result, Err(ServeError::Deadline));
        server.shutdown();
    }

    #[test]
    fn wrong_input_length_is_bad_request_not_panic() {
        let server = Server::start(mock(0), BatchPolicy::default());
        let t = server.submit(vec![1.0; 3]); // engine wants 4
        let r = t.wait_deadline(Duration::from_secs(2));
        assert!(
            matches!(r.result, Err(ServeError::BadRequest(_))),
            "got {:?}",
            r.result
        );
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn non_infer_requests_rejected_by_inference_server() {
        let server = Server::start(mock(0), BatchPolicy::default());
        let t = server.call(Request::new(5, RequestBody::Stats));
        assert!(matches!(t.wait().result, Err(ServeError::BadRequest(_))));
        server.shutdown();
    }

    fn simulate_req(id: u64, model: &str, variant: FuseVariant, config: ConfigPatch) -> Request {
        Request::new(
            id,
            RequestBody::Simulate { model: ModelSpec::Zoo(model.into()), variant, config },
        )
    }

    #[test]
    fn sim_service_matches_direct_simulation() {
        let server = SimServer::new(2);
        let t = server.call(simulate_req(1, "mobilenet-v2", FuseVariant::Half, ConfigPatch::default()));
        let sim = sim_ok(t.wait_deadline(Duration::from_secs(60)));
        let net = models::by_name("mobilenet-v2").unwrap();
        let expect =
            simulate_network(&FuseVariant::Half.apply(&net), &SimConfig::default());
        assert_eq!(sim.total_cycles, expect.total_cycles);
        assert_eq!(sim.network, expect.network);
        assert_eq!(sim.num_layers, expect.layers.len());
        assert_eq!(server.submitted(), 1);
    }

    #[test]
    fn sim_service_repeat_traffic_hits_cache() {
        let server = SimServer::new(3);
        let tickets: Vec<_> = (0..6)
            .map(|i| {
                server.call(simulate_req(
                    i,
                    "mobilenet-v3-small",
                    FuseVariant::Base,
                    ConfigPatch::default(),
                ))
            })
            .collect();
        let sims: Vec<_> = tickets
            .into_iter()
            .map(|t| sim_ok(t.wait_deadline(Duration::from_secs(60))))
            .collect();
        assert!(sims.windows(2).all(|w| w[0].total_cycles == w[1].total_cycles));
        let stats = server.cache_stats();
        assert!(stats.hits > 0, "repeat scenarios never hit the cache: {stats:?}");
        let net = models::by_name("mobilenet-v3-small").unwrap();
        assert!(stats.entries <= net.layers.len());
        assert_eq!(server.completed(), 6);
    }

    #[test]
    fn sim_service_unknown_model_is_bad_request() {
        let server = SimServer::new(1);
        let t = server.call(simulate_req(7, "nonesuch", FuseVariant::Base, ConfigPatch::default()));
        let r = t.wait_deadline(Duration::from_secs(10));
        assert!(matches!(r.result, Err(ServeError::BadRequest(_))), "got {:?}", r.result);
    }

    #[test]
    fn sim_service_bounded_admission_answers_busy() {
        // capacity 1, one worker: the first (cold, whole-network) job
        // holds the only slot for milliseconds while the burst lands.
        let server = SimServer::with_capacity(1, Arc::new(LayerCache::new()), 1);
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                server.call(simulate_req(
                    i,
                    "mobilenet-v2",
                    FuseVariant::Full,
                    ConfigPatch::sized(32),
                ))
            })
            .collect();
        let mut ok = 0;
        let mut busy = 0;
        for t in tickets {
            match t.wait_deadline(Duration::from_secs(60)).result {
                Ok(Reply::Sim(_)) => ok += 1,
                Err(ServeError::Busy) => busy += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(ok + busy, 8);
        assert!(ok >= 1, "at least the first admitted job completes");
        assert!(busy >= 1, "burst past capacity must bounce as Busy");
    }

    #[test]
    fn batch_lane_full_still_admits_interactive_simulate() {
        // batch lane bound 1: while one sweep occupies it, further sweeps
        // bounce Busy — but the interactive lane must keep admitting.
        let server = SimServer::with_lanes(2, Arc::new(LayerCache::new()), 4, 1);
        let sweep_body = RequestBody::Sweep {
            models: vec!["mobilenet-v2".into()],
            variants: vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
            configs: (0..4).map(|i| ConfigPatch::sized(8 << i)).collect(),
        };
        let mut admitted = Vec::new();
        let mut saw_busy = false;
        for id in 0..32u64 {
            let mut t = server.call(Request::new(id, sweep_body.clone()));
            if matches!(t.try_recv(), Ok(Some(Frame::Final(Err(ServeError::Busy))))) {
                // The batch lane is full *right now*; a point query must
                // still be admitted and answered.
                saw_busy = true;
                let t = server.call(simulate_req(
                    1000,
                    "mobilenet-v3-small",
                    FuseVariant::Base,
                    ConfigPatch::sized(8),
                ));
                let r = t.wait_deadline(Duration::from_secs(60));
                assert!(
                    matches!(r.result, Ok(Reply::Sim(_))),
                    "interactive query starved by the batch lane: {:?}",
                    r.result
                );
                break;
            }
            admitted.push(t);
        }
        assert!(saw_busy, "batch lane never filled");
        for t in admitted {
            assert!(t.wait_deadline(Duration::from_secs(120)).is_ok());
        }
    }

    #[test]
    fn sweep_streams_progress_and_rows_before_final() {
        let server = SimServer::new(2);
        let mut t = server.call(Request::new(
            9,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half],
                configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(16)],
            },
        ));
        let mut progress = 0;
        let mut rows = Vec::new();
        loop {
            match t.recv_deadline(Duration::from_secs(120)).expect("stream frame") {
                Frame::Progress { done, total } => {
                    assert_eq!(total, 4);
                    assert!(done <= total);
                    progress += 1;
                }
                Frame::Row(row) => rows.push(row),
                Frame::SearchRow(p) => panic!("sweep stream leaked a search row: {p:?}"),
                Frame::Final(result) => {
                    assert_eq!(result, Ok(Reply::Done));
                    break;
                }
            }
        }
        assert!(progress >= 2, "want the up-front + completion progress frames");
        assert_eq!(rows.len(), 4);
        // rows arrive in plan order and price identically to a direct sweep
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v3-small").unwrap()],
            vec![FuseVariant::Base, FuseVariant::Half],
            vec![SimConfig::with_size(8), SimConfig::with_size(16)],
        );
        let direct = run_sweep_serial(&plan);
        for (row, rec) in rows.iter().zip(direct.records()) {
            assert_eq!(row.network, rec.network);
            assert_eq!(row.variant, rec.variant);
            assert_eq!((row.rows, row.cols), (rec.cfg.rows, rec.cfg.cols));
            assert_eq!(row.total_cycles, rec.total_cycles());
        }
    }

    #[test]
    fn sim_service_sweep_request_covers_grid() {
        let server = SimServer::new(2);
        let t = server.call(Request::new(
            3,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half],
                configs: vec![ConfigPatch::default(), ConfigPatch::sized(8)],
            },
        ));
        let r = t.wait_deadline(Duration::from_secs(120));
        match r.result {
            Ok(Reply::Sweep(rows)) => {
                assert_eq!(rows.len(), 4);
                assert!(rows.iter().all(|row| row.total_cycles > 0));
                assert!(rows.iter().any(|row| row.rows == 8));
            }
            other => panic!("expected sweep rows, got {other:?}"),
        }
    }

    #[test]
    fn sim_service_runs_sweep_plans_in_process() {
        let server = SimServer::new(2);
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v3-small").unwrap()],
            vec![FuseVariant::Base, FuseVariant::Half],
            vec![SimConfig::default(), SimConfig::with_size(8)],
        );
        let out = server.sweep(&plan);
        assert_eq!(out.records().len(), 4);
        assert!(out.records().iter().all(|r| r.total_cycles() > 0));
    }

    #[test]
    fn sim_service_zoo_and_stats() {
        let server = SimServer::new(1);
        let t = server.call(Request::new(1, RequestBody::Zoo));
        match t.wait().result {
            Ok(Reply::Zoo(entries)) => {
                assert_eq!(entries.len(), models::ZOO_NAMES.len());
                assert!(entries.iter().all(|e| e.macs_m > 0.0));
            }
            other => panic!("expected zoo, got {other:?}"),
        }
        let t = server.call(Request::new(2, RequestBody::Stats));
        match t.wait().result {
            Ok(Reply::Stats(s)) => assert_eq!(s.protocol_version, PROTOCOL_VERSION),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn latency_stats_populated() {
        let server = Server::start(mock(0), BatchPolicy::default());
        for _ in 0..10 {
            let t = server.submit(vec![0.0; 4]);
            infer_ok(t.wait_deadline(Duration::from_secs(2)));
        }
        let stats = server.shutdown();
        let s = stats.latency_summary().unwrap();
        assert_eq!(s.n, 10);
        assert!(s.p99 >= s.p50);
    }

    #[test]
    fn router_dispatches_by_request_kind() {
        let router = Router::new(SimServer::new(2))
            .with_engine(Server::start(mock(0), BatchPolicy::default()));
        // infer through the engine
        let t = router.call(Request::new(1, RequestBody::Infer { input: vec![1.0; 4] }));
        let r = infer_ok(t.wait_deadline(Duration::from_secs(5)));
        assert_eq!(r.output.len(), 2);
        // simulate through the pool
        let t = router.call(simulate_req(2, "mobilenet-v3-small", FuseVariant::Base, ConfigPatch::default()));
        assert!(sim_ok(t.wait_deadline(Duration::from_secs(60))).total_cycles > 0);
        // stats merges both halves
        let t = router.call(Request::new(3, RequestBody::Stats));
        match t.wait().result {
            Ok(Reply::Stats(s)) => {
                assert_eq!(s.infer_served, 1);
                assert_eq!(s.sim_submitted, 1);
            }
            other => panic!("expected stats, got {other:?}"),
        }
        // shutdown latches
        let t = router.call(Request::new(4, RequestBody::Shutdown));
        assert_eq!(t.wait().result, Ok(Reply::Done));
        assert!(router.closing());
        let t = router.call(Request::new(5, RequestBody::Stats));
        assert_eq!(t.wait().result, Err(ServeError::Shutdown));
        assert!(router.into_stats().is_some());
    }

    #[test]
    fn router_without_engine_rejects_infer() {
        let router = Router::new(SimServer::new(1));
        let t = router.call(Request::new(1, RequestBody::Infer { input: vec![0.0; 4] }));
        assert!(matches!(t.wait().result, Err(ServeError::BadRequest(_))));
        assert!(router.into_stats().is_none());
    }

    fn tiny_search() -> SearchSpec {
        SearchSpec {
            population: 6,
            iterations: 3,
            config: ConfigPatch::sized(8),
            ..SearchSpec::default()
        }
    }

    /// Drain a search stream into (progress, rows, terminal reply).
    fn drain_search(mut t: Ticket) -> (Vec<(u64, u64)>, Vec<SearchPoint>, SearchReply) {
        let mut progress = Vec::new();
        let mut rows = Vec::new();
        loop {
            match t.recv_deadline(Duration::from_secs(120)).expect("stream frame") {
                Frame::Progress { done, total } => progress.push((done, total)),
                Frame::SearchRow(p) => rows.push(p),
                Frame::Row(row) => panic!("search stream leaked a sweep row: {row:?}"),
                Frame::Final(result) => match result {
                    Ok(Reply::Search(r)) => return (progress, rows, r),
                    other => panic!("expected search reply, got {other:?}"),
                },
            }
        }
    }

    #[test]
    fn search_streams_progress_and_rows_before_final() {
        let server = SimServer::new(2);
        let spec = tiny_search();
        let t = server.call(Request::new(11, RequestBody::Search { spec: spec.clone() }));
        let (progress, rows, reply) = drain_search(t);
        // the up-front 0/total frame plus one per generation
        assert_eq!(progress.first(), Some(&(0, 3)));
        assert_eq!(progress.len(), 4);
        assert_eq!(progress.last(), Some(&(3, 3)));
        assert!(!rows.is_empty(), "per-generation pareto rows must stream");
        assert!(!reply.cancelled);
        assert_eq!(reply.generations, 3);
        assert_eq!(reply.evaluated, 6 + 3 * 6);
        assert!(!reply.frontier.is_empty());
        // the last generation's rows are exactly the final frontier
        let tail = &rows[rows.len() - reply.frontier.len()..];
        for (row, fin) in tail.iter().zip(&reply.frontier) {
            assert_eq!(row.genome, fin.genome);
            assert_eq!(row.latency_ms.to_bits(), fin.latency_ms.to_bits());
        }
        // same seed ⇒ byte-identical stream and reply
        let t = server.call(Request::new(12, RequestBody::Search { spec }));
        let (progress2, rows2, reply2) = drain_search(t);
        assert_eq!(progress, progress2);
        assert_eq!(rows.len(), rows2.len());
        for (a, b) in rows.iter().zip(&rows2) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.acc.to_bits(), b.acc.to_bits());
            assert_eq!(a.latency_ms.to_bits(), b.latency_ms.to_bits());
        }
        assert_eq!(reply.frontier.len(), reply2.frontier.len());
        let stats = server.stats_reply();
        assert_eq!(stats.search_started, 2);
        assert_eq!(stats.search_completed, 2);
        assert_eq!(stats.search_cancelled, 0);
    }

    #[test]
    fn cancel_frame_stops_search_and_frees_the_lane_slot() {
        let server = SimServer::new(2);
        let spec = SearchSpec { iterations: 1024, ..tiny_search() };
        let mut t = server.call(Request::new(21, RequestBody::Search { spec }));
        // wait until the run is demonstrably underway
        match t.recv_deadline(Duration::from_secs(60)).expect("first frame") {
            Frame::Progress { done: 0, total: 1024 } => {}
            other => panic!("expected up-front progress, got {other:?}"),
        }
        assert_eq!(server.search_inflight(), 1);
        let c = server.call(Request::new(22, RequestBody::Cancel { target: 21 }));
        assert_eq!(c.wait().result, Ok(Reply::Done));
        // drain to the terminal frame: partial frontier, flagged cancelled
        let reply = loop {
            match t.recv_deadline(Duration::from_secs(120)).expect("stream frame") {
                Frame::Final(Ok(Reply::Search(r))) => break r,
                Frame::Final(other) => panic!("expected search reply, got {other:?}"),
                _ => {}
            }
        };
        assert!(reply.cancelled);
        assert!(reply.generations < 1024, "cancel must stop the run early");
        // the detached thread releases its slot after finish()
        let t0 = Instant::now();
        while server.search_inflight() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "search lane slot never freed");
            thread::sleep(Duration::from_millis(5));
        }
        let stats = server.stats_reply();
        assert_eq!(stats.search_started, 1);
        assert_eq!(stats.search_completed, 0);
        assert_eq!(stats.search_cancelled, 1);
        // cancel of a finished (or unknown) id is still Done
        let c = server.call(Request::new(23, RequestBody::Cancel { target: 999 }));
        assert_eq!(c.wait().result, Ok(Reply::Done));
    }

    #[test]
    fn search_lane_is_bounded_and_validation_rejects_bad_specs() {
        let server = SimServer::with_lanes(2, Arc::new(LayerCache::new()), 4, 4)
            .with_search_capacity(1);
        // population below the floor bounces before touching the lane
        let spec = SearchSpec { population: 1, ..SearchSpec::default() };
        let t = server.call(Request::new(31, RequestBody::Search { spec }));
        assert!(matches!(t.wait().result, Err(ServeError::BadRequest(_))));
        assert_eq!(server.stats_reply().search_started, 0);
        // one long search occupies the single slot; the next must bounce Busy
        let spec = SearchSpec { iterations: 1024, ..tiny_search() };
        let mut t1 = server.call(Request::new(32, RequestBody::Search { spec: spec.clone() }));
        assert!(t1.recv_deadline(Duration::from_secs(60)).is_ok());
        let t2 = server.call(Request::new(33, RequestBody::Search { spec }));
        assert_eq!(t2.wait().result, Err(ServeError::Busy));
        server.cancel_target(32);
        let reply = loop {
            match t1.recv_deadline(Duration::from_secs(120)).expect("stream frame") {
                Frame::Final(r) => break r,
                _ => {}
            }
        };
        assert!(matches!(reply, Ok(Reply::Search(r)) if r.cancelled));
    }
}
