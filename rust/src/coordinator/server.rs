//! Serving loops.
//!
//! * [`Server`] — inference serving: a dispatcher thread drains the
//!   dynamic batcher and drives an [`Engine`] (the PJRT executable in
//!   production, a mock in tests). Per-request latency and batch
//!   statistics come back with each response — this is the L3 hot path
//!   the §Perf pass profiles.
//! * [`SimServer`] — simulation-as-a-service: scenario requests
//!   (network × variant × config) fan out across the worker pool through
//!   the sweep engine's shared layer cache, instead of the serial
//!   one-`simulate_network`-at-a-time loop clients used to run themselves.

use super::batcher::{BatchPolicy, Batcher};
use crate::exec::Pool;
use crate::nn::Network;
use crate::sim::{
    run_sweep, simulate_network_cached, CacheStats, FuseVariant, LayerCache, NetworkSim,
    SimConfig, SweepOutcome, SweepPlan,
};
use crate::stats::Summary;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Something that can run a batch of flattened image tensors.
///
/// Implementations need not be `Send` — the PJRT client is thread-bound —
/// so the server constructs the engine *inside* its dispatcher thread via
/// [`Server::start_with`].
pub trait Engine: 'static {
    /// Elements per single input (e.g. 3·H·W).
    fn input_len(&self) -> usize;
    /// Elements per single output (e.g. #classes).
    fn output_len(&self) -> usize;
    /// Largest batch the compiled executable accepts.
    fn max_batch(&self) -> usize;
    /// Run one batch: `inputs.len() == n × input_len()`; must return
    /// `n × output_len()` elements.
    fn infer(&self, inputs: &[f32], n: usize) -> Vec<f32>;
}

/// One client request.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub reply: mpsc::Sender<Response>,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    pub queue_us: u64,
    pub batch_size: usize,
    pub latency_us: u64,
}

/// Serving statistics, accumulated by the dispatcher.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub latencies_us: Vec<f64>,
    pub batch_sizes: Vec<f64>,
}

impl ServerStats {
    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies_us.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_us))
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<f64>() / self.batch_sizes.len() as f64
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: mpsc::Sender<ServerMsg>,
    dispatcher: Option<thread::JoinHandle<ServerStats>>,
    next_id: std::sync::atomic::AtomicU64,
}

enum ServerMsg {
    Req(Request),
    Shutdown,
}

impl Server {
    /// Start with an engine constructed on the dispatcher thread (required
    /// for thread-bound engines like the PJRT one).
    pub fn start_with<E, F>(factory: F, policy: BatchPolicy) -> Server
    where
        E: Engine,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let rx = Arc::new(Mutex::new(rx));
        let dispatcher = thread::Builder::new()
            .name("fuseconv-dispatch".into())
            .spawn(move || dispatch_loop(factory(), policy, rx))
            .expect("spawn dispatcher");
        Server { tx, dispatcher: Some(dispatcher), next_id: 0.into() }
    }

    /// Convenience for `Send` engines.
    pub fn start<E: Engine + Send>(engine: E, policy: BatchPolicy) -> Server {
        Server::start_with(move || engine, policy)
    }

    /// Submit one input; returns a receiver for the response.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(ServerMsg::Req(Request { id, input, reply }))
            .expect("server alive");
        rx
    }

    /// Stop the dispatcher and collect statistics.
    pub fn shutdown(mut self) -> ServerStats {
        let _ = self.tx.send(ServerMsg::Shutdown);
        self.dispatcher.take().expect("not yet shut down").join().expect("dispatcher join")
    }
}

fn dispatch_loop<E: Engine>(
    engine: E,
    policy: BatchPolicy,
    rx: Arc<Mutex<mpsc::Receiver<ServerMsg>>>,
) -> ServerStats {
    let mut batcher: Batcher<Request> = Batcher::new(BatchPolicy {
        max_batch: policy.max_batch.min(engine.max_batch()),
        ..policy
    });
    let mut stats = ServerStats::default();
    let mut open = true;

    while open || !batcher.is_empty() {
        // Pull what's available without exceeding the batch deadline.
        let now = Instant::now();
        let wait = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        if open {
            match rx.lock().unwrap().recv_timeout(wait) {
                Ok(ServerMsg::Req(r)) => batcher.push(r),
                Ok(ServerMsg::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain anything else queued
            while let Ok(msg) = rx.lock().unwrap().try_recv() {
                match msg {
                    ServerMsg::Req(r) => batcher.push(r),
                    ServerMsg::Shutdown => open = false,
                }
            }
        }

        let now = Instant::now();
        if batcher.ready(now) || (!open && !batcher.is_empty()) {
            let batch = batcher.take_batch();
            let n = batch.len();
            let in_len = engine.input_len();
            let mut flat = Vec::with_capacity(n * in_len);
            for p in &batch {
                assert_eq!(p.item.input.len(), in_len, "bad input length");
                flat.extend_from_slice(&p.item.input);
            }
            let t0 = Instant::now();
            let out = engine.infer(&flat, n);
            let infer_us = t0.elapsed().as_micros() as u64;
            assert_eq!(out.len(), n * engine.output_len(), "engine output length");
            let done = Instant::now();
            stats.batches += 1;
            stats.batch_sizes.push(n as f64);
            for (i, p) in batch.into_iter().enumerate() {
                let queue_us = done.duration_since(p.arrived).as_micros() as u64 - infer_us.min(
                    done.duration_since(p.arrived).as_micros() as u64,
                );
                let resp = Response {
                    id: p.item.id,
                    output: out[i * engine.output_len()..(i + 1) * engine.output_len()].to_vec(),
                    queue_us,
                    batch_size: n,
                    latency_us: done.duration_since(p.arrived).as_micros() as u64,
                };
                stats.served += 1;
                stats.latencies_us.push(resp.latency_us as f64);
                let _ = p.item.reply.send(resp);
            }
        }
    }
    stats
}

/// One simulation scenario: a network, the FuSe form to apply, and the
/// hardware config to price it under.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub network: Network,
    pub variant: FuseVariant,
    pub cfg: SimConfig,
}

/// Simulation-serving handle: submit scenarios, receive [`NetworkSim`]s.
/// All workers share one sweep-engine layer cache, so a traffic mix that
/// revisits networks/configs (EA populations, dashboard queries, repeated
/// what-if scenarios) degenerates to cache lookups.
pub struct SimServer {
    pool: Pool,
    cache: Arc<LayerCache>,
    submitted: std::sync::atomic::AtomicU64,
}

impl SimServer {
    /// `threads == 0` means one worker per CPU.
    pub fn new(threads: usize) -> SimServer {
        SimServer::with_cache(threads, Arc::new(LayerCache::new()))
    }

    /// Share a cache with other subsystems (sweeps, evaluators).
    pub fn with_cache(threads: usize, cache: Arc<LayerCache>) -> SimServer {
        SimServer { pool: Pool::new(threads), cache, submitted: 0.into() }
    }

    /// Submit one scenario; returns a receiver for the result.
    pub fn submit(&self, req: SimRequest) -> mpsc::Receiver<NetworkSim> {
        let (tx, rx) = mpsc::channel();
        let cache = Arc::clone(&self.cache);
        self.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.pool.spawn(move || {
            let net = req.variant.apply(&req.network);
            // The client may have hung up (dropped the receiver); that is
            // not the server's problem.
            let _ = tx.send(simulate_network_cached(&net, &req.cfg, &cache));
        });
        rx
    }

    /// Run a whole sweep plan synchronously on the server's pool + cache.
    pub fn sweep(&self, plan: &SweepPlan) -> SweepOutcome {
        run_sweep(plan, &self.pool, &self.cache)
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// Mock engine: output[j] = sum(input of sample j) + j-th class index.
    pub struct MockEngine {
        pub in_len: usize,
        pub out_len: usize,
        pub max_b: usize,
        pub delay: Duration,
    }

    impl Engine for MockEngine {
        fn input_len(&self) -> usize {
            self.in_len
        }
        fn output_len(&self) -> usize {
            self.out_len
        }
        fn max_batch(&self) -> usize {
            self.max_b
        }
        fn infer(&self, inputs: &[f32], n: usize) -> Vec<f32> {
            if !self.delay.is_zero() {
                thread::sleep(self.delay);
            }
            let mut out = Vec::with_capacity(n * self.out_len);
            for j in 0..n {
                let s: f32 = inputs[j * self.in_len..(j + 1) * self.in_len].iter().sum();
                for k in 0..self.out_len {
                    out.push(s + k as f32);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::MockEngine;
    use super::*;

    fn mock(delay_ms: u64) -> MockEngine {
        MockEngine {
            in_len: 4,
            out_len: 2,
            max_b: 8,
            delay: Duration::from_millis(delay_ms),
        }
    }

    #[test]
    fn serves_single_request() {
        let server = Server::start(mock(0), BatchPolicy::default());
        let rx = server.submit(vec![1.0, 2.0, 3.0, 4.0]);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(resp.output, vec![10.0, 11.0]);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_under_load() {
        let server = Server::start(
            mock(3),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
        );
        let rxs: Vec<_> = (0..24).map(|i| server.submit(vec![i as f32; 4])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.output[0], (i * 4) as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 24);
        // batching actually happened (fewer batches than requests)
        assert!(stats.batches < 24, "batches {}", stats.batches);
        assert!(stats.mean_batch() > 1.0);
    }

    #[test]
    fn shutdown_drains_queue() {
        let server = Server::start(
            mock(1),
            BatchPolicy { max_batch: 64, max_wait: Duration::from_secs(10) },
        );
        let rxs: Vec<_> = (0..5).map(|i| server.submit(vec![i as f32; 4])).collect();
        let stats = server.shutdown(); // deadline far away: drain on shutdown
        assert_eq!(stats.served, 5);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    #[test]
    fn sim_server_matches_direct_simulation() {
        use crate::nn::models;
        use crate::sim::simulate_network;
        let server = SimServer::new(2);
        let net = models::by_name("mobilenet-v2").unwrap();
        let rx = server.submit(SimRequest {
            network: net.clone(),
            variant: FuseVariant::Half,
            cfg: SimConfig::default(),
        });
        let sim = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let expect = simulate_network(&FuseVariant::Half.apply(&net), &SimConfig::default());
        assert_eq!(sim.total_cycles, expect.total_cycles);
        assert_eq!(sim.network, expect.network);
        assert_eq!(server.submitted(), 1);
    }

    #[test]
    fn sim_server_repeat_traffic_hits_cache() {
        use crate::nn::models;
        let server = SimServer::new(3);
        let net = models::by_name("mobilenet-v3-small").unwrap();
        let mk = || SimRequest {
            network: net.clone(),
            variant: FuseVariant::Base,
            cfg: SimConfig::default(),
        };
        let rxs: Vec<_> = (0..6).map(|_| server.submit(mk())).collect();
        let sims: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap())
            .collect();
        assert!(sims.windows(2).all(|w| w[0].total_cycles == w[1].total_cycles));
        let stats = server.cache_stats();
        assert!(stats.hits > 0, "repeat scenarios never hit the cache: {stats:?}");
        assert!(stats.entries <= net.layers.len());
    }

    #[test]
    fn sim_server_runs_sweep_plans() {
        use crate::nn::models;
        let server = SimServer::new(2);
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v3-small").unwrap()],
            vec![FuseVariant::Base, FuseVariant::Half],
            vec![SimConfig::default(), SimConfig::with_size(8)],
        );
        let out = server.sweep(&plan);
        assert_eq!(out.records().len(), 4);
        assert!(out.records().iter().all(|r| r.total_cycles() > 0));
    }

    #[test]
    fn latency_stats_populated() {
        let server = Server::start(mock(0), BatchPolicy::default());
        for _ in 0..10 {
            let rx = server.submit(vec![0.0; 4]);
            let _ = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        let stats = server.shutdown();
        let s = stats.latency_summary().unwrap();
        assert_eq!(s.n, 10);
        assert!(s.p99 >= s.p50);
    }
}
