//! Wire-level frontend: a `std::net::TcpListener` speaking the JSON
//! protocol of [`wire`](super::wire), one newline-delimited frame per
//! request/response, feeding any shared [`Service`].
//!
//! Threading model: one reader thread per connection decodes frames and
//! performs admission through `Service::call` (which never blocks on the
//! work), plus one writer thread per connection that redeems [`Ticket`]s
//! in request order. Responses on one connection are therefore FIFO;
//! clients that want out-of-order completion open more connections (ids
//! still match replies to requests either way).
//!
//! Lifecycle: a decoded `Shutdown` frame is forwarded to the service
//! (the [`Router`](super::server::Router) latches closed and acks
//! `Done`), the ack is flushed, and the accept loop is released.
//! Shutdown then *drains*: every connection reader polls the stop latch
//! (reads carry a short timeout), so idle connections close promptly
//! while queued replies still flush through each connection's writer —
//! in-flight work is never cut off, and [`WireServer::run`] returns
//! once every handler has exited. Frames that fail to decode answer
//! `bad_request` without killing the connection.

use super::protocol::{Request, RequestBody, Response, ServeError, Service, Ticket};
use super::wire::{
    decode_response, encode_request, encode_response, parse_json, Json, WireError,
};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// Upper bound a connection writer waits on any single ticket; a service
/// that never answers turns into a typed `deadline` error, not a wedged
/// connection.
pub const MAX_TICKET_WAIT: Duration = Duration::from_secs(600);

/// Read-poll interval on server-side connections: how often an idle
/// reader wakes to check the shutdown latch.
const READ_POLL: Duration = Duration::from_millis(500);

/// A read error that only means "nothing arrived within the timeout"
/// (Unix reports WouldBlock, Windows TimedOut).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// A bound TCP frontend. `bind` then `run`; `run` returns after a
/// `Shutdown` request has been served.
pub struct WireServer {
    listener: TcpListener,
    addr: SocketAddr,
    service: Arc<dyn Service>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) in front
    /// of `service`.
    pub fn bind(addr: &str, service: Arc<dyn Service>) -> std::io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(WireServer { listener, addr, service })
    }

    /// The actual bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept-and-serve until a `Shutdown` frame arrives; joins every
    /// connection handler before returning.
    pub fn run(self) -> std::io::Result<()> {
        let stop = Arc::new(AtomicBool::new(false));
        let mut handlers = Vec::new();
        for conn in self.listener.incoming() {
            if stop.load(Ordering::Acquire) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    // Transient accept failure (e.g. fd exhaustion):
                    // back off instead of spinning hot, and say so.
                    eprintln!("fuseconv serve: accept error: {e}");
                    thread::sleep(Duration::from_millis(50));
                    continue;
                }
            };
            let service = Arc::clone(&self.service);
            let stop = Arc::clone(&stop);
            let self_addr = self.addr;
            let h = thread::Builder::new()
                .name("fuseconv-conn".into())
                .spawn(move || handle_conn(stream, service, stop, self_addr))
                .expect("spawn connection handler");
            handlers.push(h);
        }
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Best-effort id recovery from a frame that failed full decoding, so
/// the bad_request response still correlates with the client's request.
fn salvage_id(line: &str) -> u64 {
    parse_json(line)
        .ok()
        .and_then(|v| v.get("id").and_then(Json::as_u64))
        .unwrap_or(0)
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<dyn Service>,
    stop: Arc<AtomicBool>,
    self_addr: SocketAddr,
) {
    // Reads poll: an idle connection must notice the shutdown latch and
    // close instead of parking `run`'s join forever.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let (wtx, wrx) = mpsc::channel::<Ticket>();
    let mut write_half = stream;
    let writer = thread::Builder::new()
        .name("fuseconv-conn-write".into())
        .spawn(move || {
            for ticket in wrx {
                let resp = ticket.recv_deadline(MAX_TICKET_WAIT);
                let mut line = encode_response(&resp);
                line.push('\n');
                if write_half.write_all(line.as_bytes()).is_err() {
                    break;
                }
                let _ = write_half.flush();
            }
            let _ = write_half.shutdown(std::net::Shutdown::Both);
        })
        .expect("spawn connection writer");

    let mut saw_shutdown = false;
    // One persistent buffer: a timed-out read keeps any partial frame,
    // and the next pass appends the rest (no mid-frame desync).
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => break, // client closed
            Ok(_) => {
                if !buf.ends_with('\n') {
                    // EOF mid-frame: nothing complete left to serve.
                    break;
                }
                let line = buf.trim();
                if !line.is_empty() {
                    let ticket = match super::wire::decode_request(line) {
                        Ok(req) => {
                            saw_shutdown = matches!(req.body, RequestBody::Shutdown);
                            service.call(req)
                        }
                        Err(e) => Ticket::immediate(Response::err(
                            salvage_id(line),
                            ServeError::BadRequest(e.to_string()),
                        )),
                    };
                    if wtx.send(ticket).is_err() {
                        break;
                    }
                }
                buf.clear();
                if saw_shutdown {
                    break;
                }
            }
            Err(e) if is_timeout(&e) => {
                if stop.load(Ordering::Acquire) {
                    break; // shutdown latched elsewhere: close this idle conn
                }
            }
            Err(_) => break,
        }
    }
    // Flush everything queued (including the Shutdown ack), then release
    // the accept loop with a self-dial if we are the closing connection.
    drop(wtx);
    let _ = writer.join();
    if saw_shutdown {
        stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(dial_addr(self_addr));
    }
}

/// Where to self-dial to release the accept loop: a wildcard bind
/// (0.0.0.0 / ::) is not connectable on every platform, so dial the
/// matching loopback with the bound port instead.
fn dial_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => {
                addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
            }
            SocketAddr::V6(_) => {
                addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
            }
        }
    }
    addr
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Blocking wire client: pipelined `send`/`recv` over one connection
/// (responses arrive in request order), for scripted load and tests.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
    /// Partial frame carried across a timed-out `recv`, so a retry
    /// resumes mid-frame instead of desynchronizing the stream.
    pending: String,
}

impl WireClient {
    /// Connect with `timeout` applied to connect/read/write
    /// (`Duration::ZERO` disables the timeouts).
    pub fn connect(addr: &str, timeout: Duration) -> std::io::Result<WireClient> {
        let sockaddr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "unresolvable address")
        })?;
        let stream = if timeout.is_zero() {
            TcpStream::connect(sockaddr)?
        } else {
            let s = TcpStream::connect_timeout(&sockaddr, timeout)?;
            s.set_read_timeout(Some(timeout))?;
            s.set_write_timeout(Some(timeout))?;
            s
        };
        let reader = BufReader::new(stream.try_clone()?);
        Ok(WireClient { reader, stream, pending: String::new() })
    }

    pub fn send(&mut self, req: &Request) -> std::io::Result<()> {
        let mut line = encode_request(req);
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()
    }

    /// Receive one response frame. A timed-out read returns an error but
    /// keeps the partially-read frame buffered — calling `recv` again
    /// continues from where the stream left off.
    pub fn recv(&mut self) -> Result<Response, WireError> {
        match self.reader.read_line(&mut self.pending) {
            Ok(0) => {
                self.pending.clear();
                Err(WireError("connection closed by server".into()))
            }
            Ok(_) => {
                let result = decode_response(self.pending.trim_end());
                self.pending.clear();
                result
            }
            // partial bytes stay in self.pending for the next attempt
            Err(e) => Err(WireError(format!("read: {e}"))),
        }
    }

    pub fn roundtrip(&mut self, req: &Request) -> Result<Response, WireError> {
        self.send(req).map_err(|e| WireError(format!("send: {e}")))?;
        self.recv()
    }
}

/// One-shot convenience: connect, send one request, await its reply.
pub fn request_once(
    addr: &str,
    req: &Request,
    timeout: Duration,
) -> Result<Response, WireError> {
    let mut client = WireClient::connect(addr, timeout)
        .map_err(|e| WireError(format!("connect {addr}: {e}")))?;
    client.roundtrip(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ConfigPatch, ModelSpec, Reply};
    use crate::coordinator::server::{Router, SimServer};
    use crate::sim::FuseVariant;

    fn start_sim_frontend() -> (String, thread::JoinHandle<()>) {
        let router = Router::new(SimServer::new(2));
        let server =
            WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind ephemeral");
        let addr = server.local_addr().to_string();
        let h = thread::spawn(move || server.run().expect("serve"));
        (addr, h)
    }

    #[test]
    fn frontend_serves_and_shuts_down_cleanly() {
        let (addr, h) = start_sim_frontend();
        let mut client = WireClient::connect(&addr, Duration::from_secs(30)).unwrap();

        // zoo
        let resp = client
            .roundtrip(&Request::new(1, RequestBody::Zoo))
            .expect("zoo roundtrip");
        assert_eq!(resp.id, 1);
        assert!(matches!(resp.result, Ok(Reply::Zoo(_))));

        // simulate by zoo name
        let resp = client
            .roundtrip(&Request::new(
                2,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                    variant: FuseVariant::Half,
                    config: ConfigPatch::sized(8),
                },
            ))
            .expect("simulate roundtrip");
        match resp.result {
            Ok(Reply::Sim(s)) => assert!(s.total_cycles > 0),
            other => panic!("expected sim, got {other:?}"),
        }

        // malformed frame answers bad_request without dropping the conn
        self::send_raw(&mut client, "{\"v\":1,\"id\":42,\"op\":\"nope\"}\n");
        let resp = client.recv().expect("error response");
        assert_eq!(resp.id, 42);
        assert!(matches!(resp.result, Err(ServeError::BadRequest(_))));

        // shutdown: ack arrives, listener exits
        let resp = client
            .roundtrip(&Request::new(3, RequestBody::Shutdown))
            .expect("shutdown ack");
        assert_eq!(resp.result, Ok(Reply::Done));
        h.join().expect("listener thread");

        // post-shutdown connects fail (listener gone)
        assert!(request_once(
            &addr,
            &Request::new(4, RequestBody::Stats),
            Duration::from_millis(500),
        )
        .is_err());
    }

    fn send_raw(client: &mut WireClient, raw: &str) {
        client.stream.write_all(raw.as_bytes()).unwrap();
        client.stream.flush().unwrap();
    }

    #[test]
    fn pipelined_requests_reply_in_order() {
        let (addr, h) = start_sim_frontend();
        let mut client = WireClient::connect(&addr, Duration::from_secs(60)).unwrap();
        for id in 10..14u64 {
            client
                .send(&Request::new(
                    id,
                    RequestBody::Simulate {
                        model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                        variant: FuseVariant::Base,
                        config: ConfigPatch::sized(8),
                    },
                ))
                .unwrap();
        }
        for id in 10..14u64 {
            let resp = client.recv().expect("pipelined response");
            assert_eq!(resp.id, id, "responses must be FIFO per connection");
            assert!(resp.is_ok());
        }
        let _ = client.roundtrip(&Request::new(99, RequestBody::Shutdown));
        h.join().unwrap();
    }
}
