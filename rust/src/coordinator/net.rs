//! Wire-level frontend: a `std::net::TcpListener` speaking the JSON
//! frame protocol of [`wire`](super::wire), one newline-delimited frame
//! per request or reply-stream element, feeding any shared [`Service`]
//! — the single-node [`Router`](super::server::Router) of `fuseconv
//! serve` or the multi-node [`ShardRouter`](super::shard::ShardRouter)
//! of `fuseconv shard`, which mounts here unchanged.
//!
//! Threading model (protocol v2): one reader thread per connection
//! decodes request frames and performs admission through `Service::call`
//! (which never blocks on the work). Each admitted request's [`Ticket`]
//! is drained by a small *stream forwarder* thread into one shared
//! per-connection writer channel, and the writer thread serializes
//! frames onto the socket in arrival order. Frames from concurrent
//! requests therefore interleave on the wire — every frame carries its
//! request id, and clients demultiplex by id ([`WireClient`] does this
//! transparently). There is no whole-response FIFO guarantee any more;
//! `final` frames land whenever their work completes.
//!
//! Per-connection limits: an optional request budget
//! (`--max-requests-per-conn`) bounds how many requests one connection
//! may submit; the first request past the budget is answered with a
//! terminal `busy` frame and the connection is closed. The writer
//! channel is *bounded* ([`WRITER_BOUND`]): a client that stops reading
//! backs the channel up and pauses the connection's stream forwarders
//! (which in turn pause the sweep coordinator through the bounded
//! [`Ticket`] buffer) instead of buffering frames without limit.
//!
//! Lifecycle: a decoded `Shutdown` frame is forwarded to the service
//! (the [`Router`](super::server::Router) latches closed and acks
//! `Done`), the ack is flushed, and the [`StopLatch`] trips — releasing
//! the accept loop of *every* frontend registered on it (the HTTP
//! listener of [`http`](super::http) shares the latch when `fuseconv
//! serve --http-port` runs both). Shutdown then *drains*: every
//! connection reader polls the latch (reads carry a short timeout), so
//! idle connections close promptly while queued frames still flush
//! through each connection's writer — in-flight streams are never cut
//! off (only a connection that is both backed up and unread past the
//! stall timeout is abandoned), and [`WireServer::run`] returns once
//! every handler has exited. Frames that fail to decode answer a
//! terminal `bad_request` without killing the connection.
//!
//! ```
//! use fuseconv::coordinator::StopLatch;
//! let latch = StopLatch::new();
//! assert!(!latch.stopped());
//! latch.trip(); // releases every listener registered on the latch
//! assert!(latch.stopped());
//! ```

use super::protocol::{
    collapse_stream, Frame, RecvError, Request, RequestBody, Response, ServeError, Service,
    StatsReply, SweepRow, Ticket,
};
use super::reactor::{self, ConnCx, Driver};
use super::wire::{
    decode_frame, encode_frame, encode_request, parse_json, Json, WireError,
};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Upper bound a stream forwarder waits between any two frames of one
/// ticket; a service that never answers turns into a typed `deadline`
/// error, not a wedged connection.
pub const MAX_TICKET_WAIT: Duration = Duration::from_secs(600);

/// Bound on a connection's writer channel, in frames (ROADMAP
/// backpressure item): the reader and every stream forwarder pause once
/// this many frames are queued for a client that is not draining its
/// socket, rather than buffering without limit.
pub const WRITER_BOUND: usize = 128;

/// Read-poll interval on server-side connections: how often an idle
/// reader wakes to check the shutdown latch.
const READ_POLL: Duration = Duration::from_millis(500);

/// Poll interval while a full writer channel is backpressuring a send.
const WRITE_POLL: Duration = Duration::from_millis(5);

/// Server-side socket write timeout: a connection that accepts zero
/// bytes for this long is declared dead and closed (the one case where
/// an in-flight stream is cut off). Matches [`MAX_TICKET_WAIT`].
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(600);

/// A read error that only means "nothing arrived within the timeout"
/// (Unix reports WouldBlock, Windows TimedOut).
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Constant-time token equality: runtime depends only on the length of
/// the *configured* token, never on how many leading bytes of the
/// presented one match, so the comparison cannot be used as a
/// byte-at-a-time oracle.
pub(crate) fn token_eq(expected: &str, presented: &str) -> bool {
    let a = expected.as_bytes();
    let b = presented.as_bytes();
    let mut diff = a.len() ^ b.len();
    for (i, &x) in a.iter().enumerate() {
        let y = if i < b.len() { b[i] } else { 0 };
        diff |= (x ^ y) as usize;
    }
    diff == 0
}

/// The auth gate both transports share: with no configured token every
/// request passes; with one, the request must present a matching token
/// (its absence is not secret — only the comparison is constant-time).
pub(crate) fn authorized(required: Option<&str>, presented: Option<&str>) -> bool {
    match required {
        None => true,
        Some(want) => presented.is_some_and(|got| token_eq(want, got)),
    }
}

// ---------------------------------------------------------------------------
// Shared frontend scaffolding (TCP frames + HTTP)
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct StopInner {
    stop: AtomicBool,
    /// Listener addresses to self-dial on trip, releasing blocked
    /// `accept` calls.
    listeners: Mutex<Vec<SocketAddr>>,
}

/// Shared shutdown latch for every wire frontend serving one deployment.
/// Each listener registers its bound address; [`StopLatch::trip`] sets
/// the stop flag and dials every registered listener so blocked accept
/// loops wake up and exit. Cloning shares the latch.
#[derive(Debug, Clone)]
pub struct StopLatch {
    inner: Arc<StopInner>,
}

impl StopLatch {
    pub fn new() -> StopLatch {
        StopLatch {
            inner: Arc::new(StopInner {
                stop: AtomicBool::new(false),
                listeners: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Has shutdown been requested?
    pub fn stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Acquire)
    }

    /// Register a listener to be released (self-dialed) on [`trip`](StopLatch::trip).
    pub fn register(&self, addr: SocketAddr) {
        self.inner.listeners.lock().unwrap().push(addr);
    }

    /// Latch shutdown and release every registered accept loop.
    pub fn trip(&self) {
        self.inner.stop.store(true, Ordering::Release);
        for addr in self.inner.listeners.lock().unwrap().iter() {
            let _ = TcpStream::connect(dial_addr(*addr));
        }
    }
}

impl Default for StopLatch {
    fn default() -> StopLatch {
        StopLatch::new()
    }
}

/// Transport concurrency model both frontends can run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// Thread-per-connection: one reader + one writer thread per
    /// connection, plus one forwarder thread per in-flight stream.
    #[default]
    Threaded,
    /// Single-threaded epoll readiness loop
    /// ([`reactor`](super::reactor)); Linux only — `run` reports
    /// `Unsupported` elsewhere.
    Epoll,
}

impl Transport {
    /// Parse a `--transport` flag value.
    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "threaded" => Some(Transport::Threaded),
            "epoll" => Some(Transport::Epoll),
            _ => None,
        }
    }
}

#[derive(Debug, Default)]
struct GaugeCells {
    open_conns: AtomicU64,
    active_streams: AtomicU64,
    transport_threads: AtomicU64,
}

/// Live transport gauges (`open_conns` / `active_streams` /
/// `transport_threads`), shared by every frontend of one deployment
/// and overlaid onto `Stats` replies (see `Router::with_gauges`).
/// Cloning shares the cells; increments are RAII [`GaugeGuard`]s, so a
/// leaked forwarder is visible as a gauge that never returns to
/// baseline.
#[derive(Debug, Clone, Default)]
pub struct TransportGauges {
    cells: Arc<GaugeCells>,
}

impl TransportGauges {
    pub fn new() -> TransportGauges {
        TransportGauges::default()
    }

    fn guard(&self, cell: fn(&GaugeCells) -> &AtomicU64) -> GaugeGuard {
        cell(&self.cells).fetch_add(1, Ordering::AcqRel);
        GaugeGuard { cells: Arc::clone(&self.cells), cell }
    }

    /// Count one open connection until the guard drops.
    pub fn conn_opened(&self) -> GaugeGuard {
        self.guard(|c| &c.open_conns)
    }

    /// Count one in-flight reply stream until the guard drops.
    pub fn stream_started(&self) -> GaugeGuard {
        self.guard(|c| &c.active_streams)
    }

    /// Count one transport-owned OS thread until the guard drops.
    pub fn thread_started(&self) -> GaugeGuard {
        self.guard(|c| &c.transport_threads)
    }

    /// Connections currently open.
    pub fn open_conns(&self) -> u64 {
        self.cells.open_conns.load(Ordering::Acquire)
    }

    /// Reply streams currently being forwarded.
    pub fn active_streams(&self) -> u64 {
        self.cells.active_streams.load(Ordering::Acquire)
    }

    /// OS threads the transports currently own.
    pub fn transport_threads(&self) -> u64 {
        self.cells.transport_threads.load(Ordering::Acquire)
    }

    /// Stamp the live gauge values into a stats reply.
    pub fn overlay(&self, s: &mut StatsReply) {
        s.open_conns = self.open_conns();
        s.active_streams = self.active_streams();
        s.transport_threads = self.transport_threads();
    }
}

/// RAII increment of one [`TransportGauges`] cell; decrements on drop.
pub struct GaugeGuard {
    cells: Arc<GaugeCells>,
    cell: fn(&GaugeCells) -> &AtomicU64,
}

impl Drop for GaugeGuard {
    fn drop(&mut self) {
        (self.cell)(&self.cells).fetch_sub(1, Ordering::AcqRel);
    }
}

/// Per-connection request budget, counted identically by the TCP and
/// HTTP frontends: only *decoded* requests consume a slot (malformed
/// input answers `bad_request` for free), and the first request past
/// the cap is answered `busy` before the connection closes.
pub(crate) struct RequestBudget {
    cap: Option<u64>,
    used: u64,
}

impl RequestBudget {
    pub(crate) fn new(cap: Option<u64>) -> RequestBudget {
        RequestBudget { cap, used: 0 }
    }

    /// Count one decoded request; `false` once it exceeds the budget.
    pub(crate) fn admit(&mut self) -> bool {
        self.used += 1;
        match self.cap {
            Some(cap) => self.used <= cap,
            None => true,
        }
    }
}

/// The accept loop both frontends share: accept until the stop latch
/// trips, spawn one named handler thread per connection (transient
/// accept failures back off instead of spinning), and join every
/// handler before returning so shutdown always drains.
pub(crate) fn accept_loop(
    listener: TcpListener,
    stop: StopLatch,
    thread_name: &str,
    handler: impl Fn(TcpStream) + Send + Sync + 'static,
) -> std::io::Result<()> {
    let handler = Arc::new(handler);
    let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if stop.stopped() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("fuseconv serve: accept error: {e}");
                thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let h = Arc::clone(&handler);
        let t = thread::Builder::new()
            .name(thread_name.into())
            .spawn(move || h(stream))
            .expect("spawn connection handler");
        handlers.push(t);
        // Reap finished handlers so a long-lived listener serving many
        // short connections doesn't grow the join list without bound.
        let mut live = Vec::with_capacity(handlers.len());
        for t in handlers.drain(..) {
            if t.is_finished() {
                let _ = t.join();
            } else {
                live.push(t);
            }
        }
        handlers = live;
    }
    for t in handlers {
        let _ = t.join();
    }
    Ok(())
}

/// A bound TCP frontend. `bind` then `run`; `run` returns after a
/// `Shutdown` request has been served (or the shared [`StopLatch`]
/// trips from another frontend).
pub struct WireServer {
    listener: TcpListener,
    addr: SocketAddr,
    service: Arc<dyn Service>,
    /// Per-connection request budget; `None` = unlimited.
    max_requests_per_conn: Option<u64>,
    /// When set, every request must carry a matching `token` envelope
    /// field; mismatches answer a terminal `unauthorized` frame.
    auth_token: Option<Arc<str>>,
    stop: StopLatch,
    transport: Transport,
    gauges: TransportGauges,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) in front
    /// of `service`, with no per-connection limits and a private stop
    /// latch.
    pub fn bind(addr: &str, service: Arc<dyn Service>) -> std::io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(WireServer {
            listener,
            addr,
            service,
            max_requests_per_conn: None,
            auth_token: None,
            stop: StopLatch::new(),
            transport: Transport::default(),
            gauges: TransportGauges::default(),
        })
    }

    /// Require every request on this frontend to carry `token` in its
    /// envelope (`None` = open). Checked before admission and before the
    /// budget, with a constant-time comparison; failures answer a
    /// terminal `unauthorized` frame without consuming a budget slot.
    pub fn with_auth_token(mut self, token: Option<String>) -> WireServer {
        self.auth_token = token.map(Arc::from);
        self
    }

    /// Select the concurrency model (`Threaded` is the default).
    pub fn with_transport(mut self, transport: Transport) -> WireServer {
        self.transport = transport;
        self
    }

    /// Share live gauges with other frontends (and the service's
    /// `Stats` reply) instead of keeping private ones.
    pub fn with_gauges(mut self, gauges: TransportGauges) -> WireServer {
        self.gauges = gauges;
        self
    }

    /// Cap how many requests one connection may submit. The request that
    /// exceeds the budget is answered `busy` and the connection closes.
    pub fn with_request_budget(mut self, budget: Option<u64>) -> WireServer {
        self.max_requests_per_conn = budget;
        self
    }

    /// Share a shutdown latch with other frontends: a `Shutdown` served
    /// by any of them stops all of them.
    pub fn with_stop(mut self, stop: StopLatch) -> WireServer {
        self.stop = stop;
        self
    }

    /// The actual bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept-and-serve until a `Shutdown` frame arrives. The threaded
    /// transport joins every connection handler before returning; the
    /// epoll transport returns once every connection has drained.
    pub fn run(self) -> std::io::Result<()> {
        self.stop.register(self.addr);
        let service = self.service;
        let budget = self.max_requests_per_conn;
        let auth = self.auth_token;
        let gauges = self.gauges;
        match self.transport {
            Transport::Threaded => {
                let stop = self.stop.clone();
                let _accept_thread = gauges.thread_started();
                let conn_gauges = gauges.clone();
                accept_loop(self.listener, self.stop, "fuseconv-conn", move |stream| {
                    handle_conn(
                        stream,
                        Arc::clone(&service),
                        stop.clone(),
                        budget,
                        auth.clone(),
                        conn_gauges.clone(),
                    )
                })
            }
            Transport::Epoll => {
                let driver_gauges = gauges.clone();
                reactor::serve_event_loop(self.listener, self.stop, gauges, move || {
                    Box::new(FrameDriver::new(
                        Arc::clone(&service),
                        budget,
                        auth.clone(),
                        driver_gauges.clone(),
                    )) as Box<dyn Driver>
                })
            }
        }
    }
}

/// Best-effort id recovery from a frame that failed full decoding, so
/// the bad_request frame still correlates with the client's request.
fn salvage_id(line: &str) -> u64 {
    parse_json(line)
        .ok()
        .and_then(|v| v.get("id").and_then(Json::as_u64))
        .unwrap_or(0)
}

/// Backpressure-aware send into a connection's bounded writer channel:
/// waits (politely polling) while the channel is full, gives up when
/// the writer is gone or — so a backed-up connection cannot park
/// shutdown forever — once the stop latch trips mid-wait. Returns
/// `false` when the frame could not be delivered.
fn send_frame(
    out: &mpsc::SyncSender<(u64, Frame)>,
    mut item: (u64, Frame),
    stop: &StopLatch,
) -> bool {
    loop {
        match out.try_send(item) {
            Ok(()) => return true,
            Err(mpsc::TrySendError::Full(back)) => {
                if stop.stopped() {
                    return false;
                }
                item = back;
                thread::sleep(WRITE_POLL);
            }
            Err(mpsc::TrySendError::Disconnected(_)) => return false,
        }
    }
}

/// Drain one ticket's frame stream into the connection's shared writer
/// channel, tagging every frame with the request id. A forwarder always
/// terminates the stream with a `final` frame, even when the service
/// wedges (typed `deadline`) or drops the sink (typed `shutdown`); a
/// full writer channel pauses the forwarder (and, transitively, the
/// sweep coordinator behind the bounded ticket buffer) until the client
/// drains.
fn forward_stream(mut ticket: Ticket, out: mpsc::SyncSender<(u64, Frame)>, stop: StopLatch) {
    let id = ticket.id();
    loop {
        match ticket.recv_deadline(MAX_TICKET_WAIT) {
            Ok(frame) => {
                let last = frame.is_final();
                if !send_frame(&out, (id, frame), &stop) || last {
                    break;
                }
            }
            Err(RecvError::Deadline) => {
                let _ = send_frame(&out, (id, Frame::Final(Err(ServeError::Deadline))), &stop);
                break;
            }
            Err(RecvError::Disconnected) => {
                let _ = send_frame(&out, (id, Frame::Final(Err(ServeError::Shutdown))), &stop);
                break;
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<dyn Service>,
    stop: StopLatch,
    budget: Option<u64>,
    auth: Option<Arc<str>>,
    gauges: TransportGauges,
) {
    let _conn_gauge = gauges.conn_opened();
    let _reader_gauge = gauges.thread_started();
    // Reads poll: an idle connection must notice the shutdown latch and
    // close instead of parking `run`'s join forever. Writes time out so
    // a socket that accepts zero bytes eventually counts as dead.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    // One writer thread serializes interleaved frames from every
    // in-flight stream (plus immediate error frames from the reader).
    // The channel is bounded: a client that stops draining its socket
    // backs it up and pauses the senders (see WRITER_BOUND).
    let (wtx, wrx) = mpsc::sync_channel::<(u64, Frame)>(WRITER_BOUND);
    let mut write_half = stream;
    let writer_gauges = gauges.clone();
    let writer = thread::Builder::new()
        .name("fuseconv-conn-write".into())
        .spawn(move || {
            let _writer_gauge = writer_gauges.thread_started();
            for (id, frame) in wrx {
                let mut line = encode_frame(id, &frame);
                line.push('\n');
                if write_half.write_all(line.as_bytes()).is_err() {
                    break;
                }
                let _ = write_half.flush();
            }
            let _ = write_half.shutdown(std::net::Shutdown::Both);
        })
        .expect("spawn connection writer");

    // In-flight stream table: one forwarder per admitted request; all are
    // joined before the connection closes so streams are never cut off.
    let mut streams: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut budget = RequestBudget::new(budget);
    let mut saw_shutdown = false;
    // One persistent buffer: a timed-out read keeps any partial frame,
    // and the next pass appends the rest (no mid-frame desync).
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => break, // client closed
            Ok(_) => {
                if !buf.ends_with('\n') {
                    // EOF mid-frame: nothing complete left to serve.
                    break;
                }
                let line = buf.trim();
                if !line.is_empty() {
                    match super::wire::decode_request(line) {
                        Ok(req) => {
                            // Auth gate first: an unauthorized request is
                            // answered (typed, same id) and consumes no
                            // budget slot — and it can't shut us down.
                            if !authorized(auth.as_deref(), req.token.as_deref()) {
                                let _ = send_frame(
                                    &wtx,
                                    (req.id, Frame::Final(Err(ServeError::Unauthorized))),
                                    &stop,
                                );
                                buf.clear();
                                continue;
                            }
                            // Only decoded requests count against the
                            // budget (malformed lines answer bad_request
                            // without consuming a slot).
                            if !budget.admit() {
                                // Budget exhausted: typed Busy, hang up.
                                let _ = send_frame(
                                    &wtx,
                                    (req.id, Frame::Final(Err(ServeError::Busy))),
                                    &stop,
                                );
                                break;
                            }
                            saw_shutdown = matches!(req.body, RequestBody::Shutdown);
                            let mut ticket = service.call(req);
                            // Fast path: admission-time errors and
                            // immediate replies (Busy, Stats, Zoo, the
                            // Shutdown ack, ...) already hold their
                            // terminal frame — forward it without
                            // spawning a per-request thread.
                            let still_streaming = match ticket.try_recv() {
                                Ok(Some(frame)) if frame.is_final() => {
                                    let _ = send_frame(&wtx, (ticket.id(), frame), &stop);
                                    false
                                }
                                Ok(Some(frame)) => {
                                    // stream already flowing: pass the
                                    // first frame on, forward the rest
                                    // from a dedicated thread below
                                    let _ = send_frame(&wtx, (ticket.id(), frame), &stop);
                                    true
                                }
                                Ok(None) => true,
                                Err(_) => {
                                    let _ = send_frame(
                                        &wtx,
                                        (ticket.id(), Frame::Final(Err(ServeError::Shutdown))),
                                        &stop,
                                    );
                                    false
                                }
                            };
                            if still_streaming {
                                let out = wtx.clone();
                                let stop2 = stop.clone();
                                let stream_gauges = gauges.clone();
                                // The ticket rides in a take-slot so it
                                // survives a failed spawn (the closure —
                                // and anything moved into it — is
                                // dropped on spawn error).
                                let slot = Arc::new(std::sync::Mutex::new(Some(ticket)));
                                let slot2 = Arc::clone(&slot);
                                match thread::Builder::new()
                                    .name("fuseconv-conn-stream".into())
                                    .spawn(move || {
                                        let _thread_gauge = stream_gauges.thread_started();
                                        let _stream_gauge = stream_gauges.stream_started();
                                        if let Some(t) = slot2.lock().unwrap().take() {
                                            forward_stream(t, out, stop2);
                                        }
                                    }) {
                                    Ok(h) => streams.push(h),
                                    // Thread exhaustion: forward inline —
                                    // pipelining on this connection
                                    // stalls, but the request is still
                                    // answered.
                                    Err(_) => {
                                        if let Some(t) = slot.lock().unwrap().take() {
                                            forward_stream(t, wtx.clone(), stop.clone());
                                        }
                                    }
                                }
                            }
                            // Reap completed forwarders so a long-lived
                            // connection doesn't accumulate unjoined
                            // threads one per request served.
                            let mut live = Vec::with_capacity(streams.len());
                            for h in streams.drain(..) {
                                if h.is_finished() {
                                    let _ = h.join();
                                } else {
                                    live.push(h);
                                }
                            }
                            streams = live;
                        }
                        Err(e) => {
                            let _ = send_frame(
                                &wtx,
                                (
                                    salvage_id(line),
                                    Frame::Final(Err(ServeError::BadRequest(e.to_string()))),
                                ),
                                &stop,
                            );
                        }
                    }
                }
                buf.clear();
                if saw_shutdown {
                    break;
                }
            }
            Err(e) if is_timeout(&e) => {
                if stop.stopped() {
                    break; // shutdown latched elsewhere: close this idle conn
                }
            }
            Err(_) => break,
        }
    }
    // Let every in-flight stream finish (including the Shutdown ack),
    // flush the writer, then trip the latch — releasing every frontend
    // registered on it — if we are the closing connection.
    for h in streams {
        let _ = h.join();
    }
    drop(wtx);
    let _ = writer.join();
    if saw_shutdown {
        stop.trip();
    }
}

/// Where to self-dial to release the accept loop: a wildcard bind
/// (0.0.0.0 / ::) is not connectable on every platform, so dial the
/// matching loopback with the bound port instead.
fn dial_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => {
                addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
            }
            SocketAddr::V6(_) => {
                addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
            }
        }
    }
    addr
}

// ---------------------------------------------------------------------------
// Epoll transport: frame-protocol driver
// ---------------------------------------------------------------------------

/// Append one encoded frame line to a connection's pending output.
fn push_wire_frame(out: &mut Vec<u8>, id: u64, frame: &Frame) {
    let mut line = encode_frame(id, frame);
    line.push('\n');
    out.extend_from_slice(line.as_bytes());
}

/// One in-flight stream on an epoll connection: the ticket the event
/// loop polls in place of a forwarder thread.
struct EpollStream {
    ticket: Ticket,
    /// Last frame arrival — the [`MAX_TICKET_WAIT`] clock.
    last_frame: Instant,
    _gauge: GaugeGuard,
}

/// The newline-framed TCP protocol as a nonblocking [`Driver`]: wire
/// semantics identical to [`handle_conn`] (same admission, budget,
/// fast path, and error taxonomy), with per-ticket forwarder threads
/// collapsed into [`Driver::pump`] polls.
struct FrameDriver {
    service: Arc<dyn Service>,
    budget: RequestBudget,
    auth: Option<Arc<str>>,
    gauges: TransportGauges,
    streams: Vec<EpollStream>,
    /// Stop consuming input: shutdown seen, budget bounced, or EOF.
    draining: bool,
}

impl FrameDriver {
    fn new(
        service: Arc<dyn Service>,
        budget: Option<u64>,
        auth: Option<Arc<str>>,
        gauges: TransportGauges,
    ) -> FrameDriver {
        FrameDriver {
            service,
            budget: RequestBudget::new(budget),
            auth,
            gauges,
            streams: Vec::new(),
            draining: false,
        }
    }

    /// Serve one decoded line — the nonblocking mirror of the threaded
    /// reader's per-line block.
    fn serve_line(&mut self, line: &str, cx: &mut ConnCx<'_>, now: Instant) {
        match super::wire::decode_request(line) {
            Ok(req) => {
                // Same gate as the threaded reader: unauthorized answers
                // typed, consumes no budget, and cannot latch shutdown.
                if !authorized(self.auth.as_deref(), req.token.as_deref()) {
                    push_wire_frame(
                        cx.out,
                        req.id,
                        &Frame::Final(Err(ServeError::Unauthorized)),
                    );
                    return;
                }
                if !self.budget.admit() {
                    push_wire_frame(cx.out, req.id, &Frame::Final(Err(ServeError::Busy)));
                    self.draining = true;
                    *cx.close_after_flush = true;
                    return;
                }
                let shutdown = matches!(req.body, RequestBody::Shutdown);
                let mut ticket = self.service.call(req);
                // Fast path: immediate replies forward without joining
                // the stream table.
                let still_streaming = match ticket.try_recv() {
                    Ok(Some(frame)) if frame.is_final() => {
                        push_wire_frame(cx.out, ticket.id(), &frame);
                        false
                    }
                    Ok(Some(frame)) => {
                        push_wire_frame(cx.out, ticket.id(), &frame);
                        true
                    }
                    Ok(None) => true,
                    Err(_) => {
                        push_wire_frame(
                            cx.out,
                            ticket.id(),
                            &Frame::Final(Err(ServeError::Shutdown)),
                        );
                        false
                    }
                };
                if still_streaming {
                    self.streams.push(EpollStream {
                        ticket,
                        last_frame: now,
                        _gauge: self.gauges.stream_started(),
                    });
                }
                if shutdown {
                    // stop reading; ack flushes, then the latch trips
                    self.draining = true;
                    *cx.close_after_flush = true;
                    *cx.trip_after_flush = true;
                }
            }
            Err(e) => {
                push_wire_frame(
                    cx.out,
                    salvage_id(line),
                    &Frame::Final(Err(ServeError::BadRequest(e.to_string()))),
                );
            }
        }
    }
}

impl Driver for FrameDriver {
    fn on_data(&mut self, cx: &mut ConnCx<'_>, now: Instant) {
        while !self.draining {
            let Some(pos) = cx.inbuf.iter().position(|&b| b == b'\n') else { break };
            let line_bytes: Vec<u8> = cx.inbuf.drain(..=pos).collect();
            let Ok(line) = std::str::from_utf8(&line_bytes) else {
                // mirrors the threaded reader: a non-UTF-8 stream is
                // desynchronized beyond repair — hang up
                self.draining = true;
                *cx.close_after_flush = true;
                break;
            };
            let line = line.trim().to_string();
            if line.is_empty() {
                continue;
            }
            self.serve_line(&line, cx, now);
        }
    }

    fn on_eof(&mut self, cx: &mut ConnCx<'_>) {
        // Keep pumping in-flight streams (their frames still flush to a
        // half-closed peer); the loop closes us once they drain.
        self.draining = true;
        *cx.close_after_flush = true;
    }

    fn pump(&mut self, cx: &mut ConnCx<'_>, now: Instant) {
        let mut wake: Option<Instant> = None;
        let out = &mut *cx.out;
        self.streams.retain_mut(|s| loop {
            if out.len() >= reactor::OUT_BOUND {
                // Backpressure maps onto write readiness: pending
                // output is over the bound, so park this stream (its
                // producer parks on the bounded ticket buffer) until
                // the socket drains.
                break true;
            }
            match s.ticket.try_recv() {
                Ok(Some(frame)) => {
                    s.last_frame = now;
                    let done = frame.is_final();
                    push_wire_frame(out, s.ticket.id(), &frame);
                    if done {
                        break false;
                    }
                }
                Ok(None) => {
                    if now.duration_since(s.last_frame) > MAX_TICKET_WAIT {
                        push_wire_frame(
                            out,
                            s.ticket.id(),
                            &Frame::Final(Err(ServeError::Deadline)),
                        );
                        break false;
                    }
                    let at = s.last_frame + MAX_TICKET_WAIT;
                    if wake.is_none_or(|w| at < w) {
                        wake = Some(at);
                    }
                    break true;
                }
                Err(_) => {
                    push_wire_frame(out, s.ticket.id(), &Frame::Final(Err(ServeError::Shutdown)));
                    break false;
                }
            }
        });
        if let Some(at) = wake {
            if cx.wake_at.is_none_or(|w| at < w) {
                *cx.wake_at = Some(at);
            }
        }
    }

    fn is_streaming(&self) -> bool {
        !self.streams.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Blocking wire client for protocol v2: pipelined `send` plus
/// id-demultiplexed frame receives over one connection. Frames for
/// requests other than the one being awaited are parked in per-id queues
/// and handed out when their request is polled, so concurrent streams on
/// one connection reassemble independently.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
    /// Partial frame carried across a timed-out read, so a retry
    /// resumes mid-frame instead of desynchronizing the stream.
    pending: String,
    /// Demux table: frames read off the wire while waiting on a
    /// different request id.
    parked: HashMap<u64, VecDeque<Frame>>,
}

impl WireClient {
    /// Connect with `timeout` applied to connect/read/write
    /// (`Duration::ZERO` disables the timeouts).
    pub fn connect(addr: &str, timeout: Duration) -> std::io::Result<WireClient> {
        let sockaddr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "unresolvable address")
        })?;
        let stream = if timeout.is_zero() {
            TcpStream::connect(sockaddr)?
        } else {
            let s = TcpStream::connect_timeout(&sockaddr, timeout)?;
            s.set_read_timeout(Some(timeout))?;
            s.set_write_timeout(Some(timeout))?;
            s
        };
        let reader = BufReader::new(stream.try_clone()?);
        Ok(WireClient {
            reader,
            stream,
            pending: String::new(),
            parked: HashMap::new(),
        })
    }

    pub fn send(&mut self, req: &Request) -> std::io::Result<()> {
        let mut line = encode_request(req);
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()
    }

    /// Read the next frame off the wire (no demux). A timed-out read
    /// returns an error but keeps the partially-read frame buffered —
    /// calling again continues from where the stream left off.
    fn read_frame(&mut self) -> Result<(u64, Frame), WireError> {
        match self.reader.read_line(&mut self.pending) {
            Ok(0) => {
                self.pending.clear();
                Err(WireError("connection closed by server".into()))
            }
            Ok(_) => {
                let result = decode_frame(self.pending.trim_end());
                self.pending.clear();
                result
            }
            // partial bytes stay in self.pending for the next attempt
            Err(e) => Err(WireError(format!("read: {e}"))),
        }
    }

    /// Pop the oldest parked frame for `id`, dropping the queue once it
    /// drains so the demux table never grows with finished request ids.
    fn unpark(&mut self, id: u64) -> Option<Frame> {
        let q = self.parked.get_mut(&id)?;
        let frame = q.pop_front();
        if q.is_empty() {
            self.parked.remove(&id);
        }
        frame
    }

    /// Next frame for *any* request: parked frames first (oldest id
    /// order is not defined), then the wire. The workhorse for streaming
    /// consumers that track several requests at once.
    pub fn recv_any(&mut self) -> Result<(u64, Frame), WireError> {
        let parked_id = self
            .parked
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|(&id, _)| id);
        if let Some(id) = parked_id {
            let frame = self.unpark(id).expect("non-empty parked queue");
            return Ok((id, frame));
        }
        self.read_frame()
    }

    /// Next frame of request `id`'s stream, demultiplexing: frames for
    /// other ids encountered on the way are parked for their own polls.
    pub fn recv_frame(&mut self, id: u64) -> Result<Frame, WireError> {
        if let Some(frame) = self.unpark(id) {
            return Ok(frame);
        }
        loop {
            let (got, frame) = self.read_frame()?;
            if got == id {
                return Ok(frame);
            }
            self.parked.entry(got).or_default().push_back(frame);
        }
    }

    /// Drain request `id`'s stream to its terminal frame and collapse it
    /// into one [`Response`] (streamed sweep rows are merged, mirroring
    /// [`Ticket::wait`]).
    pub fn recv_response(&mut self, id: u64) -> Result<Response, WireError> {
        let mut rows: Vec<SweepRow> = Vec::new();
        loop {
            match self.recv_frame(id)? {
                Frame::Progress { .. } => {}
                Frame::Row(row) => rows.push(row),
                // live pareto rows are a display stream; the terminal
                // Search reply already carries the converged frontier
                Frame::SearchRow(_) => {}
                Frame::Final(result) => {
                    return Ok(Response { id, result: collapse_stream(result, rows) });
                }
            }
        }
    }

    pub fn roundtrip(&mut self, req: &Request) -> Result<Response, WireError> {
        self.send(req).map_err(|e| WireError(format!("send: {e}")))?;
        self.recv_response(req.id)
    }
}

/// One-shot convenience: connect, send one request, await its reply.
pub fn request_once(
    addr: &str,
    req: &Request,
    timeout: Duration,
) -> Result<Response, WireError> {
    let mut client = WireClient::connect(addr, timeout)
        .map_err(|e| WireError(format!("connect {addr}: {e}")))?;
    client.roundtrip(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ConfigPatch, ModelSpec, Reply};
    use crate::coordinator::server::{Router, SimServer};
    use crate::sim::FuseVariant;

    fn start_sim_frontend() -> (String, thread::JoinHandle<()>) {
        start_sim_frontend_budget(None)
    }

    fn start_sim_frontend_budget(
        budget: Option<u64>,
    ) -> (String, thread::JoinHandle<()>) {
        let router = Router::new(SimServer::new(2));
        let server = WireServer::bind("127.0.0.1:0", Arc::new(router))
            .expect("bind ephemeral")
            .with_request_budget(budget);
        let addr = server.local_addr().to_string();
        let h = thread::spawn(move || server.run().expect("serve"));
        (addr, h)
    }

    #[test]
    fn frontend_serves_and_shuts_down_cleanly() {
        let (addr, h) = start_sim_frontend();
        let mut client = WireClient::connect(&addr, Duration::from_secs(30)).unwrap();

        // zoo
        let resp = client
            .roundtrip(&Request::new(1, RequestBody::Zoo))
            .expect("zoo roundtrip");
        assert_eq!(resp.id, 1);
        assert!(matches!(resp.result, Ok(Reply::Zoo(_))));

        // simulate by zoo name
        let resp = client
            .roundtrip(&Request::new(
                2,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                    variant: FuseVariant::Half,
                    config: ConfigPatch::sized(8),
                },
            ))
            .expect("simulate roundtrip");
        match resp.result {
            Ok(Reply::Sim(s)) => assert!(s.total_cycles > 0),
            other => panic!("expected sim, got {other:?}"),
        }

        // malformed frame answers bad_request without dropping the conn
        self::send_raw(&mut client, "{\"v\":2,\"id\":42,\"op\":\"nope\"}\n");
        let resp = client.recv_response(42).expect("error response");
        assert_eq!(resp.id, 42);
        assert!(matches!(resp.result, Err(ServeError::BadRequest(_))));

        // shutdown: ack arrives, listener exits
        let resp = client
            .roundtrip(&Request::new(3, RequestBody::Shutdown))
            .expect("shutdown ack");
        assert_eq!(resp.result, Ok(Reply::Done));
        h.join().expect("listener thread");

        // post-shutdown connects fail (listener gone)
        assert!(request_once(
            &addr,
            &Request::new(4, RequestBody::Stats),
            Duration::from_millis(500),
        )
        .is_err());
    }

    fn send_raw(client: &mut WireClient, raw: &str) {
        client.stream.write_all(raw.as_bytes()).unwrap();
        client.stream.flush().unwrap();
    }

    #[test]
    fn pipelined_requests_each_get_their_own_reply() {
        // v2 drops the whole-response FIFO guarantee (streams interleave);
        // what must hold is that every id is answered exactly once and
        // demux hands each poll the right stream.
        let (addr, h) = start_sim_frontend();
        let mut client = WireClient::connect(&addr, Duration::from_secs(60)).unwrap();
        for id in 10..14u64 {
            client
                .send(&Request::new(
                    id,
                    RequestBody::Simulate {
                        model: ModelSpec::Zoo("mobilenet-v3-small".into()),
                        variant: FuseVariant::Base,
                        config: ConfigPatch::sized(8),
                    },
                ))
                .unwrap();
        }
        // redeem out of order on purpose: the demux table must park and
        // replay frames read while waiting on a different id
        for id in (10..14u64).rev() {
            let resp = client.recv_response(id).expect("pipelined response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok());
        }
        let _ = client.roundtrip(&Request::new(99, RequestBody::Shutdown));
        h.join().unwrap();
    }

    #[test]
    fn request_budget_answers_busy_and_closes() {
        let (addr, h) = start_sim_frontend_budget(Some(2));
        let mut client = WireClient::connect(&addr, Duration::from_secs(30)).unwrap();
        for id in [1, 2] {
            let resp = client.roundtrip(&Request::new(id, RequestBody::Stats)).unwrap();
            assert!(resp.is_ok(), "within budget: {resp:?}");
        }
        // third request: typed Busy, then the server hangs up
        let resp = client.roundtrip(&Request::new(3, RequestBody::Stats)).unwrap();
        assert_eq!(resp.result, Err(ServeError::Busy));
        assert!(
            client.roundtrip(&Request::new(4, RequestBody::Stats)).is_err(),
            "connection must be closed after the budget bounce"
        );
        // a fresh connection gets a fresh budget
        let mut c2 = WireClient::connect(&addr, Duration::from_secs(30)).unwrap();
        assert!(c2.roundtrip(&Request::new(5, RequestBody::Stats)).unwrap().is_ok());
        let _ = c2.roundtrip(&Request::new(6, RequestBody::Shutdown));
        h.join().unwrap();
    }

    #[test]
    fn token_eq_is_exact() {
        assert!(token_eq("s3cret", "s3cret"));
        assert!(!token_eq("s3cret", "s3cres"));
        assert!(!token_eq("s3cret", "s3cre"));
        assert!(!token_eq("s3cret", "s3crets"));
        assert!(!token_eq("s3cret", ""));
        assert!(token_eq("", ""));
    }

    #[test]
    fn auth_token_gates_every_op_including_shutdown() {
        let router = Router::new(SimServer::new(1));
        let server = WireServer::bind("127.0.0.1:0", Arc::new(router))
            .expect("bind ephemeral")
            .with_auth_token(Some("s3cret".into()));
        let addr = server.local_addr().to_string();
        let h = thread::spawn(move || server.run().expect("serve"));
        let mut client = WireClient::connect(&addr, Duration::from_secs(30)).unwrap();

        // missing and wrong tokens answer typed unauthorized (same conn)
        let resp = client.roundtrip(&Request::new(1, RequestBody::Stats)).unwrap();
        assert_eq!(resp.result, Err(ServeError::Unauthorized));
        let resp = client
            .roundtrip(&Request::new(2, RequestBody::Stats).with_token("wrong"))
            .unwrap();
        assert_eq!(resp.result, Err(ServeError::Unauthorized));
        // an unauthorized shutdown must NOT stop the deployment
        let resp = client
            .roundtrip(&Request::new(3, RequestBody::Shutdown))
            .unwrap();
        assert_eq!(resp.result, Err(ServeError::Unauthorized));

        // the right token unlocks the same connection
        let resp = client
            .roundtrip(&Request::new(4, RequestBody::Stats).with_token("s3cret"))
            .unwrap();
        assert!(matches!(resp.result, Ok(Reply::Stats(_))));
        let resp = client
            .roundtrip(&Request::new(5, RequestBody::Shutdown).with_token("s3cret"))
            .unwrap();
        assert_eq!(resp.result, Ok(Reply::Done));
        h.join().unwrap();
    }
}
