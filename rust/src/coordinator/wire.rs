//! Wire codec for the serving protocol: a hand-rolled, zero-dependency
//! JSON reader/writer plus the encode/decode rules for every
//! [`protocol`](super::protocol) type. One request or frame is one
//! newline-delimited JSON object (see README §Wire protocol).
//!
//! Protocol v2 frame grammar (server → client): every frame carries the
//! request's `id` plus a `frame` tag —
//! `{"v":2,"id":N,"frame":"progress","done":D,"total":T}`,
//! `{"v":2,"id":N,"frame":"row","row":{...}}`, and the terminal
//! `{"v":2,"id":N,"frame":"final","ok":{...}}` (or `"err":{...}`). A
//! reply stream is `progress`/`row` frames then exactly one `final`;
//! frames from concurrent requests may interleave and are demultiplexed
//! by `id`.
//!
//! The codec is total: `decode(encode(x)) == x` for every protocol value
//! (the round-trip tests below cover each variant), and decoding never
//! panics on malformed input — it returns a [`WireError`] the frontend
//! turns into a [`ServeError::BadRequest`].
//!
//! Numbers: JSON integers decode losslessly into `u64`/`i64` (cycle
//! counts exceed 2^53, so going through `f64` would corrupt them);
//! floats use Rust's shortest round-trip formatting.
//!
//! The HTTP frontend ([`http`](super::http)) reuses this codec: request
//! *bodies* share the envelope's fields (minus `v` and `op`, which ride
//! the URL — see [`encode_request_body`] / [`decode_request_body`]), and
//! streamed frames render as Server-Sent Events via [`encode_sse_event`]
//! with byte-identical `data:` JSON. `PROTOCOL.md` at the repository
//! root is the normative spec for both renderings.
//!
//! ```
//! use fuseconv::coordinator::wire::{decode_request, encode_request};
//! use fuseconv::coordinator::{Request, RequestBody};
//! let req = Request::new(1, RequestBody::Stats).with_deadline_ms(250);
//! assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
//! ```

use super::protocol::{
    ConfigPatch, Frame, InferReply, LayerSpec, ModelSpec, Reply, Request, RequestBody,
    Response, SearchPoint, SearchReply, SearchSpec, ServeError, SimSummary, StatsReply,
    SweepRow, ZooEntry, PROTOCOL_VERSION,
};
use crate::nn::OpKind;
use crate::sim::{Dataflow, FuseVariant, MappingPolicy, SimConfig};
use std::fmt::Write as _;

/// Codec failure: carries a human-readable reason (surface it to the
/// client as a `bad_request`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire: {}", self.0)
    }
}

impl std::error::Error for WireError {}

fn err<T>(msg: impl Into<String>) -> Result<T, WireError> {
    Err(WireError(msg.into()))
}

// ---------------------------------------------------------------------------
// JSON values
// ---------------------------------------------------------------------------

/// A parsed JSON value. Integers are kept exact (`UInt`/`Int`) and only
/// fractional/exponent literals become `Num`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    UInt(u64),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(n) => Some(*n),
            Json::Int(n) if *n >= 0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::UInt(n) => Some(*n as f64),
            Json::Int(n) => Some(*n as f64),
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize (compact, single line — safe for newline framing).
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::UInt(n) => {
                let _ = write!(out, "{n}");
            }
            Json::Int(n) => {
                let _ = write!(out, "{n}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    // NaN/inf are not JSON; the protocol never produces
                    // them, but never emit an unparsable frame.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON document; trailing garbage (other than whitespace) is
/// an error, so a frame is exactly one value.
pub fn parse_json(text: &str) -> Result<Json, WireError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, expected: u8) -> Result<(), WireError> {
        if self.peek() == Some(expected) {
            self.pos += 1;
            Ok(())
        } else {
            err(format!(
                "expected {:?} at byte {}",
                expected as char, self.pos
            ))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), WireError> {
        let end = self.pos + lit.len();
        if self.bytes.len() >= end && &self.bytes[self.pos..end] == lit.as_bytes() {
            self.pos = end;
            Ok(())
        } else {
            err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, WireError> {
        if depth > MAX_DEPTH {
            return err("nesting too deep");
        }
        match self.peek() {
            None => err("unexpected end of input"),
            Some(b'n') => {
                self.eat_lit("null")?;
                Ok(Json::Null)
            }
            Some(b't') => {
                self.eat_lit("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.eat_lit("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut pairs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    let val = self.value(depth + 1)?;
                    pairs.push((key, val));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(pairs));
                        }
                        _ => return err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, WireError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{0008}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{000c}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: require the low half
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return err("bad low surrogate");
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(c) => out.push(c),
                                None => return err("bad \\u escape"),
                            }
                        }
                        _ => return err("bad escape"),
                    }
                }
                Some(b) if b < 0x20 => return err("raw control char in string"),
                Some(_) => {
                    // copy one UTF-8 scalar (input is &str, so boundaries
                    // are valid; find the next char start)
                    let start = self.pos;
                    self.pos += 1;
                    while self
                        .bytes
                        .get(self.pos)
                        .is_some_and(|&b| (b & 0xC0) == 0x80)
                    {
                        self.pos += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| WireError("invalid utf-8".into()))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, WireError> {
        let end = self.pos + 4;
        if self.bytes.len() < end {
            return err("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| WireError("bad \\u escape".into()))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| WireError("bad \\u escape".into()))?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, WireError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut fractional = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    fractional = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| WireError("bad number".into()))?;
        if text.is_empty() || text == "-" {
            return err(format!("expected a value at byte {start}"));
        }
        if !fractional {
            if let Ok(n) = text.parse::<u64>() {
                return Ok(Json::UInt(n));
            }
            if let Ok(n) = text.parse::<i64>() {
                return Ok(Json::Int(n));
            }
        }
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => err(format!("bad number {text:?}")),
        }
    }
}

// ---------------------------------------------------------------------------
// Builder / accessor helpers
// ---------------------------------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn need<'a>(v: &'a Json, key: &str) -> Result<&'a Json, WireError> {
    v.get(key).ok_or_else(|| WireError(format!("missing field {key:?}")))
}

fn need_u64(v: &Json, key: &str) -> Result<u64, WireError> {
    need(v, key)?
        .as_u64()
        .ok_or_else(|| WireError(format!("field {key:?} must be a non-negative integer")))
}

fn need_usize(v: &Json, key: &str) -> Result<usize, WireError> {
    Ok(need_u64(v, key)? as usize)
}

fn need_f64(v: &Json, key: &str) -> Result<f64, WireError> {
    need(v, key)?
        .as_f64()
        .ok_or_else(|| WireError(format!("field {key:?} must be a number")))
}

fn need_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, WireError> {
    need(v, key)?
        .as_str()
        .ok_or_else(|| WireError(format!("field {key:?} must be a string")))
}

fn need_bool(v: &Json, key: &str) -> Result<bool, WireError> {
    need(v, key)?
        .as_bool()
        .ok_or_else(|| WireError(format!("field {key:?} must be a boolean")))
}

fn need_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json], WireError> {
    need(v, key)?
        .as_arr()
        .ok_or_else(|| WireError(format!("field {key:?} must be an array")))
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_u64()
            .map(Some)
            .ok_or_else(|| WireError(format!("field {key:?} must be a non-negative integer"))),
    }
}

fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>, WireError> {
    Ok(opt_u64(v, key)?.map(|n| n as usize))
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_f64()
            .map(Some)
            .ok_or_else(|| WireError(format!("field {key:?} must be a number"))),
    }
}

fn opt_bool(v: &Json, key: &str) -> Result<Option<bool>, WireError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_bool()
            .map(Some)
            .ok_or_else(|| WireError(format!("field {key:?} must be a boolean"))),
    }
}

// ---------------------------------------------------------------------------
// Domain enums: string forms shared with the CLI
// ---------------------------------------------------------------------------

fn variant_to_json(v: FuseVariant) -> Json {
    Json::Str(v.label().to_string())
}

fn variant_from_json(v: &Json) -> Result<FuseVariant, WireError> {
    let s = v.as_str().ok_or_else(|| WireError("variant must be a string".into()))?;
    FuseVariant::parse(s).ok_or_else(|| WireError(format!("unknown variant {s:?}")))
}

fn dataflow_from_str(s: &str) -> Result<Dataflow, WireError> {
    Dataflow::parse(s).ok_or_else(|| WireError(format!("unknown dataflow {s:?} (want os|ws|is)")))
}

fn mapping_from_str(s: &str) -> Result<MappingPolicy, WireError> {
    MappingPolicy::parse(s).ok_or_else(|| {
        WireError(format!("unknown mapping {s:?} (want spatial-first|channels-first|hybrid)"))
    })
}

// ---------------------------------------------------------------------------
// OpKind / LayerSpec / ModelSpec
// ---------------------------------------------------------------------------

fn op_to_json(op: &OpKind) -> Json {
    let u = |n: usize| Json::UInt(n as u64);
    match *op {
        OpKind::Conv2d { k, stride, cin, cout } => obj(vec![
            ("kind", Json::Str("conv2d".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
        OpKind::Depthwise { k, stride, c } => obj(vec![
            ("kind", Json::Str("depthwise".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("c", u(c)),
        ]),
        OpKind::Pointwise { cin, cout } => obj(vec![
            ("kind", Json::Str("pointwise".into())),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
        OpKind::FuseRow { k, stride, c } => obj(vec![
            ("kind", Json::Str("fuse_row".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("c", u(c)),
        ]),
        OpKind::FuseCol { k, stride, c } => obj(vec![
            ("kind", Json::Str("fuse_col".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("c", u(c)),
        ]),
        OpKind::Fc { cin, cout } => obj(vec![
            ("kind", Json::Str("fc".into())),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
        OpKind::GlobalPool { c } => {
            obj(vec![("kind", Json::Str("global_pool".into())), ("c", u(c))])
        }
        OpKind::SqueezeExcite { c, reduced } => obj(vec![
            ("kind", Json::Str("squeeze_excite".into())),
            ("c", u(c)),
            ("reduced", u(reduced)),
        ]),
        OpKind::Add { c } => obj(vec![("kind", Json::Str("add".into())), ("c", u(c))]),
        OpKind::Dilated { k, stride, dilation, cin, cout } => obj(vec![
            ("kind", Json::Str("dilated".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("dilation", u(dilation)),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
        OpKind::Transposed { k, stride, cin, cout } => obj(vec![
            ("kind", Json::Str("transposed".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
        OpKind::Grouped { k, stride, groups, cin, cout } => obj(vec![
            ("kind", Json::Str("grouped".into())),
            ("k", u(k)),
            ("stride", u(stride)),
            ("groups", u(groups)),
            ("cin", u(cin)),
            ("cout", u(cout)),
        ]),
    }
}

fn op_from_json(v: &Json) -> Result<OpKind, WireError> {
    let kind = need_str(v, "kind")?;
    Ok(match kind {
        "conv2d" => OpKind::Conv2d {
            k: need_usize(v, "k")?,
            stride: need_usize(v, "stride")?,
            cin: need_usize(v, "cin")?,
            cout: need_usize(v, "cout")?,
        },
        "depthwise" => OpKind::Depthwise {
            k: need_usize(v, "k")?,
            stride: need_usize(v, "stride")?,
            c: need_usize(v, "c")?,
        },
        "pointwise" => OpKind::Pointwise {
            cin: need_usize(v, "cin")?,
            cout: need_usize(v, "cout")?,
        },
        "fuse_row" => OpKind::FuseRow {
            k: need_usize(v, "k")?,
            stride: need_usize(v, "stride")?,
            c: need_usize(v, "c")?,
        },
        "fuse_col" => OpKind::FuseCol {
            k: need_usize(v, "k")?,
            stride: need_usize(v, "stride")?,
            c: need_usize(v, "c")?,
        },
        "fc" => OpKind::Fc { cin: need_usize(v, "cin")?, cout: need_usize(v, "cout")? },
        "global_pool" => OpKind::GlobalPool { c: need_usize(v, "c")? },
        "squeeze_excite" => OpKind::SqueezeExcite {
            c: need_usize(v, "c")?,
            reduced: need_usize(v, "reduced")?,
        },
        "add" => OpKind::Add { c: need_usize(v, "c")? },
        // New-op fields are additive: `dilation`/`groups` absent decode to
        // 1 (the dense-conv degenerate), so a client one vocabulary ahead
        // of its server round-trips cleanly through proxies that re-encode.
        "dilated" => {
            let dilation = opt_usize(v, "dilation")?.unwrap_or(1);
            if dilation == 0 {
                return err("dilated: dilation must be >= 1".to_string());
            }
            OpKind::Dilated {
                k: need_usize(v, "k")?,
                stride: need_usize(v, "stride")?,
                dilation,
                cin: need_usize(v, "cin")?,
                cout: need_usize(v, "cout")?,
            }
        }
        "transposed" => OpKind::Transposed {
            k: need_usize(v, "k")?,
            stride: need_usize(v, "stride")?,
            cin: need_usize(v, "cin")?,
            cout: need_usize(v, "cout")?,
        },
        "grouped" => {
            let groups = opt_usize(v, "groups")?.unwrap_or(1);
            let cin = need_usize(v, "cin")?;
            let cout = need_usize(v, "cout")?;
            if groups == 0 || cin % groups != 0 || cout % groups != 0 {
                return err(format!(
                    "grouped: groups={groups} must be >= 1 and divide cin={cin} and cout={cout}"
                ));
            }
            OpKind::Grouped {
                k: need_usize(v, "k")?,
                stride: need_usize(v, "stride")?,
                groups,
                cin,
                cout,
            }
        }
        other => return err(format!("unknown op kind {other:?}")),
    })
}

fn layer_spec_to_json(l: &LayerSpec) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(l.name.clone())),
        ("op", op_to_json(&l.op)),
        ("h", Json::UInt(l.h as u64)),
        ("w", Json::UInt(l.w as u64)),
    ];
    if let Some(b) = l.block {
        pairs.push(("block", Json::UInt(b as u64)));
    }
    obj(pairs)
}

fn layer_spec_from_json(v: &Json) -> Result<LayerSpec, WireError> {
    Ok(LayerSpec {
        name: need_str(v, "name")?.to_string(),
        op: op_from_json(need(v, "op")?)?,
        h: need_usize(v, "h")?,
        w: need_usize(v, "w")?,
        block: opt_usize(v, "block")?,
    })
}

fn model_to_json(m: &ModelSpec) -> Json {
    match m {
        ModelSpec::Zoo(name) => obj(vec![("zoo", Json::Str(name.clone()))]),
        ModelSpec::Inline { name, layers } => obj(vec![
            ("name", Json::Str(name.clone())),
            ("layers", Json::Arr(layers.iter().map(layer_spec_to_json).collect())),
        ]),
    }
}

/// Parse a standalone [`ModelSpec`] JSON document — either
/// `{"zoo":"name"}` or an inline `{"name":..., "layers":[...]}` — the
/// same shape `simulate`/`sweep` requests embed. This is the
/// `fuseconv request --model-file` entry: remote clients can simulate
/// any operator the vocabulary knows (including dilated / transposed /
/// grouped) without waiting for a zoo release.
pub fn model_spec_from_json_str(s: &str) -> Result<ModelSpec, WireError> {
    model_from_json(&parse_json(s)?)
}

fn model_from_json(v: &Json) -> Result<ModelSpec, WireError> {
    if let Some(zoo) = v.get("zoo") {
        let name = zoo
            .as_str()
            .ok_or_else(|| WireError("model.zoo must be a string".into()))?;
        return Ok(ModelSpec::Zoo(name.to_string()));
    }
    if v.get("layers").is_some() {
        let layers = need_arr(v, "layers")?
            .iter()
            .map(layer_spec_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(ModelSpec::Inline { name: need_str(v, "name")?.to_string(), layers });
    }
    err("model must have \"zoo\" or \"layers\"")
}

// ---------------------------------------------------------------------------
// ConfigPatch / SimConfig
// ---------------------------------------------------------------------------

fn patch_to_json(p: &ConfigPatch) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if let Some(n) = p.size {
        pairs.push(("size", Json::UInt(n as u64)));
    }
    if let Some(n) = p.rows {
        pairs.push(("rows", Json::UInt(n as u64)));
    }
    if let Some(n) = p.cols {
        pairs.push(("cols", Json::UInt(n as u64)));
    }
    if let Some(n) = p.freq_mhz {
        pairs.push(("freq_mhz", Json::UInt(n)));
    }
    if let Some(n) = p.ifmap_sram_kb {
        pairs.push(("ifmap_sram_kb", Json::UInt(n as u64)));
    }
    if let Some(n) = p.weight_sram_kb {
        pairs.push(("weight_sram_kb", Json::UInt(n as u64)));
    }
    if let Some(n) = p.ofmap_sram_kb {
        pairs.push(("ofmap_sram_kb", Json::UInt(n as u64)));
    }
    if let Some(x) = p.dram_bw {
        pairs.push(("dram_bw", Json::Num(x)));
    }
    if let Some(b) = p.enforce_dram_bw {
        pairs.push(("enforce_dram_bw", Json::Bool(b)));
    }
    if let Some(n) = p.bytes_per_elem {
        pairs.push(("bytes_per_elem", Json::UInt(n as u64)));
    }
    if let Some(df) = p.dataflow {
        pairs.push(("dataflow", Json::Str(df.short().to_string())));
    }
    if let Some(b) = p.stos {
        pairs.push(("stos", Json::Bool(b)));
    }
    if let Some(m) = p.mapping {
        pairs.push(("mapping", Json::Str(m.label().to_string())));
    }
    obj(pairs)
}

fn patch_from_json(v: &Json) -> Result<ConfigPatch, WireError> {
    if !matches!(v, Json::Obj(_)) {
        return err("config must be an object");
    }
    let dataflow = match v.get("dataflow") {
        None => None,
        Some(Json::Null) => None,
        Some(x) => {
            let s = x
                .as_str()
                .ok_or_else(|| WireError("config.dataflow must be a string".into()))?;
            Some(dataflow_from_str(s)?)
        }
    };
    let mapping = match v.get("mapping") {
        None => None,
        Some(Json::Null) => None,
        Some(x) => {
            let s = x
                .as_str()
                .ok_or_else(|| WireError("config.mapping must be a string".into()))?;
            Some(mapping_from_str(s)?)
        }
    };
    Ok(ConfigPatch {
        size: opt_usize(v, "size")?,
        rows: opt_usize(v, "rows")?,
        cols: opt_usize(v, "cols")?,
        freq_mhz: opt_u64(v, "freq_mhz")?,
        ifmap_sram_kb: opt_usize(v, "ifmap_sram_kb")?,
        weight_sram_kb: opt_usize(v, "weight_sram_kb")?,
        ofmap_sram_kb: opt_usize(v, "ofmap_sram_kb")?,
        dram_bw: opt_f64(v, "dram_bw")?,
        enforce_dram_bw: opt_bool(v, "enforce_dram_bw")?,
        bytes_per_elem: opt_usize(v, "bytes_per_elem")?,
        dataflow,
        stos: opt_bool(v, "stos")?,
        mapping,
    })
}

/// Full [`SimConfig`] as JSON (every field explicit).
pub fn sim_config_to_json(c: &SimConfig) -> Json {
    obj(vec![
        ("rows", Json::UInt(c.rows as u64)),
        ("cols", Json::UInt(c.cols as u64)),
        ("freq_mhz", Json::UInt(c.freq_mhz)),
        ("ifmap_sram_kb", Json::UInt(c.ifmap_sram_kb as u64)),
        ("weight_sram_kb", Json::UInt(c.weight_sram_kb as u64)),
        ("ofmap_sram_kb", Json::UInt(c.ofmap_sram_kb as u64)),
        ("dram_bw", Json::Num(c.dram_bw)),
        ("enforce_dram_bw", Json::Bool(c.enforce_dram_bw)),
        ("bytes_per_elem", Json::UInt(c.bytes_per_elem as u64)),
        ("dataflow", Json::Str(c.dataflow.short().to_string())),
        ("stos", Json::Bool(c.stos)),
        ("mapping", Json::Str(c.mapping.label().to_string())),
    ])
}

/// Decode a full or partial `SimConfig`: absent fields keep Table-1
/// defaults (so this accepts both [`sim_config_to_json`] output and a
/// sparse override object).
pub fn sim_config_from_json(v: &Json) -> Result<SimConfig, WireError> {
    let patch = patch_from_json(v)?;
    patch.to_config().map_err(|e| WireError(e.to_string()))
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f32s_from_json(v: &Json, key: &str) -> Result<Vec<f32>, WireError> {
    need_arr(v, key)?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| WireError(format!("{key:?} must hold numbers")))
        })
        .collect()
}

/// The operation-specific fields of a request body — shared by the TCP
/// envelope encoder and the HTTP body encoder.
fn body_fields(body: &RequestBody) -> Vec<(&'static str, Json)> {
    let mut pairs: Vec<(&'static str, Json)> = Vec::new();
    match body {
        RequestBody::Infer { input } => pairs.push(("input", f32s_to_json(input))),
        RequestBody::Simulate { model, variant, config } => {
            pairs.push(("model", model_to_json(model)));
            pairs.push(("variant", variant_to_json(*variant)));
            pairs.push(("config", patch_to_json(config)));
        }
        RequestBody::Sweep { models, variants, configs } => {
            pairs.push((
                "models",
                Json::Arr(models.iter().map(|m| Json::Str(m.clone())).collect()),
            ));
            pairs.push((
                "variants",
                Json::Arr(variants.iter().map(|&v| variant_to_json(v)).collect()),
            ));
            pairs.push(("configs", Json::Arr(configs.iter().map(patch_to_json).collect())));
        }
        RequestBody::Search { spec } => {
            pairs.push(("population", Json::UInt(spec.population as u64)));
            pairs.push(("iterations", Json::UInt(spec.iterations as u64)));
            pairs.push(("mutation_p", Json::Num(spec.mutation_p)));
            pairs.push(("allow_fuse", Json::Bool(spec.allow_fuse)));
            pairs.push(("seed", Json::UInt(spec.seed)));
            pairs.push(("config", patch_to_json(&spec.config)));
        }
        RequestBody::Cancel { target } => pairs.push(("target", Json::UInt(*target))),
        RequestBody::AddBackend { addr } | RequestBody::DrainBackend { addr } => {
            pairs.push(("backend", Json::Str(addr.clone())))
        }
        RequestBody::Stats | RequestBody::Zoo | RequestBody::Shutdown => {}
    }
    pairs
}

/// Encode one request as a single-line JSON frame (no trailing newline).
pub fn encode_request(req: &Request) -> String {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("v", Json::UInt(PROTOCOL_VERSION as u64)),
        ("id", Json::UInt(req.id)),
    ];
    if let Some(ms) = req.deadline_ms {
        pairs.push(("deadline_ms", Json::UInt(ms)));
    }
    if let Some(tok) = &req.token {
        pairs.push(("token", Json::Str(tok.clone())));
    }
    pairs.push(("op", Json::Str(req.body.op().to_string())));
    pairs.extend(body_fields(&req.body));
    let mut out = String::new();
    obj(pairs).write(&mut out);
    out
}

/// Encode a request as an HTTP body: the same fields as the TCP frame
/// minus `v` and `op` — the URL carries both (`POST /v1/<op>`, where
/// `v1` versions the HTTP mapping). `id` and `deadline_ms` stay in the
/// body so HTTP clients keep the envelope's correlation semantics. The
/// `token` envelope field is also omitted: HTTP auth rides the
/// `Authorization: Bearer` header, never the body (PROTOCOL.md §12).
pub fn encode_request_body(req: &Request) -> String {
    let mut pairs: Vec<(&str, Json)> = vec![("id", Json::UInt(req.id))];
    if let Some(ms) = req.deadline_ms {
        pairs.push(("deadline_ms", Json::UInt(ms)));
    }
    pairs.extend(body_fields(&req.body));
    let mut out = String::new();
    obj(pairs).write(&mut out);
    out
}

fn check_version(v: &Json) -> Result<(), WireError> {
    let ver = need_u64(v, "v")?;
    if ver != PROTOCOL_VERSION as u64 {
        return err(format!(
            "protocol version {ver} not supported (this server speaks v{PROTOCOL_VERSION})"
        ));
    }
    Ok(())
}

/// Decode one request frame.
pub fn decode_request(text: &str) -> Result<Request, WireError> {
    let v = parse_json(text)?;
    check_version(&v)?;
    let id = need_u64(&v, "id")?;
    let deadline_ms = opt_u64(&v, "deadline_ms")?;
    let token = match v.get("token") {
        None | Some(Json::Null) => None,
        Some(t) => Some(
            t.as_str()
                .ok_or_else(|| WireError("field \"token\" must be a string".into()))?
                .to_string(),
        ),
    };
    let body = decode_request_body(need_str(&v, "op")?, &v)?;
    Ok(Request { id, deadline_ms, token, body })
}

/// Decode a request *body* given its operation tag. The TCP framing
/// reads `op` out of the envelope; the HTTP frontend takes it from the
/// URL (`/v1/<op>`) and hands the parsed body object in as `v`. Both
/// share every field rule below.
pub fn decode_request_body(op: &str, v: &Json) -> Result<RequestBody, WireError> {
    let body = match op {
        "infer" => RequestBody::Infer { input: f32s_from_json(v, "input")? },
        "simulate" => RequestBody::Simulate {
            model: model_from_json(need(v, "model")?)?,
            variant: match v.get("variant") {
                None => FuseVariant::Base,
                Some(j) => variant_from_json(j)?,
            },
            config: match v.get("config") {
                None => ConfigPatch::default(),
                Some(j) => patch_from_json(j)?,
            },
        },
        "sweep" => {
            let models = need_arr(v, "models")?
                .iter()
                .map(|m| {
                    m.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| WireError("models must hold strings".into()))
                })
                .collect::<Result<Vec<_>, _>>()?;
            let variants = match v.get("variants") {
                None => vec![FuseVariant::Base],
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(variant_from_json)
                    .collect::<Result<Vec<_>, _>>()?,
                Some(_) => return err("variants must be an array"),
            };
            let configs = match v.get("configs") {
                None => vec![ConfigPatch::default()],
                Some(Json::Arr(items)) => {
                    items.iter().map(patch_from_json).collect::<Result<Vec<_>, _>>()?
                }
                Some(_) => return err("configs must be an array"),
            };
            RequestBody::Sweep { models, variants, configs }
        }
        "search" => {
            // absent fields keep SearchSpec defaults, so a minimal
            // `{"op":"search"}` runs the default NAS job
            let d = SearchSpec::default();
            RequestBody::Search {
                spec: SearchSpec {
                    population: opt_usize(v, "population")?.unwrap_or(d.population),
                    iterations: opt_usize(v, "iterations")?.unwrap_or(d.iterations),
                    mutation_p: opt_f64(v, "mutation_p")?.unwrap_or(d.mutation_p),
                    allow_fuse: opt_bool(v, "allow_fuse")?.unwrap_or(d.allow_fuse),
                    seed: opt_u64(v, "seed")?.unwrap_or(d.seed),
                    config: match v.get("config") {
                        None => ConfigPatch::default(),
                        Some(j) => patch_from_json(j)?,
                    },
                },
            }
        }
        "cancel" => RequestBody::Cancel { target: need_u64(v, "target")? },
        "add-backend" => RequestBody::AddBackend { addr: need_str(v, "backend")?.to_string() },
        "drain-backend" => {
            RequestBody::DrainBackend { addr: need_str(v, "backend")?.to_string() }
        }
        "stats" => RequestBody::Stats,
        "zoo" => RequestBody::Zoo,
        "shutdown" => RequestBody::Shutdown,
        other => return err(format!("unknown op {other:?}")),
    };
    Ok(body)
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

fn sweep_row_to_json(r: &SweepRow) -> Json {
    obj(vec![
        ("network", Json::Str(r.network.clone())),
        ("variant", variant_to_json(r.variant)),
        ("rows", Json::UInt(r.rows as u64)),
        ("cols", Json::UInt(r.cols as u64)),
        ("dataflow", Json::Str(r.dataflow.short().to_string())),
        ("stos", Json::Bool(r.stos)),
        ("total_cycles", Json::UInt(r.total_cycles)),
        ("latency_ms", Json::Num(r.latency_ms)),
    ])
}

fn sweep_row_from_json(v: &Json) -> Result<SweepRow, WireError> {
    Ok(SweepRow {
        network: need_str(v, "network")?.to_string(),
        variant: variant_from_json(need(v, "variant")?)?,
        rows: need_usize(v, "rows")?,
        cols: need_usize(v, "cols")?,
        dataflow: dataflow_from_str(need_str(v, "dataflow")?)?,
        stos: need_bool(v, "stos")?,
        total_cycles: need_u64(v, "total_cycles")?,
        latency_ms: need_f64(v, "latency_ms")?,
    })
}

fn search_point_to_json(p: &SearchPoint) -> Json {
    obj(vec![
        ("genome", Json::Str(p.genome.clone())),
        ("acc", Json::Num(p.acc)),
        ("latency_ms", Json::Num(p.latency_ms)),
        ("macs_m", Json::Num(p.macs_m)),
        ("params_m", Json::Num(p.params_m)),
        ("rank", Json::UInt(p.rank)),
    ])
}

fn search_point_from_json(v: &Json) -> Result<SearchPoint, WireError> {
    Ok(SearchPoint {
        genome: need_str(v, "genome")?.to_string(),
        acc: need_f64(v, "acc")?,
        latency_ms: need_f64(v, "latency_ms")?,
        macs_m: need_f64(v, "macs_m")?,
        params_m: need_f64(v, "params_m")?,
        rank: need_u64(v, "rank")?,
    })
}

fn reply_to_json(reply: &Reply) -> Json {
    match reply {
        Reply::Infer(r) => obj(vec![
            ("kind", Json::Str("infer".into())),
            ("output", f32s_to_json(&r.output)),
            ("queue_us", Json::UInt(r.queue_us)),
            ("batch_size", Json::UInt(r.batch_size as u64)),
            ("latency_us", Json::UInt(r.latency_us)),
        ]),
        Reply::Sim(s) => obj(vec![
            ("kind", Json::Str("sim".into())),
            ("network", Json::Str(s.network.clone())),
            ("config_label", Json::Str(s.config_label.clone())),
            ("total_cycles", Json::UInt(s.total_cycles)),
            ("latency_ms", Json::Num(s.latency_ms)),
            ("utilization", Json::Num(s.utilization)),
            ("num_layers", Json::UInt(s.num_layers as u64)),
        ]),
        Reply::Sweep(rows) => obj(vec![
            ("kind", Json::Str("sweep".into())),
            ("rows", Json::Arr(rows.iter().map(sweep_row_to_json).collect())),
        ]),
        Reply::Stats(s) => obj(vec![
            ("kind", Json::Str("stats".into())),
            ("protocol_version", Json::UInt(s.protocol_version as u64)),
            ("infer_served", Json::UInt(s.infer_served)),
            ("infer_batches", Json::UInt(s.infer_batches)),
            ("sim_submitted", Json::UInt(s.sim_submitted)),
            ("sim_completed", Json::UInt(s.sim_completed)),
            ("cache_hits", Json::UInt(s.cache_hits)),
            ("cache_misses", Json::UInt(s.cache_misses)),
            ("cache_entries", Json::UInt(s.cache_entries)),
            ("backends", Json::UInt(s.backends)),
            ("open_conns", Json::UInt(s.open_conns)),
            ("active_streams", Json::UInt(s.active_streams)),
            ("transport_threads", Json::UInt(s.transport_threads)),
            ("result_hits", Json::UInt(s.result_hits)),
            ("result_misses", Json::UInt(s.result_misses)),
            ("result_coalesced", Json::UInt(s.result_coalesced)),
            ("result_evicted", Json::UInt(s.result_evicted)),
            ("result_entries", Json::UInt(s.result_entries)),
            ("result_bytes", Json::UInt(s.result_bytes)),
            ("search_started", Json::UInt(s.search_started)),
            ("search_completed", Json::UInt(s.search_completed)),
            ("search_cancelled", Json::UInt(s.search_cancelled)),
            (
                "backend_state",
                Json::Arr(s.backend_state.iter().map(|e| Json::Str(e.clone())).collect()),
            ),
            ("failover_resteered", Json::UInt(s.failover_resteered)),
            ("probe_failures", Json::UInt(s.probe_failures)),
        ]),
        Reply::Search(s) => obj(vec![
            ("kind", Json::Str("search".into())),
            (
                "frontier",
                Json::Arr(s.frontier.iter().map(search_point_to_json).collect()),
            ),
            ("evaluated", Json::UInt(s.evaluated)),
            ("generations", Json::UInt(s.generations)),
            ("cancelled", Json::Bool(s.cancelled)),
        ]),
        Reply::Zoo(entries) => obj(vec![
            ("kind", Json::Str("zoo".into())),
            (
                "models",
                Json::Arr(
                    entries
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("name", Json::Str(e.name.clone())),
                                ("macs_m", Json::Num(e.macs_m)),
                                ("params_m", Json::Num(e.params_m)),
                                ("blocks", Json::UInt(e.blocks as u64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        Reply::Done => obj(vec![("kind", Json::Str("done".into()))]),
    }
}

fn reply_from_json(v: &Json) -> Result<Reply, WireError> {
    let kind = need_str(v, "kind")?;
    Ok(match kind {
        "infer" => Reply::Infer(InferReply {
            output: f32s_from_json(v, "output")?,
            queue_us: need_u64(v, "queue_us")?,
            batch_size: need_usize(v, "batch_size")?,
            latency_us: need_u64(v, "latency_us")?,
        }),
        "sim" => Reply::Sim(SimSummary {
            network: need_str(v, "network")?.to_string(),
            config_label: need_str(v, "config_label")?.to_string(),
            total_cycles: need_u64(v, "total_cycles")?,
            latency_ms: need_f64(v, "latency_ms")?,
            utilization: need_f64(v, "utilization")?,
            num_layers: need_usize(v, "num_layers")?,
        }),
        "sweep" => Reply::Sweep(
            need_arr(v, "rows")?
                .iter()
                .map(sweep_row_from_json)
                .collect::<Result<Vec<_>, _>>()?,
        ),
        "stats" => Reply::Stats(StatsReply {
            protocol_version: need_u64(v, "protocol_version")? as u32,
            infer_served: need_u64(v, "infer_served")?,
            infer_batches: need_u64(v, "infer_batches")?,
            sim_submitted: need_u64(v, "sim_submitted")?,
            sim_completed: need_u64(v, "sim_completed")?,
            cache_hits: need_u64(v, "cache_hits")?,
            cache_misses: need_u64(v, "cache_misses")?,
            cache_entries: need_u64(v, "cache_entries")?,
            // additive v2 field (shard front tiers); absent = direct node
            backends: opt_u64(v, "backends")?.unwrap_or(0),
            // additive v2 transport gauges (PR 6); absent = old node
            open_conns: opt_u64(v, "open_conns")?.unwrap_or(0),
            active_streams: opt_u64(v, "active_streams")?.unwrap_or(0),
            transport_threads: opt_u64(v, "transport_threads")?.unwrap_or(0),
            // additive v2 result-cache counters (PR 7); absent = old
            // node or no cache attached
            result_hits: opt_u64(v, "result_hits")?.unwrap_or(0),
            result_misses: opt_u64(v, "result_misses")?.unwrap_or(0),
            result_coalesced: opt_u64(v, "result_coalesced")?.unwrap_or(0),
            result_evicted: opt_u64(v, "result_evicted")?.unwrap_or(0),
            result_entries: opt_u64(v, "result_entries")?.unwrap_or(0),
            result_bytes: opt_u64(v, "result_bytes")?.unwrap_or(0),
            // additive v2 search counters (PR 8); absent = old node
            search_started: opt_u64(v, "search_started")?.unwrap_or(0),
            search_completed: opt_u64(v, "search_completed")?.unwrap_or(0),
            search_cancelled: opt_u64(v, "search_cancelled")?.unwrap_or(0),
            // additive v2 fleet-health fields (shard front tiers);
            // absent = old node or direct single node
            backend_state: match v.get("backend_state") {
                None | Some(Json::Null) => Vec::new(),
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(|e| {
                        e.as_str().map(str::to_string).ok_or_else(|| {
                            WireError("backend_state must hold strings".into())
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?,
                Some(_) => return err("backend_state must be an array"),
            },
            failover_resteered: opt_u64(v, "failover_resteered")?.unwrap_or(0),
            probe_failures: opt_u64(v, "probe_failures")?.unwrap_or(0),
        }),
        "search" => Reply::Search(SearchReply {
            frontier: need_arr(v, "frontier")?
                .iter()
                .map(search_point_from_json)
                .collect::<Result<Vec<_>, _>>()?,
            evaluated: need_u64(v, "evaluated")?,
            generations: need_u64(v, "generations")?,
            cancelled: need_bool(v, "cancelled")?,
        }),
        "zoo" => Reply::Zoo(
            need_arr(v, "models")?
                .iter()
                .map(|e| {
                    Ok(ZooEntry {
                        name: need_str(e, "name")?.to_string(),
                        macs_m: need_f64(e, "macs_m")?,
                        params_m: need_f64(e, "params_m")?,
                        blocks: need_usize(e, "blocks")?,
                    })
                })
                .collect::<Result<Vec<_>, WireError>>()?,
        ),
        "done" => Reply::Done,
        other => return err(format!("unknown reply kind {other:?}")),
    })
}

fn serve_error_to_json(e: &ServeError) -> Json {
    let mut pairs = vec![("code", Json::Str(e.code().to_string()))];
    if let ServeError::BadRequest(detail) = e {
        pairs.push(("detail", Json::Str(detail.clone())));
    }
    obj(pairs)
}

fn serve_error_from_json(v: &Json) -> Result<ServeError, WireError> {
    Ok(match need_str(v, "code")? {
        "busy" => ServeError::Busy,
        "bad_request" => ServeError::BadRequest(
            v.get("detail").and_then(Json::as_str).unwrap_or("").to_string(),
        ),
        "deadline" => ServeError::Deadline,
        "unauthorized" => ServeError::Unauthorized,
        "shutdown" => ServeError::Shutdown,
        other => return err(format!("unknown error code {other:?}")),
    })
}

/// Encode one frame of a reply stream as a single-line JSON object (no
/// trailing newline). `id` is the request id the frame belongs to.
pub fn encode_frame(id: u64, frame: &Frame) -> String {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("v", Json::UInt(PROTOCOL_VERSION as u64)),
        ("id", Json::UInt(id)),
        ("frame", Json::Str(frame.tag().into())),
    ];
    match frame {
        Frame::Progress { done, total } => {
            pairs.push(("done", Json::UInt(*done)));
            pairs.push(("total", Json::UInt(*total)));
        }
        Frame::Row(row) => {
            pairs.push(("row", sweep_row_to_json(row)));
        }
        Frame::SearchRow(point) => {
            pairs.push(("point", search_point_to_json(point)));
        }
        Frame::Final(result) => match result {
            Ok(reply) => pairs.push(("ok", reply_to_json(reply))),
            Err(e) => pairs.push(("err", serve_error_to_json(e))),
        },
    }
    let mut out = String::new();
    obj(pairs).write(&mut out);
    out
}

/// Render one frame as a Server-Sent Events block — the HTTP streaming
/// rendering of the same grammar the TCP framing sends: `event:` is the
/// frame's [`tag`](Frame::tag), `id:` the request id, and `data:` the
/// *byte-identical* JSON of [`encode_frame`], so SSE consumers reuse
/// [`decode_frame`] unchanged. Ends with the blank line that terminates
/// an SSE event.
pub fn encode_sse_event(id: u64, frame: &Frame) -> String {
    format!("event: {}\nid: {id}\ndata: {}\n\n", frame.tag(), encode_frame(id, frame))
}

/// Decode one frame: `(request id, frame)`.
pub fn decode_frame(text: &str) -> Result<(u64, Frame), WireError> {
    let v = parse_json(text)?;
    check_version(&v)?;
    let id = need_u64(&v, "id")?;
    let frame = match need_str(&v, "frame")? {
        "progress" => Frame::Progress {
            done: need_u64(&v, "done")?,
            total: need_u64(&v, "total")?,
        },
        "row" => Frame::Row(sweep_row_from_json(need(&v, "row")?)?),
        "search_row" => Frame::SearchRow(search_point_from_json(need(&v, "point")?)?),
        "final" => {
            if let Some(ok) = v.get("ok") {
                Frame::Final(Ok(reply_from_json(ok)?))
            } else if let Some(e) = v.get("err") {
                Frame::Final(Err(serve_error_from_json(e)?))
            } else {
                return err("final frame must have \"ok\" or \"err\"");
            }
        }
        other => return err(format!("unknown frame tag {other:?}")),
    };
    Ok((id, frame))
}

/// Encode a one-shot response — exactly its terminal `final` frame.
pub fn encode_response(resp: &Response) -> String {
    encode_frame(resp.id, &Frame::Final(resp.result.clone()))
}

/// Decode a frame that must be terminal (one-shot traffic); a
/// `progress`/`row` frame here is a [`WireError`].
pub fn decode_response(text: &str) -> Result<Response, WireError> {
    match decode_frame(text)? {
        (id, Frame::Final(result)) => Ok(Response { id, result }),
        (_, other) => err(format!("expected a final frame, got a {} frame", other.tag())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt_request(req: Request) {
        let line = encode_request(&req);
        assert!(!line.contains('\n'), "frames must be single-line: {line}");
        let back = decode_request(&line).unwrap();
        assert_eq!(back, req, "round-trip mismatch for {line}");
    }

    fn rt_response(resp: Response) {
        let line = encode_response(&resp);
        assert!(!line.contains('\n'), "frames must be single-line: {line}");
        let back = decode_response(&line).unwrap();
        assert_eq!(back, resp, "round-trip mismatch for {line}");
    }

    #[test]
    fn json_scalars_round_trip() {
        for text in ["null", "true", "false", "0", "-7", "18446744073709551615", "1.5"] {
            let v = parse_json(text).unwrap();
            let mut out = String::new();
            v.write(&mut out);
            assert_eq!(out, text);
        }
        // big u64 survives exactly (would corrupt through f64)
        assert_eq!(parse_json("9007199254740993").unwrap().as_u64(), Some(9007199254740993));
        // exponents parse as floats
        assert_eq!(parse_json("2e3").unwrap().as_f64(), Some(2000.0));
    }

    #[test]
    fn json_strings_escape_and_unescape() {
        let v = parse_json(r#""a\"b\\c\nd\u0041\u00e9""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA\u{e9}"));
        let v = parse_json(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        // writer escapes what it must
        let mut out = String::new();
        Json::Str("x\"y\\z\n\t\u{1}".into()).write(&mut out);
        assert_eq!(out, r#""x\"y\\z\n\t\u0001""#);
        assert_eq!(parse_json(&out).unwrap().as_str(), Some("x\"y\\z\n\t\u{1}"));
    }

    #[test]
    fn json_structures_parse() {
        let v = parse_json(r#" { "a" : [1, 2.5, {"b": true}], "c": null } "#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.get("c").unwrap().is_null());
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn json_malformed_inputs_error_not_panic() {
        for text in [
            "", "{", "[1,", "{\"a\":}", "nul", "\"abc", "{\"a\" 1}", "[1] 2", "--4",
            "\"\\u12\"", "\"\\q\"", "{\"a\":1,}",
        ] {
            assert!(parse_json(text).is_err(), "accepted malformed {text:?}");
        }
    }

    #[test]
    fn infer_request_round_trips() {
        rt_request(Request::new(
            1,
            RequestBody::Infer { input: vec![0.0, 1.5, -2.25, 3.0e-3] },
        ));
        rt_request(
            Request::new(2, RequestBody::Infer { input: vec![] }).with_deadline_ms(250),
        );
    }

    #[test]
    fn simulate_request_round_trips_zoo_and_inline() {
        rt_request(Request::new(
            3,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Half,
                config: ConfigPatch::sized(32),
            },
        ));
        // one layer of every op kind, so every arm of the codec runs
        let ops = vec![
            OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 },
            OpKind::Depthwise { k: 3, stride: 1, c: 32 },
            OpKind::Pointwise { cin: 32, cout: 64 },
            OpKind::FuseRow { k: 3, stride: 1, c: 16 },
            OpKind::FuseCol { k: 3, stride: 1, c: 16 },
            OpKind::Fc { cin: 1280, cout: 1000 },
            OpKind::GlobalPool { c: 1280 },
            OpKind::SqueezeExcite { c: 64, reduced: 16 },
            OpKind::Add { c: 64 },
            OpKind::Dilated { k: 3, stride: 1, dilation: 4, cin: 32, cout: 64 },
            OpKind::Transposed { k: 4, stride: 2, cin: 64, cout: 32 },
            OpKind::Grouped { k: 3, stride: 2, groups: 4, cin: 32, cout: 64 },
        ];
        let layers: Vec<LayerSpec> = ops
            .into_iter()
            .enumerate()
            .map(|(i, op)| LayerSpec {
                name: format!("l{i}"),
                op,
                h: 16 + i,
                w: 16 + i,
                block: if i % 2 == 0 { Some(i / 2) } else { None },
            })
            .collect();
        rt_request(
            Request::new(
                4,
                RequestBody::Simulate {
                    model: ModelSpec::Inline { name: "custom \"net\"".into(), layers },
                    variant: FuseVariant::Full,
                    config: ConfigPatch {
                        rows: Some(8),
                        cols: Some(64),
                        freq_mhz: Some(800),
                        ifmap_sram_kb: Some(32),
                        weight_sram_kb: Some(32),
                        ofmap_sram_kb: Some(128),
                        dram_bw: Some(12.5),
                        enforce_dram_bw: Some(true),
                        bytes_per_elem: Some(2),
                        dataflow: Some(Dataflow::WeightStationary),
                        stos: Some(false),
                        mapping: Some(MappingPolicy::ChannelsFirst),
                        ..ConfigPatch::default()
                    },
                },
            )
            .with_deadline_ms(60_000),
        );
    }

    #[test]
    fn new_op_fields_are_additive_with_dense_defaults() {
        // `dilation` / `groups` absent ⇒ 1: a v2-era client that re-encodes
        // specs it doesn't fully know keeps working.
        let op = op_from_json(
            &parse_json(r#"{"kind":"dilated","k":3,"stride":1,"cin":8,"cout":16}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(op, OpKind::Dilated { k: 3, stride: 1, dilation: 1, cin: 8, cout: 16 });
        let op = op_from_json(
            &parse_json(r#"{"kind":"grouped","k":3,"stride":1,"cin":8,"cout":16}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(op, OpKind::Grouped { k: 3, stride: 1, groups: 1, cin: 8, cout: 16 });
    }

    #[test]
    fn new_op_invalid_fields_are_typed_errors_not_panics() {
        for bad in [
            r#"{"kind":"dilated","k":3,"stride":1,"dilation":0,"cin":8,"cout":16}"#,
            r#"{"kind":"grouped","k":3,"stride":1,"groups":0,"cin":8,"cout":16}"#,
            r#"{"kind":"grouped","k":3,"stride":1,"groups":3,"cin":8,"cout":16}"#,
            r#"{"kind":"grouped","k":3,"stride":1,"groups":4,"cin":8,"cout":18}"#,
            r#"{"kind":"transposed","k":4,"stride":2,"cin":8}"#,
        ] {
            assert!(op_from_json(&parse_json(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn dataflow_vocabulary_covers_is_and_rejects_unknowns() {
        for df in crate::sim::config::ALL_DATAFLOWS {
            assert_eq!(dataflow_from_str(df.short()).unwrap(), df);
        }
        let e = dataflow_from_str("systolic").unwrap_err();
        assert!(e.0.contains("os|ws|is"), "error should teach the vocabulary: {}", e.0);
    }

    #[test]
    fn model_spec_json_str_parses_both_shapes() {
        assert_eq!(
            model_spec_from_json_str(r#"{"zoo":"espnet-c"}"#).unwrap(),
            ModelSpec::Zoo("espnet-c".into())
        );
        let m = model_spec_from_json_str(
            r#"{"name":"edge-decoder","layers":[
                {"name":"up","op":{"kind":"transposed","k":4,"stride":2,"cin":64,"cout":32},"h":16,"w":16},
                {"name":"g","op":{"kind":"grouped","k":3,"stride":1,"groups":4,"cin":32,"cout":32},"h":32,"w":32,"block":0}
            ]}"#,
        )
        .unwrap();
        match m {
            ModelSpec::Inline { name, layers } => {
                assert_eq!(name, "edge-decoder");
                assert_eq!(layers.len(), 2);
                assert_eq!(
                    layers[0].op,
                    OpKind::Transposed { k: 4, stride: 2, cin: 64, cout: 32 }
                );
                assert_eq!(layers[1].block, Some(0));
            }
            other => panic!("expected inline spec, got {other:?}"),
        }
        assert!(model_spec_from_json_str("{\"layers\":[]}").is_err());
    }

    #[test]
    fn sweep_stats_zoo_shutdown_requests_round_trip() {
        rt_request(Request::new(
            5,
            RequestBody::Sweep {
                models: vec!["mobilenet-v1".into(), "mnasnet-b1".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
                configs: vec![
                    ConfigPatch::sized(8),
                    ConfigPatch::sized(16),
                    // the is-dataflow axis rides the same patch shape
                    ConfigPatch {
                        dataflow: Some(Dataflow::InputStationary),
                        ..ConfigPatch::sized(16)
                    },
                ],
            },
        ));
        rt_request(Request::new(6, RequestBody::Stats));
        rt_request(Request::new(7, RequestBody::Zoo));
        rt_request(Request::new(8, RequestBody::Shutdown));
    }

    #[test]
    fn responses_round_trip_every_reply_kind() {
        rt_response(Response::ok(
            1,
            Reply::Infer(InferReply {
                output: vec![0.25, -1.0, 7.5],
                queue_us: 420,
                batch_size: 8,
                latency_us: 1234,
            }),
        ));
        rt_response(Response::ok(
            2,
            Reply::Sim(SimSummary {
                network: "MobileNet-V2".into(),
                config_label: "16x16 OutputStationary+ST-OS".into(),
                total_cycles: 9_007_199_254_740_993, // > 2^53: must stay exact
                latency_ms: 3.25,
                utilization: 0.875,
                num_layers: 66,
            }),
        ));
        rt_response(Response::ok(
            3,
            Reply::Sweep(vec![SweepRow {
                network: "MnasNet-B1".into(),
                variant: FuseVariant::Half,
                rows: 16,
                cols: 16,
                dataflow: Dataflow::OutputStationary,
                stos: true,
                total_cycles: 123_456_789,
                latency_ms: 0.125,
            }]),
        ));
        rt_response(Response::ok(
            4,
            Reply::Stats(StatsReply {
                protocol_version: PROTOCOL_VERSION,
                infer_served: 10,
                infer_batches: 3,
                sim_submitted: 7,
                sim_completed: 6,
                cache_hits: 100,
                cache_misses: 20,
                cache_entries: 15,
                backends: 2,
                open_conns: 4,
                active_streams: 1,
                transport_threads: 2,
                result_hits: 50,
                result_misses: 9,
                result_coalesced: 8,
                result_evicted: 3,
                result_entries: 6,
                result_bytes: 48_000,
                search_started: 5,
                search_completed: 4,
                search_cancelled: 1,
            }),
        ));
        rt_response(Response::ok(
            7,
            Reply::Search(SearchReply {
                frontier: vec![SearchPoint {
                    genome: "d2:k3e4f.k5e6d/d2:k3e3d.k7e6f/d2:k3e4d.k3e4d".into(),
                    acc: 76.55,
                    latency_ms: 1.75,
                    macs_m: 312.5,
                    params_m: 4.25,
                    rank: 0,
                }],
                evaluated: 40,
                generations: 4,
                cancelled: true,
            }),
        ));
        rt_response(Response::ok(
            5,
            Reply::Zoo(vec![ZooEntry {
                name: "mobilenet-v2".into(),
                macs_m: 300.5,
                params_m: 3.5,
                blocks: 17,
            }]),
        ));
        rt_response(Response::ok(6, Reply::Done));
    }

    #[test]
    fn responses_round_trip_every_error() {
        rt_response(Response::err(1, ServeError::Busy));
        rt_response(Response::err(2, ServeError::BadRequest("unknown model \"x\"".into())));
        rt_response(Response::err(3, ServeError::Deadline));
        rt_response(Response::err(4, ServeError::Shutdown));
        rt_response(Response::err(5, ServeError::Unauthorized));
    }

    #[test]
    fn search_and_cancel_requests_round_trip() {
        rt_request(Request::new(
            9,
            RequestBody::Search {
                spec: SearchSpec {
                    population: 16,
                    iterations: 8,
                    mutation_p: 0.25,
                    allow_fuse: false,
                    seed: 777,
                    config: ConfigPatch::sized(32),
                },
            },
        ));
        rt_request(Request::new(10, RequestBody::Cancel { target: 9 }).with_deadline_ms(100));
        // absent fields keep SearchSpec defaults
        let req = decode_request(r#"{"v":2,"id":1,"op":"search"}"#).unwrap();
        assert_eq!(req.body, RequestBody::Search { spec: SearchSpec::default() });
        // cancel requires a target
        assert!(decode_request(r#"{"v":2,"id":1,"op":"cancel"}"#).is_err());
    }

    #[test]
    fn token_rides_the_tcp_envelope_but_never_the_http_body() {
        let req = Request::new(11, RequestBody::Stats).with_token("s3cret");
        let line = encode_request(&req);
        assert!(line.contains("\"token\":\"s3cret\""), "{line}");
        assert_eq!(decode_request(&line).unwrap(), req);
        let body = encode_request_body(&req);
        assert!(!body.contains("token"), "HTTP bodies must not carry tokens: {body}");
        // tokenless requests omit the field entirely
        let line = encode_request(&Request::new(12, RequestBody::Stats));
        assert!(!line.contains("token"), "{line}");
    }

    fn rt_frame(id: u64, frame: Frame) {
        let line = encode_frame(id, &frame);
        assert!(!line.contains('\n'), "frames must be single-line: {line}");
        let (back_id, back) = decode_frame(&line).unwrap();
        assert_eq!(back_id, id, "id mismatch for {line}");
        assert_eq!(back, frame, "round-trip mismatch for {line}");
    }

    #[test]
    fn stream_frames_round_trip() {
        rt_frame(7, Frame::Progress { done: 0, total: 24 });
        rt_frame(7, Frame::Progress { done: 23, total: 24 });
        rt_frame(
            7,
            Frame::Row(SweepRow {
                network: "MobileNet-V2".into(),
                variant: FuseVariant::Full,
                rows: 64,
                cols: 64,
                dataflow: Dataflow::WeightStationary,
                stos: false,
                total_cycles: 9_007_199_254_740_993, // > 2^53: must stay exact
                latency_ms: 1.25,
            }),
        );
        rt_frame(7, Frame::Final(Ok(Reply::Done)));
        rt_frame(8, Frame::Final(Err(ServeError::Busy)));
        rt_frame(
            9,
            Frame::SearchRow(SearchPoint {
                genome: "d3:k3e3d.k7e6f.k3e4d/d2:k3e4f.k5e6d/d2:k3e4d.k3e4d".into(),
                acc: 76.875,
                latency_ms: 2.5,
                macs_m: 400.25,
                params_m: 5.5,
                rank: 0,
            }),
        );
    }

    #[test]
    fn http_body_codec_round_trips_without_envelope() {
        // The HTTP body is the envelope minus v/op; decode_request_body
        // with the op from the URL must rebuild the identical body.
        for req in [
            Request::new(3, RequestBody::Infer { input: vec![1.0, -0.5] }),
            Request::new(
                4,
                RequestBody::Simulate {
                    model: ModelSpec::Zoo("mobilenet-v2".into()),
                    variant: FuseVariant::Half,
                    config: ConfigPatch::sized(16),
                },
            )
            .with_deadline_ms(750),
            Request::new(
                5,
                RequestBody::Sweep {
                    models: vec!["mobilenet-v2".into()],
                    variants: vec![FuseVariant::Base, FuseVariant::Full],
                    configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(32)],
                },
            ),
            Request::new(6, RequestBody::Stats),
        ] {
            let body = encode_request_body(&req);
            assert!(!body.contains("\"v\":"), "no version field in HTTP bodies: {body}");
            assert!(!body.contains("\"op\":"), "no op field in HTTP bodies: {body}");
            let v = parse_json(&body).unwrap();
            assert_eq!(v.get("id").and_then(Json::as_u64), Some(req.id));
            assert_eq!(
                v.get("deadline_ms").and_then(Json::as_u64),
                req.deadline_ms,
                "{body}"
            );
            let back = decode_request_body(req.body.op(), &v).unwrap();
            assert_eq!(back, req.body, "round-trip mismatch for {body}");
        }
    }

    #[test]
    fn sse_rendering_carries_the_tcp_frame_json() {
        let frame = Frame::Progress { done: 3, total: 24 };
        let event = encode_sse_event(9, &frame);
        assert!(event.starts_with("event: progress\nid: 9\ndata: "));
        assert!(event.ends_with("\n\n"), "an SSE event ends with a blank line");
        let data = event
            .lines()
            .find_map(|l| l.strip_prefix("data: "))
            .expect("data line");
        // byte-identical to the TCP framing, so decode_frame is shared
        assert_eq!(data, encode_frame(9, &frame));
        assert_eq!(decode_frame(data).unwrap(), (9, frame));
        // every frame kind carries its tag as the event name
        let event = encode_sse_event(1, &Frame::Final(Err(ServeError::Busy)));
        assert!(event.starts_with("event: final\n"), "{event}");
    }

    #[test]
    fn decode_response_rejects_non_final_frames() {
        let line = encode_frame(3, &Frame::Progress { done: 1, total: 2 });
        assert!(decode_response(&line).is_err());
        let line = encode_frame(3, &Frame::Final(Ok(Reply::Done)));
        assert_eq!(decode_response(&line).unwrap(), Response::ok(3, Reply::Done));
    }

    #[test]
    fn decode_frame_rejects_malformed_streams() {
        assert!(decode_frame(r#"{"v":2,"id":1,"frame":"progress","done":1}"#).is_err());
        assert!(decode_frame(r#"{"v":2,"id":1,"frame":"row"}"#).is_err());
        assert!(decode_frame(r#"{"v":2,"id":1,"frame":"final"}"#).is_err());
        assert!(decode_frame(r#"{"v":2,"id":1,"frame":"chunk"}"#).is_err());
        assert!(decode_frame(r#"{"v":2,"id":1}"#).is_err(), "frame tag required");
        assert!(decode_frame(r#"{"v":1,"id":1,"frame":"final","ok":{"kind":"done"}}"#).is_err());
    }

    #[test]
    fn sim_config_round_trips_fully() {
        let mut cfg = SimConfig::with_size(32);
        cfg.dataflow = Dataflow::WeightStationary;
        cfg.stos = false;
        cfg.mapping = MappingPolicy::SpatialFirst;
        cfg.dram_bw = 24.5;
        cfg.enforce_dram_bw = true;
        cfg.freq_mhz = 750;
        cfg.bytes_per_elem = 2;
        let j = sim_config_to_json(&cfg);
        let back = sim_config_from_json(&j).unwrap();
        assert_eq!(back.price_key(), cfg.price_key());
        assert_eq!(back.freq_mhz, cfg.freq_mhz);
        assert_eq!(back.dram_bw, cfg.dram_bw);
        assert_eq!(back.mapping, cfg.mapping);
    }

    #[test]
    fn decode_rejects_wrong_version_and_bad_ops() {
        let mut line = encode_request(&Request::new(1, RequestBody::Stats));
        line = line.replace("\"v\":2", "\"v\":99");
        assert!(decode_request(&line).is_err());
        // v1 one-shot traffic is rejected with a version error, so old
        // clients get a clear negotiation failure instead of silence
        let e = decode_request(r#"{"v":1,"id":1,"op":"stats"}"#).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
        assert!(decode_request(r#"{"v":2,"id":1,"op":"frobnicate"}"#).is_err());
        assert!(decode_request(r#"{"v":2,"op":"stats"}"#).is_err(), "id is required");
        assert!(decode_request("not json").is_err());
    }

    #[test]
    fn simulate_defaults_when_variant_and_config_absent() {
        let req =
            decode_request(r#"{"v":2,"id":9,"op":"simulate","model":{"zoo":"mbv2"}}"#).unwrap();
        match req.body {
            RequestBody::Simulate { model, variant, config } => {
                assert_eq!(model, ModelSpec::Zoo("mbv2".into()));
                assert_eq!(variant, FuseVariant::Base);
                assert_eq!(config, ConfigPatch::default());
            }
            other => panic!("wrong body {other:?}"),
        }
    }

    #[test]
    fn variant_strings_accept_short_and_long_forms() {
        for (s, want) in [
            ("base", FuseVariant::Base),
            ("half", FuseVariant::Half),
            ("fuse-half", FuseVariant::Half),
            ("full", FuseVariant::Full),
            ("fuse-full", FuseVariant::Full),
        ] {
            assert_eq!(FuseVariant::parse(s), Some(want), "{s}");
        }
        assert_eq!(FuseVariant::parse("quarter"), None);
    }
}
