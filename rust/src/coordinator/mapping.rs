//! Block-selection policies for the partial (50 %) FuSe variants.
//!
//! Table 3's `-50%` rows convert only half the bottleneck blocks, "chosen
//! greedily based on the impact on latency" — i.e. convert the blocks whose
//! conversion saves the most cycles first.

use super::evaluator::HybridSpace;

/// Mask converting the `count` blocks with the largest cycle savings.
pub fn greedy_by_latency(space: &HybridSpace, count: usize) -> Vec<bool> {
    let n = space.num_blocks();
    let mut savings: Vec<(usize, u64)> = (0..n)
        .map(|i| (i, space.dw_cycles[i].saturating_sub(space.fuse_cycles[i])))
        .collect();
    savings.sort_by_key(|&(_, s)| std::cmp::Reverse(s));
    let mut mask = vec![false; n];
    for &(i, _) in savings.iter().take(count.min(n)) {
        mask[i] = true;
    }
    mask
}

/// The paper's 50 % variant.
pub fn greedy_half(space: &HybridSpace) -> Vec<bool> {
    greedy_by_latency(space, (space.num_blocks() + 1) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::Evaluator;
    use crate::nn::models::mobilenet_v2;
    use crate::sim::SimConfig;

    fn space() -> HybridSpace {
        HybridSpace::new(&mobilenet_v2::build(), &Evaluator::new(SimConfig::default()))
    }

    #[test]
    fn converts_exactly_half() {
        let sp = space();
        let mask = greedy_half(&sp);
        let n = sp.num_blocks();
        assert_eq!(mask.iter().filter(|&&m| m).count(), (n + 1) / 2);
    }

    #[test]
    fn greedy_is_optimal_for_its_budget() {
        // any other mask with the same count must be no faster
        let sp = space();
        let k = 5;
        let mask = greedy_by_latency(&sp, k);
        let greedy_cycles = sp.cycles(&mask);
        let n = sp.num_blocks();
        // compare against 50 random masks of the same cardinality
        let mut rng = crate::rng::Rng::new(3);
        for _ in 0..50 {
            let mut other = vec![false; n];
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            for &i in idx.iter().take(k) {
                other[i] = true;
            }
            assert!(sp.cycles(&other) >= greedy_cycles);
        }
    }

    #[test]
    fn half_variant_latency_between_base_and_full() {
        let sp = space();
        let n = sp.num_blocks();
        let half = sp.cycles(&greedy_half(&sp));
        let base = sp.cycles(&vec![false; n]);
        let full = sp.cycles(&vec![true; n]);
        assert!(full <= half && half <= base);
        // greedy-by-latency captures most of the benefit (paper: the 50%
        // variants retain most of the speedup)
        let captured = (base - half) as f64 / (base - full) as f64;
        assert!(captured > 0.6, "captured only {captured}");
    }

    #[test]
    fn zero_budget_is_baseline() {
        let sp = space();
        let mask = greedy_by_latency(&sp, 0);
        assert!(mask.iter().all(|&m| !m));
    }
}
