//! The unified serving protocol (DESIGN.md S12): one versioned, typed
//! request/frame vocabulary shared by every serving surface — the
//! in-process [`Service`] trait implemented by the batched inference
//! server and the cache-backed simulation pool, and the wire-level
//! TCP/JSON frontend in [`net`](super::net).
//!
//! Protocol v2 is a *streaming* contract: a request is answered by a
//! stream of [`Frame`]s keyed by the request's id — zero or more
//! [`Frame::Progress`]/[`Frame::Row`] frames followed by exactly one
//! [`Frame::Final`]. Point queries (Infer/Simulate/Stats/Zoo) emit just
//! the `Final`; a `Sweep` streams each grid row as the sweep engine
//! completes it, so large grids never buffer into one giant frame.
//!
//! Design rules:
//! * every request carries a client-chosen `id` echoed on every frame of
//!   its reply stream, so frames from concurrent requests interleave
//!   safely on one pipelined/wire transport;
//! * deadlines are explicit (`deadline_ms` from admission) and produce a
//!   typed [`ServeError::Deadline`], never a hang;
//! * admission control is part of the contract, and is *priority-tiered*:
//!   interactive traffic (`Infer`/`Simulate`/`Stats`/`Zoo`) and batch
//!   traffic (`Sweep`) are admitted through separate bounded lanes, so a
//!   full batch lane answers [`ServeError::Busy`] without starving point
//!   queries (see [`RequestBody::priority`]);
//! * models are addressed by zoo name *or* shipped inline as layer
//!   specs, so remote clients need no access to the zoo crate;
//! * reply streams are *bounded* ([`STREAM_BOUND`] frames): a producer
//!   that outruns its consumer pauses instead of buffering without
//!   limit, so one slow client can never balloon server memory.
//!
//! The normative wire contract — the envelope fields, frame grammar,
//! error taxonomy, and both transport renderings (newline-delimited
//! TCP frames and HTTP/SSE) — is pinned in `PROTOCOL.md` at the
//! repository root; this module is its in-process realization.
//!
//! ```
//! use fuseconv::coordinator::{Reply, Response, Ticket};
//! // A service streams frames into the sink; the caller collapses the
//! // ticket into one response (`wait` merges streamed sweep rows).
//! let (ticket, sink) = Ticket::pending(7);
//! sink.progress(0, 1);
//! sink.finish(Ok(Reply::Done));
//! assert_eq!(ticket.wait(), Response::ok(7, Reply::Done));
//! ```

use crate::nn::{models, Layer, Network, OpKind};
use crate::sim::{Dataflow, FuseVariant, MappingPolicy, NetworkSim, SimConfig};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Wire/protocol version; bumped on any incompatible change to the
/// request or frame schema. v2 replaced the one-shot response with the
/// frame-stream grammar (`progress*`/`row*` then one `final`).
pub const PROTOCOL_VERSION: u32 = 2;

/// Largest accepted PE-array side length in a request config — a sanity
/// bound on remote input, far above any hardware the paper models.
pub const MAX_ARRAY_DIM: usize = 4096;

/// Bound on one reply stream's frame buffer (the channel between a
/// [`FrameSink`] and its [`Ticket`]). Point queries emit a single
/// terminal frame and never block; a streaming producer (sweep rows)
/// that gets this far ahead of its consumer pauses until the consumer
/// catches up — backpressure instead of unbounded buffering. Sized so a
/// typical Table-1 grid streams without a single pause.
pub const STREAM_BOUND: usize = 256;

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// One request envelope: id + optional deadline + typed body.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client-chosen id, echoed verbatim on the response.
    pub id: u64,
    /// Optional deadline, in milliseconds from admission. Work still
    /// queued when it expires is answered with [`ServeError::Deadline`].
    pub deadline_ms: Option<u64>,
    /// Optional auth token (`PROTOCOL.md` §Authentication). On the TCP
    /// framing this rides the envelope; the HTTP rendering carries it as
    /// an `Authorization: Bearer` header instead and never places it in
    /// the body. A frontend started with `--auth-token` rejects
    /// requests whose token is absent or wrong with
    /// [`ServeError::Unauthorized`].
    pub token: Option<String>,
    pub body: RequestBody,
}

impl Request {
    pub fn new(id: u64, body: RequestBody) -> Request {
        Request { id, deadline_ms: None, token: None, body }
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn with_token(mut self, token: impl Into<String>) -> Request {
        self.token = Some(token.into());
        self
    }
}

/// The typed operations the serving surface understands.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestBody {
    /// Run one input through the batched inference engine.
    Infer { input: Vec<f32> },
    /// Price one (model, variant, config) scenario on the simulator.
    Simulate { model: ModelSpec, variant: FuseVariant, config: ConfigPatch },
    /// Price a models × variants × configs grid (zoo names only).
    Sweep { models: Vec<String>, variants: Vec<FuseVariant>, configs: Vec<ConfigPatch> },
    /// Serving/cache statistics snapshot.
    Stats,
    /// List the model zoo (names + MAC/param totals).
    Zoo,
    /// Run an evolutionary NAS job over the FuSe-extended OFA space;
    /// the reply is a long-lived frame stream (`progress` per
    /// generation, `search_row` per Pareto-front point, then a terminal
    /// `search` reply with the converged frontier).
    Search { spec: SearchSpec },
    /// Cooperatively cancel the in-flight streaming request whose
    /// envelope id is `target`. Idempotent: cancelling an unknown or
    /// already-finished id still acks `Done`.
    Cancel { target: u64 },
    /// Admin op (shard front tier only): join `addr` to the fleet. New
    /// traffic starts routing to it immediately; only the keys that
    /// rendezvous-move to the new node go cold. A direct single node
    /// answers `bad_request`.
    AddBackend { addr: String },
    /// Admin op (shard front tier only): stop routing *new* work to
    /// `addr`, let its in-flight requests finish, then drop it from the
    /// fleet. Idempotent; a direct single node answers `bad_request`.
    DrainBackend { addr: String },
    /// Ask the frontend to stop accepting traffic and exit cleanly.
    Shutdown,
}

impl RequestBody {
    /// Short operation name (used in wire tags and log lines).
    pub fn op(&self) -> &'static str {
        match self {
            RequestBody::Infer { .. } => "infer",
            RequestBody::Simulate { .. } => "simulate",
            RequestBody::Sweep { .. } => "sweep",
            RequestBody::Stats => "stats",
            RequestBody::Zoo => "zoo",
            RequestBody::Search { .. } => "search",
            RequestBody::Cancel { .. } => "cancel",
            RequestBody::AddBackend { .. } => "add-backend",
            RequestBody::DrainBackend { .. } => "drain-backend",
            RequestBody::Shutdown => "shutdown",
        }
    }

    /// Which admission lane this operation rides: whole-grid `Sweep`s are
    /// batch traffic, multi-minute `Search` jobs get their own (narrow)
    /// lane, and everything else is interactive. The lanes have separate
    /// bounds so searches can't starve sweeps and neither can starve
    /// dashboard point queries.
    pub fn priority(&self) -> Priority {
        match self {
            RequestBody::Sweep { .. } => Priority::Batch,
            RequestBody::Search { .. } => Priority::Search,
            _ => Priority::Interactive,
        }
    }
}

/// Admission lane of a request (see [`RequestBody::priority`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// Point queries: single Infer/Simulate, Stats, Zoo, Cancel, Shutdown.
    Interactive,
    /// Whole-grid traffic: Sweep (EA/NAS populations, table reproduction).
    Batch,
    /// Long-lived evolutionary search jobs (`Search`).
    Search,
}

/// Parameters of one wire-served NAS job — the serving-side mirror of
/// `NasConfig` (thread count stays a server concern and is deliberately
/// absent: results are thread-count-invariant by construction).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpec {
    pub population: usize,
    pub iterations: usize,
    pub mutation_p: f64,
    pub allow_fuse: bool,
    pub seed: u64,
    /// Hardware config the candidates are priced on (Table-1 defaults
    /// plus these overrides, exactly like `Simulate`).
    pub config: ConfigPatch,
}

impl Default for SearchSpec {
    fn default() -> SearchSpec {
        SearchSpec {
            population: 32,
            iterations: 16,
            mutation_p: 0.15,
            allow_fuse: true,
            seed: 42,
            config: ConfigPatch::default(),
        }
    }
}

/// Remote-input sanity bounds on a search job (far above any useful
/// run; a genuinely bigger experiment belongs in-process, not behind a
/// serving lane).
pub const MAX_SEARCH_POPULATION: usize = 1024;
pub const MAX_SEARCH_ITERATIONS: usize = 1024;

impl SearchSpec {
    /// Validate remote input. The evolutionary loop needs at least two
    /// elites, so populations below 2 are rejected rather than panicking
    /// mid-generation.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.population < 2 || self.population > MAX_SEARCH_POPULATION {
            return Err(ServeError::BadRequest(format!(
                "population {} outside 2..={MAX_SEARCH_POPULATION}",
                self.population
            )));
        }
        if self.iterations > MAX_SEARCH_ITERATIONS {
            return Err(ServeError::BadRequest(format!(
                "iterations {} exceeds {MAX_SEARCH_ITERATIONS}",
                self.iterations
            )));
        }
        if !(0.0..=1.0).contains(&self.mutation_p) {
            return Err(ServeError::BadRequest(format!(
                "mutation_p {} outside 0..=1",
                self.mutation_p
            )));
        }
        Ok(())
    }
}

/// How a simulation request names its network: by zoo name, or as an
/// inline list of layer specs (for networks the server has never seen).
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    Zoo(String),
    Inline { name: String, layers: Vec<LayerSpec> },
}

impl ModelSpec {
    /// Resolve to a concrete [`Network`]; unknown zoo names and empty
    /// inline specs are [`ServeError::BadRequest`]s.
    pub fn resolve(&self) -> Result<Network, ServeError> {
        match self {
            ModelSpec::Zoo(name) => models::by_name(name).ok_or_else(|| {
                ServeError::BadRequest(format!("unknown zoo model {name:?}"))
            }),
            ModelSpec::Inline { name, layers } => {
                if layers.is_empty() {
                    return Err(ServeError::BadRequest("inline model has no layers".into()));
                }
                let layers: Vec<Layer> = layers.iter().map(|s| s.to_layer()).collect();
                let num_blocks =
                    layers.iter().filter_map(|l| l.block).max().map_or(0, |b| b + 1);
                Ok(Network { name: name.clone(), layers, num_blocks })
            }
        }
    }
}

/// Wire-friendly layer description: exactly the fields that affect
/// simulation (operator + input spatial dims + block membership).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: String,
    pub op: OpKind,
    pub h: usize,
    pub w: usize,
    pub block: Option<usize>,
}

impl LayerSpec {
    pub fn from_layer(l: &Layer) -> LayerSpec {
        LayerSpec { name: l.name.clone(), op: l.op, h: l.h, w: l.w, block: l.block }
    }

    pub fn to_layer(&self) -> Layer {
        let mut l = Layer::new(self.name.clone(), self.op, self.h, self.w);
        if let Some(b) = self.block {
            l = l.in_block(b);
        }
        l
    }
}

/// A partial [`SimConfig`]: only the overridden fields are present, the
/// rest come from the paper's Table-1 defaults. This is the protocol's
/// config vocabulary and the CLI's `--size/--dataflow/--no-stos`
/// equivalents share its validation (via [`Dataflow::parse`] /
/// [`MappingPolicy::parse`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConfigPatch {
    /// Square-array shorthand (sets both rows and cols).
    pub size: Option<usize>,
    pub rows: Option<usize>,
    pub cols: Option<usize>,
    pub freq_mhz: Option<u64>,
    pub ifmap_sram_kb: Option<usize>,
    pub weight_sram_kb: Option<usize>,
    pub ofmap_sram_kb: Option<usize>,
    pub dram_bw: Option<f64>,
    pub enforce_dram_bw: Option<bool>,
    pub bytes_per_elem: Option<usize>,
    pub dataflow: Option<Dataflow>,
    pub stos: Option<bool>,
    pub mapping: Option<MappingPolicy>,
}

impl ConfigPatch {
    /// Just the array size (the most common override).
    pub fn sized(size: usize) -> ConfigPatch {
        ConfigPatch { size: Some(size), ..ConfigPatch::default() }
    }

    /// Apply the overrides on top of `base`. `rows`/`cols` win over
    /// `size` when both are given. Zero-sized arrays are rejected.
    pub fn apply(&self, base: &SimConfig) -> Result<SimConfig, ServeError> {
        let mut cfg = base.clone();
        if let Some(s) = self.size {
            cfg.rows = s;
            cfg.cols = s;
        }
        if let Some(r) = self.rows {
            cfg.rows = r;
        }
        if let Some(c) = self.cols {
            cfg.cols = c;
        }
        if let Some(f) = self.freq_mhz {
            cfg.freq_mhz = f;
        }
        if let Some(k) = self.ifmap_sram_kb {
            cfg.ifmap_sram_kb = k;
        }
        if let Some(k) = self.weight_sram_kb {
            cfg.weight_sram_kb = k;
        }
        if let Some(k) = self.ofmap_sram_kb {
            cfg.ofmap_sram_kb = k;
        }
        if let Some(bw) = self.dram_bw {
            cfg.dram_bw = bw;
        }
        if let Some(e) = self.enforce_dram_bw {
            cfg.enforce_dram_bw = e;
        }
        if let Some(b) = self.bytes_per_elem {
            cfg.bytes_per_elem = b;
        }
        if let Some(df) = self.dataflow {
            cfg.dataflow = df;
        }
        if let Some(s) = self.stos {
            cfg.stos = s;
        }
        if let Some(m) = self.mapping {
            cfg.mapping = m;
        }
        if cfg.rows == 0 || cfg.cols == 0 {
            return Err(ServeError::BadRequest(format!(
                "degenerate array geometry {}x{}",
                cfg.rows, cfg.cols
            )));
        }
        // Remote input: bound the geometry so arithmetic on rows*cols and
        // per-fold allocations can't overflow or balloon (paper max 128;
        // 4096 leaves room for far-future what-ifs).
        if cfg.rows > MAX_ARRAY_DIM || cfg.cols > MAX_ARRAY_DIM {
            return Err(ServeError::BadRequest(format!(
                "array geometry {}x{} exceeds the {MAX_ARRAY_DIM} per-side limit",
                cfg.rows, cfg.cols
            )));
        }
        if cfg.freq_mhz == 0 || cfg.bytes_per_elem == 0 {
            return Err(ServeError::BadRequest(
                "freq_mhz and bytes_per_elem must be positive".into(),
            ));
        }
        Ok(cfg)
    }

    /// Overrides applied to the paper's Table-1 defaults.
    pub fn to_config(&self) -> Result<SimConfig, ServeError> {
        self.apply(&SimConfig::default())
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// One response envelope: the request's id plus either a typed reply or
/// a typed error.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub result: Result<Reply, ServeError>,
}

impl Response {
    pub fn ok(id: u64, reply: Reply) -> Response {
        Response { id, result: Ok(reply) }
    }

    pub fn err(id: u64, e: ServeError) -> Response {
        Response { id, result: Err(e) }
    }

    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }
}

/// Successful results, one variant per request kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    Infer(InferReply),
    Sim(SimSummary),
    Sweep(Vec<SweepRow>),
    Stats(StatsReply),
    Zoo(Vec<ZooEntry>),
    /// Terminal reply of a `Search` stream: the converged frontier.
    Search(SearchReply),
    /// Acknowledgement with no payload (e.g. `Shutdown`, `Cancel`).
    Done,
}

/// Completed inference, with the serving-side latency breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReply {
    pub output: Vec<f32>,
    /// Time spent queued before the engine ran (admission → engine start).
    pub queue_us: u64,
    /// Size of the dynamic batch this request rode in.
    pub batch_size: usize,
    /// End-to-end latency (admission → response).
    pub latency_us: u64,
}

/// Network-level simulation summary — the serving-sized digest of a
/// [`NetworkSim`] (per-layer detail stays in-process; `fuseconv trace`
/// serves that need).
#[derive(Debug, Clone, PartialEq)]
pub struct SimSummary {
    pub network: String,
    pub config_label: String,
    pub total_cycles: u64,
    pub latency_ms: f64,
    pub utilization: f64,
    pub num_layers: usize,
}

impl SimSummary {
    pub fn of(sim: &NetworkSim) -> SimSummary {
        SimSummary {
            network: sim.network.clone(),
            config_label: sim.config_label.clone(),
            total_cycles: sim.total_cycles,
            latency_ms: sim.latency_ms,
            utilization: sim.overall_utilization(),
            num_layers: sim.layers.len(),
        }
    }
}

/// One sweep grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub network: String,
    pub variant: FuseVariant,
    pub rows: usize,
    pub cols: usize,
    pub dataflow: Dataflow,
    pub stos: bool,
    pub total_cycles: u64,
    pub latency_ms: f64,
}

/// One point on a search's Pareto front, as streamed in `search_row`
/// frames and carried by the terminal [`SearchReply`]. The genome rides
/// as its compact string form (`OfaGenome::compact`) — clients plot and
/// compare points; the server alone realizes genomes.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchPoint {
    pub genome: String,
    /// Predicted top-1 accuracy (calibrated OFA predictor, NOS-trained).
    pub acc: f64,
    /// Simulated latency on the requested config.
    pub latency_ms: f64,
    pub macs_m: f64,
    pub params_m: f64,
    /// Pareto rank at emission time (0 = non-dominated).
    pub rank: u64,
}

/// Terminal payload of a `Search` stream.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchReply {
    /// The converged Pareto frontier, sorted by latency ascending.
    pub frontier: Vec<SearchPoint>,
    /// Genomes evaluated across all generations run.
    pub evaluated: u64,
    /// Generations completed (equals the requested iterations unless
    /// cancelled).
    pub generations: u64,
    /// The job was cancelled (explicit `cancel` frame or client
    /// disconnect); the frontier covers the generations that ran.
    pub cancelled: bool,
}

/// Serving statistics snapshot (inference + simulation + shared cache).
///
/// A shard front tier ([`ShardRouter`](super::shard::ShardRouter))
/// answers `Stats` with the *sum* of every backend's counters and sets
/// [`backends`](StatsReply::backends) to the number of nodes
/// aggregated; a direct single-process server reports `backends: 0`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatsReply {
    pub protocol_version: u32,
    pub infer_served: u64,
    pub infer_batches: u64,
    pub sim_submitted: u64,
    pub sim_completed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: u64,
    /// Number of shard backends aggregated into this snapshot; `0`
    /// means the counters come from the answering process itself.
    pub backends: u64,
    /// Live transport gauge: connections currently open across the
    /// answering process's frontends. Additive v2 field (absent = 0 on
    /// the wire); unlike the counters above, gauges are *not* summed by
    /// a shard front tier — they always describe the answering process.
    pub open_conns: u64,
    /// Live transport gauge: reply streams currently being forwarded.
    pub active_streams: u64,
    /// Live transport gauge: OS threads owned by the transports. The
    /// threaded transport grows this with connections; the epoll
    /// transport holds it at one per frontend — the observable
    /// O(threads) ≪ O(connections) claim.
    pub transport_threads: u64,
    /// Global result cache: requests served from a completed entry.
    /// The `result_*` fields describe the cross-request *result* cache
    /// (`serve --cache-entries`); the `cache_*` fields above describe
    /// the per-layer cache. Additive v2 fields (absent = 0 on the
    /// wire); all six are summed by a shard front tier, so `entries`/
    /// `bytes` read as fleet-wide residency.
    pub result_hits: u64,
    /// Global result cache: requests that simulated (single-flight
    /// leaders).
    pub result_misses: u64,
    /// Global result cache: requests that coalesced onto another
    /// request's in-flight simulation.
    pub result_coalesced: u64,
    /// Global result cache: entries retired by the LRU size bound.
    pub result_evicted: u64,
    /// Global result cache gauge: completed entries resident.
    pub result_entries: u64,
    /// Global result cache gauge: estimated bytes resident.
    pub result_bytes: u64,
    /// Search jobs admitted into the search lane. Additive v2 fields
    /// (absent = 0 on the wire); summed by a shard front tier.
    pub search_started: u64,
    /// Search jobs that ran every requested generation to completion.
    pub search_completed: u64,
    /// Search jobs stopped early — explicit `cancel` frame or client
    /// disconnect.
    pub search_cancelled: u64,
    /// Shard front tier only: one `addr=state` entry per fleet member
    /// (`up`, `suspect`, `down`, or `draining`). Additive field (absent
    /// = empty on the wire); a direct single node reports an empty
    /// list, and a front tier never sums it — it always describes the
    /// answering tier's own membership view.
    pub backend_state: Vec<String>,
    /// Shard front tier: sweep cells re-planned onto a survivor (plus
    /// `Simulate` retries) after a backend died mid-request. Additive
    /// field (absent = 0); summed like the other counters, but backends
    /// themselves always report 0.
    pub failover_resteered: u64,
    /// Shard front tier: health-probe round-trips that failed (each
    /// failure pushes the probed backend toward `Suspect`/`Down`).
    /// Additive field (absent = 0).
    pub probe_failures: u64,
}

/// One zoo listing row.
#[derive(Debug, Clone, PartialEq)]
pub struct ZooEntry {
    pub name: String,
    pub macs_m: f64,
    pub params_m: f64,
    pub blocks: usize,
}

/// Typed serving failures. These travel over the wire, so they carry no
/// foreign error types — just enough for the client to act.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Bounded admission queue is full; retry with backoff.
    Busy,
    /// The request cannot be served as stated (unknown model, bad
    /// geometry, missing engine, malformed frame, ...).
    BadRequest(String),
    /// The request's deadline expired before the work ran to completion.
    Deadline,
    /// The frontend requires an auth token and the request carried none,
    /// or the wrong one. Maps to HTTP 401.
    Unauthorized,
    /// The service is shutting down (or already gone).
    Shutdown,
}

impl ServeError {
    /// Stable wire code for the error kind.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Busy => "busy",
            ServeError::BadRequest(_) => "bad_request",
            ServeError::Deadline => "deadline",
            ServeError::Unauthorized => "unauthorized",
            ServeError::Shutdown => "shutdown",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy => write!(f, "busy: admission queue full"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::Deadline => write!(f, "deadline expired"),
            ServeError::Unauthorized => write!(f, "unauthorized: missing or invalid token"),
            ServeError::Shutdown => write!(f, "service shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

// ---------------------------------------------------------------------------
// Frames, Service + Ticket
// ---------------------------------------------------------------------------

/// One element of a reply stream. A request's stream is
/// `Progress*/Row*` interleaved, then exactly one `Final`.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Completion counter for a multi-frame request (`done`/`total`
    /// grid cells, or generations for a search). Servers emit one up
    /// front (`done == 0`) so clients learn the total before the first
    /// row lands.
    Progress { done: u64, total: u64 },
    /// One incremental sweep grid row, emitted in plan order.
    Row(SweepRow),
    /// One Pareto-front point of an in-flight search, re-emitted per
    /// generation as the frontier evolves (v2-additive frame kind; only
    /// `search` streams carry it).
    SearchRow(SearchPoint),
    /// Terminal frame: the typed result (or error) that ends the stream.
    Final(Result<Reply, ServeError>),
}

impl Frame {
    pub fn is_final(&self) -> bool {
        matches!(self, Frame::Final(_))
    }

    /// Stable wire tag of the frame kind — the `frame` field of the TCP
    /// framing and the `event:` name of the SSE rendering.
    pub fn tag(&self) -> &'static str {
        match self {
            Frame::Progress { .. } => "progress",
            Frame::Row(_) => "row",
            Frame::SearchRow(_) => "search_row",
            Frame::Final(_) => "final",
        }
    }
}

/// The protocol's stream-collapse rule, shared by every consumer that
/// folds a frame stream into one result ([`Ticket::wait`] in-process,
/// `WireClient::recv_response` on the wire): a streamed sweep terminates
/// with `Done` and its rows are reassembled into [`Reply::Sweep`]; any
/// other terminal result passes through unchanged.
pub fn collapse_stream(
    result: Result<Reply, ServeError>,
    rows: Vec<SweepRow>,
) -> Result<Reply, ServeError> {
    match result {
        Ok(Reply::Done) if !rows.is_empty() => Ok(Reply::Sweep(rows)),
        other => other,
    }
}

/// Receive failure on a [`Ticket`] — distinct cases so callers can tell
/// "nothing arrived within the timeout" (retryable) from "the serving
/// side dropped the stream" (terminal).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// The timeout expired with no frame; the stream is still live.
    Deadline,
    /// The service dropped its sink without a `Final` (shutdown/crash),
    /// or the stream already delivered its `Final`.
    Disconnected,
}

/// Anything that can serve protocol requests. Both halves of the
/// coordinator implement this — the batched inference [`Server`]
/// (`coordinator::server`) and the cache-backed [`SimServer`] pool — as
/// does the [`Router`](super::server::Router) that fronts them for the
/// TCP listener.
///
/// `call` never blocks on the work itself: it performs admission control
/// and returns a [`Ticket`] the caller redeems for the reply stream.
pub trait Service: Send + Sync {
    fn call(&self, req: Request) -> Ticket;
}

/// The serving side of one reply stream: emits frames into the matching
/// [`Ticket`]. Cheap to clone (worker threads can share it). Send
/// failures are deliberately swallowed — a client that dropped its
/// ticket is not the server's problem.
///
/// The stream buffer is bounded ([`STREAM_BOUND`]): once that many
/// frames are queued unconsumed, further sends *block* until the
/// consumer drains — a streaming producer is paused by its slowest
/// reader rather than buffering without limit. Single-frame replies
/// (every point query) always fit the buffer and never block.
#[derive(Debug, Clone)]
pub struct FrameSink {
    id: u64,
    tx: mpsc::SyncSender<Frame>,
}

impl FrameSink {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Emit a progress frame; `false` if the client hung up.
    pub fn progress(&self, done: u64, total: u64) -> bool {
        self.tx.send(Frame::Progress { done, total }).is_ok()
    }

    /// Emit one sweep row; `false` if the client hung up.
    pub fn row(&self, row: SweepRow) -> bool {
        self.tx.send(Frame::Row(row)).is_ok()
    }

    /// Emit one search Pareto-front point; `false` if the client hung
    /// up — search loops treat that as a cancellation signal.
    pub fn search_row(&self, point: SearchPoint) -> bool {
        self.tx.send(Frame::SearchRow(point)).is_ok()
    }

    /// Terminate the stream with its final result. Must be called exactly
    /// once; dropping the sink without it surfaces as a disconnect.
    pub fn finish(&self, result: Result<Reply, ServeError>) {
        let _ = self.tx.send(Frame::Final(result));
    }
}

/// A claim on one in-flight request: the receiving end of its frame
/// stream, with deadline-aware receive semantics so callers can never
/// hang forever.
#[derive(Debug)]
pub struct Ticket {
    id: u64,
    rx: mpsc::Receiver<Frame>,
    /// Set once `Final` has been delivered; later receives disconnect.
    finished: bool,
}

impl Ticket {
    /// A ticket plus the sink the service uses to stream into it. The
    /// stream buffer holds at most [`STREAM_BOUND`] undelivered frames
    /// (see [`FrameSink`] for the backpressure contract).
    pub fn pending(id: u64) -> (Ticket, FrameSink) {
        let (tx, rx) = mpsc::sync_channel(STREAM_BOUND);
        (Ticket { id, rx, finished: false }, FrameSink { id, tx })
    }

    /// A ticket whose stream is already terminal (admission-time errors
    /// and immediate replies).
    pub fn immediate(resp: Response) -> Ticket {
        let (ticket, sink) = Ticket::pending(resp.id);
        sink.finish(resp.result);
        ticket
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block at most `timeout` for the next frame. After the `Final`
    /// frame has been delivered the stream is over: further calls return
    /// [`RecvError::Disconnected`].
    pub fn recv_deadline(&mut self, timeout: Duration) -> Result<Frame, RecvError> {
        if self.finished {
            return Err(RecvError::Disconnected);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(frame) => {
                self.finished = frame.is_final();
                Ok(frame)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Err(RecvError::Deadline),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(RecvError::Disconnected),
        }
    }

    /// Non-blocking poll: `Ok(None)` while the stream is live but idle.
    pub fn try_recv(&mut self) -> Result<Option<Frame>, RecvError> {
        if self.finished {
            return Err(RecvError::Disconnected);
        }
        match self.rx.try_recv() {
            Ok(frame) => {
                self.finished = frame.is_final();
                Ok(Some(frame))
            }
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(RecvError::Disconnected),
        }
    }

    /// Drain the whole stream and collapse it into one [`Response`]:
    /// `Row` frames are merged (a streamed sweep that ends in `Done`
    /// becomes [`Reply::Sweep`] with the rows in emission order), and a
    /// dropped sink becomes [`ServeError::Shutdown`].
    pub fn wait(self) -> Response {
        self.drain(None)
    }

    /// As [`Ticket::wait`], bounded by an overall `timeout`; expiry
    /// yields a [`ServeError::Deadline`] response (the work may still
    /// complete server-side, but the claim is gone).
    pub fn wait_deadline(self, timeout: Duration) -> Response {
        self.drain(Some(Instant::now() + timeout))
    }

    /// Block indefinitely for the next frame (no timeout path).
    fn recv_blocking(&mut self) -> Result<Frame, RecvError> {
        if self.finished {
            return Err(RecvError::Disconnected);
        }
        match self.rx.recv() {
            Ok(frame) => {
                self.finished = frame.is_final();
                Ok(frame)
            }
            Err(mpsc::RecvError) => Err(RecvError::Disconnected),
        }
    }

    fn drain(mut self, deadline: Option<Instant>) -> Response {
        let id = self.id;
        let mut rows: Vec<SweepRow> = Vec::new();
        loop {
            let received = match deadline {
                None => self.recv_blocking(),
                Some(d) => match d.checked_duration_since(Instant::now()) {
                    Some(left) => self.recv_deadline(left),
                    None => return Response::err(id, ServeError::Deadline),
                },
            };
            match received {
                Ok(Frame::Progress { .. }) => {}
                Ok(Frame::Row(row)) => rows.push(row),
                // Incremental frontier previews; the terminal
                // `Reply::Search` carries the converged frontier.
                Ok(Frame::SearchRow(_)) => {}
                Ok(Frame::Final(result)) => {
                    return Response { id, result: collapse_stream(result, rows) };
                }
                Err(RecvError::Deadline) => return Response::err(id, ServeError::Deadline),
                Err(RecvError::Disconnected) => {
                    return Response::err(id, ServeError::Shutdown)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_spec_resolves_zoo_names() {
        let net = ModelSpec::Zoo("mobilenet-v2".into()).resolve().unwrap();
        assert_eq!(net.name, "MobileNet-V2");
        let err = ModelSpec::Zoo("nonesuch".into()).resolve().unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)));
    }

    #[test]
    fn inline_model_round_trips_layers() {
        let base = models::by_name("mobilenet-v3-small").unwrap();
        let specs: Vec<LayerSpec> = base.layers.iter().map(LayerSpec::from_layer).collect();
        let spec = ModelSpec::Inline { name: base.name.clone(), layers: specs };
        let rebuilt = spec.resolve().unwrap();
        assert_eq!(rebuilt.layers.len(), base.layers.len());
        assert_eq!(rebuilt.num_blocks, base.num_blocks);
        for (a, b) in rebuilt.layers.iter().zip(&base.layers) {
            assert_eq!(a.op, b.op);
            assert_eq!((a.h, a.w, a.block), (b.h, b.w, b.block));
        }
        // cycle counts are identical: the spec carries everything the
        // simulator reads
        let cfg = SimConfig::default();
        let a = crate::sim::simulate_network(&rebuilt, &cfg);
        let b = crate::sim::simulate_network(&base, &cfg);
        assert_eq!(a.total_cycles, b.total_cycles);
    }

    #[test]
    fn empty_inline_model_rejected() {
        let spec = ModelSpec::Inline { name: "x".into(), layers: vec![] };
        assert!(spec.resolve().is_err());
    }

    #[test]
    fn config_patch_applies_overrides() {
        let patch = ConfigPatch {
            size: Some(32),
            dataflow: Some(Dataflow::WeightStationary),
            stos: Some(false),
            freq_mhz: Some(500),
            ..ConfigPatch::default()
        };
        let cfg = patch.to_config().unwrap();
        assert_eq!((cfg.rows, cfg.cols), (32, 32));
        assert_eq!(cfg.dataflow, Dataflow::WeightStationary);
        assert!(!cfg.stos);
        assert_eq!(cfg.freq_mhz, 500);
        // untouched fields keep Table-1 defaults
        assert_eq!(cfg.ifmap_sram_kb, 64);
    }

    #[test]
    fn config_patch_rows_cols_win_over_size() {
        let patch = ConfigPatch {
            size: Some(32),
            rows: Some(8),
            cols: Some(64),
            ..ConfigPatch::default()
        };
        let cfg = patch.to_config().unwrap();
        assert_eq!((cfg.rows, cfg.cols), (8, 64));
    }

    #[test]
    fn config_patch_rejects_degenerate_geometry() {
        assert!(ConfigPatch::sized(0).to_config().is_err());
        let patch = ConfigPatch { freq_mhz: Some(0), ..ConfigPatch::default() };
        assert!(patch.to_config().is_err());
        // remote-input sanity bound: absurd geometries bounce as
        // BadRequest instead of reaching the simulator's arithmetic
        assert!(ConfigPatch::sized(MAX_ARRAY_DIM).to_config().is_ok());
        assert!(ConfigPatch::sized(MAX_ARRAY_DIM + 1).to_config().is_err());
        assert!(ConfigPatch::sized(usize::MAX).to_config().is_err());
    }

    #[test]
    fn empty_patch_is_table1_default() {
        let cfg = ConfigPatch::default().to_config().unwrap();
        let dflt = SimConfig::default();
        assert_eq!(cfg.price_key(), dflt.price_key());
        assert_eq!(cfg.freq_mhz, dflt.freq_mhz);
    }

    #[test]
    fn ticket_immediate_and_pending() {
        let t = Ticket::immediate(Response::err(7, ServeError::Busy));
        assert_eq!(t.id(), 7);
        let resp = t.wait();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.result, Err(ServeError::Busy));

        let (mut t, sink) = Ticket::pending(9);
        assert_eq!(t.try_recv(), Ok(None));
        sink.finish(Ok(Reply::Done));
        assert_eq!(t.wait(), Response::ok(9, Reply::Done));
    }

    #[test]
    fn ticket_recv_deadline_distinguishes_timeout_from_disconnect() {
        // live-but-idle stream: a timed-out recv is Deadline, not a
        // disconnect — the caller may retry.
        let (mut t, sink) = Ticket::pending(3);
        assert_eq!(t.recv_deadline(Duration::from_millis(5)), Err(RecvError::Deadline));
        sink.finish(Ok(Reply::Done));
        assert!(matches!(
            t.recv_deadline(Duration::from_millis(100)),
            Ok(Frame::Final(Ok(Reply::Done)))
        ));
        // stream over: further receives are Disconnected
        assert_eq!(t.recv_deadline(Duration::from_millis(5)), Err(RecvError::Disconnected));

        // dropped sink without a Final: Disconnected, never Deadline
        let (mut t, sink) = Ticket::pending(4);
        drop(sink);
        assert_eq!(t.recv_deadline(Duration::from_secs(5)), Err(RecvError::Disconnected));
    }

    #[test]
    fn ticket_dropped_sink_waits_as_shutdown() {
        let (t, sink) = Ticket::pending(4);
        drop(sink);
        assert_eq!(t.wait().result, Err(ServeError::Shutdown));
    }

    #[test]
    fn ticket_wait_merges_streamed_rows() {
        let (t, sink) = Ticket::pending(11);
        let row = SweepRow {
            network: "MobileNet-V2".into(),
            variant: FuseVariant::Half,
            rows: 16,
            cols: 16,
            dataflow: Dataflow::OutputStationary,
            stos: true,
            total_cycles: 42,
            latency_ms: 0.5,
        };
        assert!(sink.progress(0, 2));
        assert!(sink.row(row.clone()));
        assert!(sink.progress(1, 2));
        let mut row2 = row.clone();
        row2.rows = 32;
        assert!(sink.row(row2.clone()));
        sink.finish(Ok(Reply::Done));
        match t.wait().result {
            Ok(Reply::Sweep(rows)) => assert_eq!(rows, vec![row, row2]),
            other => panic!("expected merged sweep rows, got {other:?}"),
        }
    }

    #[test]
    fn ticket_try_recv_streams_in_order() {
        let (mut t, sink) = Ticket::pending(5);
        assert_eq!(t.try_recv(), Ok(None));
        sink.progress(1, 3);
        sink.finish(Ok(Reply::Done));
        assert_eq!(t.try_recv(), Ok(Some(Frame::Progress { done: 1, total: 3 })));
        assert_eq!(t.try_recv(), Ok(Some(Frame::Final(Ok(Reply::Done)))));
        assert_eq!(t.try_recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn bounded_stream_pauses_producer_until_consumer_drains() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        // ROADMAP backpressure item: a producer that outruns its
        // consumer must pause at STREAM_BOUND queued frames, then
        // resume losslessly (and in order) once the consumer drains.
        const EXTRA: usize = 8;
        let (mut ticket, sink) = Ticket::pending(21);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = Arc::clone(&sent);
        let producer = std::thread::spawn(move || {
            for i in 0..(STREAM_BOUND + EXTRA) as u64 {
                assert!(sink.progress(i, (STREAM_BOUND + EXTRA) as u64));
                sent2.fetch_add(1, Ordering::Release);
            }
            sink.finish(Ok(Reply::Done));
        });
        // Wait for the producer to fill the buffer, then confirm it has
        // paused there (the next send is blocked, not counted).
        let t0 = Instant::now();
        while sent.load(Ordering::Acquire) < STREAM_BOUND
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            sent.load(Ordering::Acquire),
            STREAM_BOUND,
            "producer must pause exactly at the stream bound"
        );
        // Drain: every frame arrives, in order, ending with the Final.
        let mut next = 0u64;
        loop {
            match ticket.recv_deadline(Duration::from_secs(10)).expect("frame") {
                Frame::Progress { done, .. } => {
                    assert_eq!(done, next, "frames must stay in emission order");
                    next += 1;
                }
                Frame::Final(result) => {
                    assert_eq!(result, Ok(Reply::Done));
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(next as usize, STREAM_BOUND + EXTRA, "no frame lost across the pause");
        producer.join().expect("producer");
    }

    #[test]
    fn request_priorities_split_interactive_from_batch() {
        assert_eq!(RequestBody::Stats.priority(), Priority::Interactive);
        assert_eq!(
            RequestBody::Infer { input: vec![] }.priority(),
            Priority::Interactive
        );
        assert_eq!(
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Base,
                config: ConfigPatch::default(),
            }
            .priority(),
            Priority::Interactive
        );
        assert_eq!(
            RequestBody::Sweep { models: vec![], variants: vec![], configs: vec![] }
                .priority(),
            Priority::Batch
        );
        // searches get their own lane; the cancel that stops one is a
        // point query (it must be admittable while every lane is full)
        assert_eq!(
            RequestBody::Search { spec: SearchSpec::default() }.priority(),
            Priority::Search
        );
        assert_eq!(RequestBody::Cancel { target: 7 }.priority(), Priority::Interactive);
    }

    #[test]
    fn serve_error_codes_are_stable() {
        assert_eq!(ServeError::Busy.code(), "busy");
        assert_eq!(ServeError::BadRequest("x".into()).code(), "bad_request");
        assert_eq!(ServeError::Deadline.code(), "deadline");
        assert_eq!(ServeError::Unauthorized.code(), "unauthorized");
        assert_eq!(ServeError::Shutdown.code(), "shutdown");
    }

    #[test]
    fn search_spec_validation_bounds_remote_input() {
        assert!(SearchSpec::default().validate().is_ok());
        let tiny = SearchSpec { population: 1, ..SearchSpec::default() };
        assert!(tiny.validate().is_err());
        let huge = SearchSpec { population: MAX_SEARCH_POPULATION + 1, ..SearchSpec::default() };
        assert!(huge.validate().is_err());
        let long = SearchSpec { iterations: MAX_SEARCH_ITERATIONS + 1, ..SearchSpec::default() };
        assert!(long.validate().is_err());
        let wild = SearchSpec { mutation_p: 1.5, ..SearchSpec::default() };
        assert!(wild.validate().is_err());
        // zero iterations is legal: initial population + frontier only
        let flat = SearchSpec { iterations: 0, ..SearchSpec::default() };
        assert!(flat.validate().is_ok());
    }
}
