//! Pareto-frontier utilities for the accuracy-vs-latency trade-off plots
//! (Figs 13 and 15).

/// A candidate point: maximize `acc`, minimize `latency_ms`.
#[derive(Debug, Clone, PartialEq)]
pub struct Point<T> {
    pub acc: f64,
    pub latency_ms: f64,
    pub tag: T,
}

/// `a` dominates `b` iff it is no worse in both objectives and strictly
/// better in at least one.
pub fn dominates<T>(a: &Point<T>, b: &Point<T>) -> bool {
    (a.acc >= b.acc && a.latency_ms <= b.latency_ms)
        && (a.acc > b.acc || a.latency_ms < b.latency_ms)
}

/// Non-dominated subset, sorted by latency ascending.
pub fn pareto_front<T: Clone>(points: &[Point<T>]) -> Vec<Point<T>> {
    let mut front: Vec<Point<T>> = Vec::new();
    for p in points {
        if points.iter().any(|q| dominates(q, p)) {
            continue;
        }
        // dedupe identical objective pairs
        if !front
            .iter()
            .any(|q| (q.acc - p.acc).abs() < 1e-12 && (q.latency_ms - p.latency_ms).abs() < 1e-12)
        {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap());
    front
}

/// Pareto rank of every point (0 = frontier, 1 = frontier after removing
/// rank-0, ...) — used for EA selection.
pub fn pareto_ranks<T>(points: &[Point<T>]) -> Vec<usize> {
    let n = points.len();
    let mut rank = vec![usize::MAX; n];
    let mut assigned = 0;
    let mut level = 0;
    while assigned < n {
        let mut this_level = Vec::new();
        for i in 0..n {
            if rank[i] != usize::MAX {
                continue;
            }
            let dominated = (0..n).any(|j| {
                j != i && rank[j] == usize::MAX && dominates(&points[j], &points[i])
            });
            if !dominated {
                this_level.push(i);
            }
        }
        if this_level.is_empty() {
            // all remaining are mutually identical duplicates
            for i in 0..n {
                if rank[i] == usize::MAX {
                    this_level.push(i);
                }
            }
        }
        for i in this_level {
            rank[i] = level;
            assigned += 1;
        }
        level += 1;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(acc: f64, lat: f64) -> Point<usize> {
        Point { acc, latency_ms: lat, tag: 0 }
    }

    #[test]
    fn dominance_rules() {
        assert!(dominates(&p(75.0, 1.0), &p(74.0, 2.0)));
        assert!(dominates(&p(75.0, 1.0), &p(75.0, 2.0)));
        assert!(!dominates(&p(75.0, 1.0), &p(75.0, 1.0))); // equal: no
        assert!(!dominates(&p(75.0, 2.0), &p(74.0, 1.0))); // trade-off
    }

    #[test]
    fn front_extraction() {
        let pts = vec![p(70.0, 1.0), p(75.0, 3.0), p(72.0, 2.0), p(71.0, 2.5), p(74.0, 2.9)];
        let front = pareto_front(&pts);
        let accs: Vec<f64> = front.iter().map(|q| q.acc).collect();
        // 71.0@2.5 is dominated by 72.0@2.0; everything else survives
        assert_eq!(accs, vec![70.0, 72.0, 74.0, 75.0]);
        // sorted by latency, acc strictly increasing along the front
        for w in front.windows(2) {
            assert!(w[0].latency_ms < w[1].latency_ms);
            assert!(w[0].acc < w[1].acc);
        }
    }

    #[test]
    fn ranks_layered() {
        let pts = vec![p(75.0, 1.0), p(74.0, 2.0), p(73.0, 3.0)];
        // first dominates the rest
        assert_eq!(pareto_ranks(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicate_points_share_rank() {
        let pts = vec![p(70.0, 1.0), p(70.0, 1.0)];
        let r = pareto_ranks(&pts);
        assert_eq!(r[0], r[1]);
    }
}
