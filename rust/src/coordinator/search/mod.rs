//! Search strategies over FuSe design spaces: evolutionary hybrid search
//! (Fig 13), OFA-space NAS with the FuSe operator choice (Fig 15), the
//! calibrated accuracy predictor, and pareto utilities.

pub mod ea;
pub mod nas;
pub mod pareto;
pub mod predictor;

pub use ea::{run_ea, Candidate, EaConfig, EaResult};
pub use nas::{run_nas, NasCandidate, NasConfig, NasResult};
pub use pareto::{pareto_front, pareto_ranks, Point};
pub use predictor::{paper_anchor, predict_ofa, AccuracyPredictor, TrainMethod};
