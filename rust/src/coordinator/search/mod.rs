//! Search strategies over FuSe design spaces: evolutionary hybrid search
//! (Fig 13), OFA-space NAS with the FuSe operator choice (Fig 15), the
//! calibrated accuracy predictor, and pareto utilities. The `*_with`
//! entry points ([`run_nas_with`], [`run_ea_with`]) add the serving
//! hooks — a per-generation [`SearchEvent`] callback and a cooperative
//! [`CancelToken`](crate::exec::CancelToken) — that the `search` wire op
//! streams over the frame API.

pub mod ea;
pub mod nas;
pub mod pareto;
pub mod predictor;

/// Progress callback payload for the `*_with` search runners (mirrors
/// `SweepEvent` in the sweep engine). `C` is the runner's candidate
/// type ([`NasCandidate`] or [`Candidate`]).
#[derive(Debug)]
pub enum SearchEvent<'a, C> {
    /// One generation finished: `done` of `total` iterations complete,
    /// with the current pareto front over everything evaluated so far
    /// (latency-sorted; the serving layer emits one row per point).
    Generation { done: usize, total: usize, front: &'a [C] },
}

pub use ea::{run_ea, run_ea_with, Candidate, EaConfig, EaResult};
pub use nas::{run_nas, run_nas_with, NasCandidate, NasConfig, NasResult};
pub use pareto::{pareto_front, pareto_ranks, Point};
pub use predictor::{paper_anchor, predict_ofa, AccuracyPredictor, TrainMethod};
