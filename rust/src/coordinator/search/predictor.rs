//! Accuracy model for search (DESIGN.md S7).
//!
//! The paper trains every candidate on ImageNet (8×V100, 350 epochs);
//! offline we substitute a *calibrated predictor* anchored to the paper's
//! own measurements (Table 3 baselines and in-place drops, §6.3 NOS
//! recovery rates of 37 % / 74 %), plus small-scale real training evidence
//! from the runtime (examples/train_e2e). The predictor only has to rank
//! candidates the way ImageNet training would — its anchors pin the
//! endpoints, and the per-block interpolation encodes the standard
//! capacity heuristic (accuracy sensitivity follows parameter share, with
//! a deterministic per-block perturbation so search has structure to
//! exploit).

use super::super::evaluator::HybridSpace;
use crate::nn::models::ofa::OfaGenome;

/// How the candidate is trained — in-place replacement or NOS scaffolding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMethod {
    InPlace,
    Nos,
}

/// Per-network anchors from the paper.
#[derive(Debug, Clone, Copy)]
pub struct Anchor {
    pub base_acc: f64,
    /// Accuracy delta of converting ALL blocks, in-place (Table 3).
    pub drop_half: f64,
    pub drop_full: f64,
    /// Fraction of the drop NOS recovers (§6.3: 37 % for MobileNetV3-L,
    /// 74 % for MnasNet-B1; others default to their mean).
    pub nos_recovery: f64,
}

/// Table 3 anchors.
pub fn paper_anchor(name: &str) -> Option<Anchor> {
    let a = |base: f64, half: f64, full: f64, rec: f64| Anchor {
        base_acc: base,
        drop_half: base - half,
        drop_full: base - full,
        nos_recovery: rec,
    };
    Some(match name {
        n if n.starts_with("MobileNet-V1") => a(70.60, 72.00, 72.86, 0.55),
        n if n.starts_with("MobileNet-V2") => a(72.00, 70.80, 72.49, 0.55),
        n if n.starts_with("MobileNet-V3-Small") => a(67.40, 64.55, 67.17, 0.55),
        n if n.starts_with("MobileNet-V3-Large") => a(75.20, 73.02, 74.40, 0.37),
        n if n.starts_with("MnasNet-B1") => a(73.50, 71.48, 73.16, 0.74),
        _ => return None,
    })
}

/// Deterministic per-block sensitivity jitter in [0.85, 1.15] — stands in
/// for the block-level idiosyncrasies real training exhibits (Fig 14: the
/// EA keeps a few specific depthwise blocks).
fn jitter(net: &str, block: usize) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in net.bytes().chain(block.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    0.85 + 0.30 * ((h >> 11) as f64 / (1u64 << 53) as f64)
}

/// Predictor over one base network's hybrid space.
#[derive(Debug, Clone)]
pub struct AccuracyPredictor {
    pub anchor: Anchor,
    /// Per-block share of the total in-place drop (sums to 1).
    pub block_weight: Vec<f64>,
    net_name: String,
}

impl AccuracyPredictor {
    pub fn for_space(space: &HybridSpace) -> AccuracyPredictor {
        let name = space.base.name.clone();
        let anchor = paper_anchor(&name)
            .unwrap_or(Anchor { base_acc: 75.0, drop_half: 2.1, drop_full: 0.3, nos_recovery: 0.55 });
        // Sensitivity follows depthwise parameter share with jitter.
        let raw: Vec<f64> = space
            .dw_params
            .iter()
            .enumerate()
            .map(|(i, &p)| (p.max(1) as f64).powf(0.8) * jitter(&name, i))
            .collect();
        let sum: f64 = raw.iter().sum();
        AccuracyPredictor {
            anchor,
            block_weight: raw.into_iter().map(|r| r / sum).collect(),
            net_name: name,
        }
    }

    pub fn net_name(&self) -> &str {
        &self.net_name
    }

    /// Accuracy of the hybrid selected by `mask` (true = FuSe-Half).
    pub fn predict_mask(&self, mask: &[bool], method: TrainMethod) -> f64 {
        assert_eq!(mask.len(), self.block_weight.len());
        let converted: f64 = mask
            .iter()
            .zip(&self.block_weight)
            .filter(|(&m, _)| m)
            .map(|(_, &w)| w)
            .sum();
        let drop = self.anchor.drop_half * converted;
        let recovered = match method {
            TrainMethod::InPlace => 0.0,
            TrainMethod::Nos => drop.max(0.0) * self.anchor.nos_recovery,
        };
        self.anchor.base_acc - drop + recovered
    }

    /// Accuracy with every block converted (the Table 3 "FuSe-Half" row).
    pub fn predict_all(&self, method: TrainMethod) -> f64 {
        self.predict_mask(&vec![true; self.block_weight.len()], method)
    }
}

/// Parametric accuracy model over the OFA design space (Fig 15 / Table 4).
/// Calibrated to: OFA best 77.1 % @ 369 M, FuSe-OFA-1 76.7 % @ 376 M,
/// FuSe-OFA-2 77.2 % @ 426 M (all NOS-trained).
pub fn predict_ofa(genome: &OfaGenome, macs_millions: f64, method: TrainMethod) -> f64 {
    let total_depth: usize = genome.depths.iter().sum();
    let mut ksum = 0.0;
    let mut fuse_blocks = 0.0;
    let mut blocks = 0.0;
    for s in 0..5 {
        for d in 0..genome.depths[s] {
            let g = genome.blocks[s][d];
            ksum += g.kernel as f64;
            fuse_blocks += if g.fuse { 1.0 } else { 0.0 };
            blocks += 1.0;
        }
    }
    let mean_k = ksum / blocks;
    let frac_fuse = fuse_blocks / blocks;

    // capacity + receptive field + depth (constants solved against the
    // three Table-4 anchors — see the calibration test below)
    let acc = 62.63 + 2.05 * macs_millions.ln() + 0.22 * mean_k + 0.045 * total_depth as f64;
    // operator penalty, largely recovered by NOS (OFA-style scaffolding)
    let drop = 1.9 * frac_fuse;
    let recovered = match method {
        TrainMethod::InPlace => 0.0,
        TrainMethod::Nos => 0.744 * drop,
    };
    acc - drop + recovered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::Evaluator;
    use crate::nn::models::{mnasnet, mobilenet_v3};
    use crate::sim::SimConfig;

    fn space(net: crate::nn::Network) -> HybridSpace {
        HybridSpace::new(&net, &Evaluator::new(SimConfig::default()))
    }

    #[test]
    fn endpoints_match_table3() {
        let sp = space(mobilenet_v3::large());
        let p = AccuracyPredictor::for_space(&sp);
        let n = sp.num_blocks();
        // no conversion = baseline
        assert!((p.predict_mask(&vec![false; n], TrainMethod::InPlace) - 75.20).abs() < 1e-9);
        // full conversion in-place = Table 3 FuSe-Half row
        assert!((p.predict_all(TrainMethod::InPlace) - 73.02).abs() < 1e-9);
    }

    #[test]
    fn nos_recovery_matches_section_6_3() {
        // MobileNetV3-Large: +0.8 (37 % of 2.18); MnasNet-B1: +1.5 (74 %).
        let sp = space(mobilenet_v3::large());
        let p = AccuracyPredictor::for_space(&sp);
        let gain = p.predict_all(TrainMethod::Nos) - p.predict_all(TrainMethod::InPlace);
        assert!((gain - 0.8).abs() < 0.05, "v3l gain {gain}");

        let sp = space(mnasnet::build());
        let p = AccuracyPredictor::for_space(&sp);
        let gain = p.predict_all(TrainMethod::Nos) - p.predict_all(TrainMethod::InPlace);
        assert!((gain - 1.5).abs() < 0.05, "mnas gain {gain}");
    }

    #[test]
    fn partial_conversion_interpolates_monotonically() {
        let sp = space(mobilenet_v3::large());
        let p = AccuracyPredictor::for_space(&sp);
        let n = sp.num_blocks();
        let mut mask = vec![false; n];
        let mut prev = p.predict_mask(&mask, TrainMethod::InPlace);
        for i in 0..n {
            mask[i] = true;
            let cur = p.predict_mask(&mask, TrainMethod::InPlace);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn block_weights_normalized_and_heterogeneous() {
        let sp = space(mobilenet_v3::large());
        let p = AccuracyPredictor::for_space(&sp);
        let sum: f64 = p.block_weight.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let min = p.block_weight.iter().cloned().fold(f64::MAX, f64::min);
        let max = p.block_weight.iter().cloned().fold(0.0, f64::max);
        // late big blocks dominate: search has room to convert cheap blocks
        assert!(max / min > 3.0, "weights too uniform {:?}", p.block_weight);
    }

    #[test]
    fn ofa_calibration_near_table4() {
        let ofa = OfaGenome::reference_ofa();
        let f1 = OfaGenome::reference_fuse_ofa_1();
        let f2 = OfaGenome::reference_fuse_ofa_2();
        let m = |g: &OfaGenome| g.realize("x").macs_millions();
        let a_ofa = predict_ofa(&ofa, m(&ofa), TrainMethod::Nos);
        let a_f1 = predict_ofa(&f1, m(&f1), TrainMethod::Nos);
        let a_f2 = predict_ofa(&f2, m(&f2), TrainMethod::Nos);
        assert!((a_ofa - 77.1).abs() < 0.6, "ofa {a_ofa}");
        assert!((a_f1 - 76.7).abs() < 0.6, "fuse-ofa-1 {a_f1}");
        assert!((a_f2 - 77.2).abs() < 0.6, "fuse-ofa-2 {a_f2}");
        // ordering as in Table 4
        assert!(a_f2 > a_f1);
    }

    #[test]
    fn nos_always_at_least_in_place_for_ofa() {
        use crate::rng::Rng;
        let mut rng = Rng::new(21);
        for _ in 0..30 {
            let g = OfaGenome::random(&mut rng, true);
            let m = g.realize("x").macs_millions();
            assert!(
                predict_ofa(&g, m, TrainMethod::Nos) + 1e-12
                    >= predict_ofa(&g, m, TrainMethod::InPlace)
            );
        }
    }
}
