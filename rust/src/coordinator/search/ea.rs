//! Evolutionary search over hybrid FuSe/depthwise networks (paper §4.2,
//! §6.4, Figs 13–14), following Real et al. [45] as the paper does:
//! population of genomes (bitmasks over bottleneck blocks), tournament-free
//! pareto-rank selection, mutation + crossover with a fixed parent ratio.

use super::super::evaluator::HybridSpace;
use super::pareto::{pareto_front, pareto_ranks, Point};
use super::predictor::{AccuracyPredictor, TrainMethod};
use super::SearchEvent;
use crate::exec::{CancelToken, Pool};
use crate::rng::Rng;
use std::sync::Arc;

/// Paper §5.3.2 hyperparameters.
#[derive(Debug, Clone)]
pub struct EaConfig {
    pub population: usize,
    pub iterations: usize,
    pub mutation_p: f64,
    /// Fraction of the next population taken from mutated parents
    /// (the rest comes from crossover). Paper: 0.25.
    pub parent_ratio: f64,
    pub seed: u64,
    /// Worker threads for population evaluation (0 = number of CPUs).
    /// Genome generation stays serial on the RNG, so results are identical
    /// for every thread count.
    pub threads: usize,
}

impl Default for EaConfig {
    fn default() -> EaConfig {
        EaConfig {
            population: 100,
            iterations: 100,
            mutation_p: 0.1,
            parent_ratio: 0.25,
            seed: 42,
            threads: 0,
        }
    }
}

/// One evaluated hybrid.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub mask: Vec<bool>,
    pub acc: f64,
    pub latency_ms: f64,
    pub macs: u64,
    pub params: u64,
}

/// EA outcome: final population + the pareto frontier over everything
/// evaluated during the whole run.
#[derive(Debug, Clone)]
pub struct EaResult {
    pub frontier: Vec<Candidate>,
    pub evaluated: usize,
    pub best_acc: Candidate,
    pub fastest: Candidate,
    /// Generations actually completed (== `iterations` unless cancelled).
    pub generations: usize,
    /// The run stopped early on a tripped [`CancelToken`].
    pub cancelled: bool,
}

fn evaluate(
    mask: Vec<bool>,
    space: &HybridSpace,
    pred: &AccuracyPredictor,
    method: TrainMethod,
) -> Candidate {
    let acc = pred.predict_mask(&mask, method);
    let latency_ms = space.latency_ms(&mask);
    let macs = space.macs(&mask);
    let params = space.params(&mask);
    Candidate { mask, acc, latency_ms, macs, params }
}

/// Evaluate a batch of genomes across the pool, preserving order (so the
/// run is deterministic regardless of worker count).
fn eval_batch(
    masks: Vec<Vec<bool>>,
    pool: &Pool,
    space: &Arc<HybridSpace>,
    pred: &Arc<AccuracyPredictor>,
    method: TrainMethod,
) -> Vec<Candidate> {
    let space = Arc::clone(space);
    let pred = Arc::clone(pred);
    pool.scope_map(masks, move |mask| evaluate(mask, &space, &pred, method))
}

/// Run the EA. Deterministic for a given seed (and any `threads` setting:
/// the RNG drives genome *generation* serially; only the per-genome
/// evaluation fans out across the pool).
pub fn run_ea(
    space: &HybridSpace,
    pred: &AccuracyPredictor,
    method: TrainMethod,
    cfg: &EaConfig,
) -> EaResult {
    run_ea_with(space, pred, method, cfg, &CancelToken::new(), |_| {})
}

/// Pareto front over everything evaluated so far (latency-sorted).
fn front_of(all: &[Candidate]) -> Vec<Candidate> {
    let pts: Vec<Point<usize>> = all
        .iter()
        .enumerate()
        .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
        .collect();
    pareto_front(&pts).into_iter().map(|p| all[p.tag].clone()).collect()
}

/// [`run_ea`] with the serving hooks (same contract as `run_nas_with`):
/// `on_event` fires after every completed generation with the running
/// pareto front; `cancel` is checked between generations, so a tripped
/// token stops the run within one generation and the partial frontier
/// comes back flagged `cancelled`. Determinism per seed is unchanged.
pub fn run_ea_with(
    space: &HybridSpace,
    pred: &AccuracyPredictor,
    method: TrainMethod,
    cfg: &EaConfig,
    cancel: &CancelToken,
    mut on_event: impl FnMut(SearchEvent<Candidate>),
) -> EaResult {
    let n = space.num_blocks();
    let mut rng = Rng::new(cfg.seed);
    let pool = Pool::new(cfg.threads);
    let space_arc = Arc::new(space.clone());
    let pred_arc = Arc::new(pred.clone());
    // Seed the population with the two known anchors (all-depthwise and
    // all-FuSe) plus random genomes — the paper's EA likewise starts from
    // the trained endpoint networks.
    let mut init: Vec<Vec<bool>> = vec![vec![false; n], vec![true; n]];
    init.extend(
        (2..cfg.population).map(|_| (0..n).map(|_| rng.chance(0.5)).collect::<Vec<bool>>()),
    );
    let mut pop = eval_batch(init, &pool, &space_arc, &pred_arc, method);
    let mut all: Vec<Candidate> = pop.clone();
    let mut generations = 0;
    let mut cancelled = false;

    for _ in 0..cfg.iterations {
        if cancel.is_cancelled() {
            cancelled = true;
            break;
        }
        // Pareto-rank the population; parents come from the best ranks.
        let pts: Vec<Point<usize>> = pop
            .iter()
            .enumerate()
            .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
            .collect();
        let ranks = pareto_ranks(&pts);
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by_key(|&i| ranks[i]);
        let elite = &order[..(pop.len() / 4).max(2)];

        let mut next: Vec<Candidate> = Vec::with_capacity(cfg.population);
        // keep the frontier (elitism)
        for &i in elite.iter().take(cfg.population / 10) {
            next.push(pop[i].clone());
        }
        // Generate child genomes serially (deterministic RNG order), then
        // submit the whole batch through the pool.
        let mut children: Vec<Vec<bool>> = Vec::with_capacity(cfg.population - next.len());
        while next.len() + children.len() < cfg.population {
            let child_mask: Vec<bool> = if rng.chance(cfg.parent_ratio) {
                // mutation of one elite parent
                let p = &pop[*rng.choose(elite)];
                p.mask.iter().map(|&b| if rng.chance(cfg.mutation_p) { !b } else { b }).collect()
            } else {
                // uniform crossover of two elite parents
                let a = &pop[*rng.choose(elite)];
                let b = &pop[*rng.choose(elite)];
                a.mask
                    .iter()
                    .zip(&b.mask)
                    .map(|(&x, &y)| if rng.chance(0.5) { x } else { y })
                    .collect()
            };
            children.push(child_mask);
        }
        next.extend(eval_batch(children, &pool, &space_arc, &pred_arc, method));
        all.extend(next.iter().cloned());
        pop = next;
        generations += 1;
        on_event(SearchEvent::Generation {
            done: generations,
            total: cfg.iterations,
            front: &front_of(&all),
        });
    }

    let frontier = front_of(&all);
    let best_acc = frontier
        .iter()
        .max_by(|a, b| a.acc.partial_cmp(&b.acc).unwrap())
        .expect("nonempty frontier")
        .clone();
    let fastest = frontier
        .iter()
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .unwrap()
        .clone();
    EaResult { frontier, evaluated: all.len(), best_acc, fastest, generations, cancelled }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::Evaluator;
    use crate::nn::models::mobilenet_v3;
    use crate::sim::SimConfig;

    fn small_run(seed: u64) -> (HybridSpace, EaResult) {
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::large(), &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let cfg = EaConfig { population: 24, iterations: 12, seed, ..EaConfig::default() };
        let r = run_ea(&space, &pred, TrainMethod::Nos, &cfg);
        (space, r)
    }

    #[test]
    fn deterministic_per_seed() {
        let (_, a) = small_run(7);
        let (_, b) = small_run(7);
        assert_eq!(a.frontier.len(), b.frontier.len());
        assert_eq!(a.best_acc.mask, b.best_acc.mask);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::large(), &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let run = |threads: usize| {
            let cfg = EaConfig {
                population: 16,
                iterations: 6,
                seed: 3,
                threads,
                ..EaConfig::default()
            };
            run_ea(&space, &pred, TrainMethod::Nos, &cfg)
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.mask, y.mask);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
    }

    #[test]
    fn frontier_is_nondominated_and_sorted() {
        let (_, r) = small_run(8);
        assert!(!r.frontier.is_empty());
        for w in r.frontier.windows(2) {
            assert!(w[0].latency_ms <= w[1].latency_ms);
            assert!(w[0].acc <= w[1].acc + 1e-12);
        }
    }

    #[test]
    fn frontier_beats_naive_manual_hybrid() {
        // Paper §6.4: EA hybrids dominate manually chosen 50% hybrids.
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::large(), &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let cfg = EaConfig { population: 48, iterations: 40, seed: 9, ..EaConfig::default() };
        let r = run_ea(&space, &pred, TrainMethod::Nos, &cfg);
        let n = space.num_blocks();
        // manual: convert the first half of the blocks
        let manual: Vec<bool> = (0..n).map(|i| i < n / 2).collect();
        let manual_acc = pred.predict_mask(&manual, TrainMethod::Nos);
        let manual_lat = space.latency_ms(&manual);
        // some frontier point dominates or essentially matches the manual
        // choice (ties broken at float tolerance)
        assert!(
            r.frontier
                .iter()
                .any(|c| c.acc >= manual_acc - 0.02 && c.latency_ms <= manual_lat + 1e-9),
            "EA failed to match manual hybrid (acc {manual_acc:.3} lat {manual_lat:.3}): frontier {:?}",
            r.frontier.iter().map(|c| (c.acc, c.latency_ms)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn endpoints_bracket_the_tradeoff() {
        let (space, r) = small_run(10);
        let n = space.num_blocks();
        // fastest frontier point should approach the all-FuSe latency
        let all_fuse_lat = space.latency_ms(&vec![true; n]);
        assert!(r.fastest.latency_ms <= all_fuse_lat * 1.3);
        // best-acc point should approach the baseline accuracy
        let pred = AccuracyPredictor::for_space(&space);
        let base_acc = pred.predict_mask(&vec![false; n], TrainMethod::Nos);
        assert!(r.best_acc.acc >= base_acc - 1.0);
    }

    #[test]
    fn evaluated_counts_grow_with_iterations() {
        let (_, r) = small_run(11);
        assert_eq!(r.evaluated, 24 + 12 * 24);
        assert_eq!(r.generations, 12);
        assert!(!r.cancelled);
    }

    #[test]
    fn cancel_and_events_mirror_the_nas_contract() {
        let ev = Evaluator::new(SimConfig::default());
        let space = HybridSpace::new(&mobilenet_v3::large(), &ev);
        let pred = AccuracyPredictor::for_space(&space);
        let cfg = EaConfig { population: 12, iterations: 50, seed: 4, ..EaConfig::default() };
        let token = CancelToken::new();
        let mut events = 0;
        let r = run_ea_with(&space, &pred, TrainMethod::Nos, &cfg, &token, |e| {
            let SearchEvent::Generation { done, total, front } = e;
            events += 1;
            assert_eq!(done, events);
            assert_eq!(total, 50);
            assert!(!front.is_empty());
            if done == 2 {
                token.cancel();
            }
        });
        assert!(r.cancelled);
        assert_eq!(r.generations, 2);
        assert!(!r.frontier.is_empty());
    }
}
