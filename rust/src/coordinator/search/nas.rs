//! NAS over the OFA design space with the FuSe operator choice (paper §6.5,
//! Fig 15): evolutionary sampling of `OfaGenome`s, latency from the
//! simulator, accuracy from the calibrated OFA predictor. Run twice — with
//! `allow_fuse` off (baseline OFA curve) and on (FuSe-OFA curve) — the
//! FuSe-enabled frontier should dominate, as in the paper.

use super::super::evaluator::Evaluator;
use super::pareto::{pareto_front, pareto_ranks, Point};
use super::predictor::{predict_ofa, TrainMethod};
use crate::exec::Pool;
use crate::nn::models::ofa::OfaGenome;
use crate::rng::Rng;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct NasConfig {
    pub population: usize,
    pub iterations: usize,
    pub mutation_p: f64,
    pub allow_fuse: bool,
    pub seed: u64,
    pub threads: usize,
}

impl Default for NasConfig {
    fn default() -> NasConfig {
        NasConfig {
            population: 32,
            iterations: 16,
            mutation_p: 0.15,
            allow_fuse: true,
            seed: 42,
            threads: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct NasCandidate {
    pub genome: OfaGenome,
    pub acc: f64,
    pub latency_ms: f64,
    pub macs_millions: f64,
    pub params_millions: f64,
}

#[derive(Debug, Clone)]
pub struct NasResult {
    pub frontier: Vec<NasCandidate>,
    pub evaluated: usize,
}

fn evaluate(genome: OfaGenome, ev: &Evaluator) -> NasCandidate {
    let net = genome.realize("nas");
    let e = ev.eval(&net);
    let macs_m = e.macs as f64 / 1e6;
    NasCandidate {
        acc: predict_ofa(&genome, macs_m, TrainMethod::Nos),
        latency_ms: e.latency_ms,
        macs_millions: macs_m,
        params_millions: e.params as f64 / 1e6,
        genome,
    }
}

/// Evolutionary NAS. Population evaluation is parallel (genome realization
/// + simulation dominate; the evaluator's sharded sweep-engine layer cache
/// is shared across all workers, so recurring block geometries across
/// genomes are priced once).
pub fn run_nas(ev: Arc<Evaluator>, cfg: &NasConfig) -> NasResult {
    let mut rng = Rng::new(cfg.seed);
    let pool = Pool::new(cfg.threads);

    let eval_batch = |genomes: Vec<OfaGenome>, pool: &Pool, ev: &Arc<Evaluator>| {
        let ev = Arc::clone(ev);
        pool.scope_map(genomes, move |g| evaluate(g, &ev))
    };

    let init: Vec<OfaGenome> =
        (0..cfg.population).map(|_| OfaGenome::random(&mut rng, cfg.allow_fuse)).collect();
    let mut pop = eval_batch(init, &pool, &ev);
    let mut all = pop.clone();

    for _ in 0..cfg.iterations {
        let pts: Vec<Point<usize>> = pop
            .iter()
            .enumerate()
            .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
            .collect();
        let ranks = pareto_ranks(&pts);
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by_key(|&i| ranks[i]);
        let elite: Vec<usize> = order[..(pop.len() / 4).max(2)].to_vec();

        let mut children: Vec<OfaGenome> = Vec::with_capacity(cfg.population);
        while children.len() < cfg.population {
            let child = if rng.chance(0.5) {
                pop[*rng.choose(&elite)].genome.mutate(&mut rng, cfg.mutation_p)
            } else {
                let a = &pop[*rng.choose(&elite)].genome;
                let b = &pop[*rng.choose(&elite)].genome;
                a.crossover(b, &mut rng)
            };
            children.push(child);
        }
        pop = eval_batch(children, &pool, &ev);
        all.extend(pop.iter().cloned());
    }

    let pts: Vec<Point<usize>> = all
        .iter()
        .enumerate()
        .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
        .collect();
    let frontier = pareto_front(&pts).into_iter().map(|p| all[p.tag].clone()).collect();
    NasResult { frontier, evaluated: all.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    fn tiny(allow_fuse: bool, seed: u64) -> NasResult {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg = NasConfig {
            population: 8,
            iterations: 4,
            allow_fuse,
            seed,
            threads: 2,
            ..NasConfig::default()
        };
        run_nas(ev, &cfg)
    }

    #[test]
    fn produces_nonempty_frontier() {
        let r = tiny(true, 5);
        assert!(!r.frontier.is_empty());
        assert_eq!(r.evaluated, 8 + 4 * 8);
    }

    #[test]
    fn fuse_frontier_dominates_baseline_in_latency() {
        // Fig 15's core claim: with FuSe in the space, the frontier reaches
        // much lower latency at comparable accuracy.
        let base = tiny(false, 6);
        let fuse = tiny(true, 6);
        let base_fastest =
            base.frontier.iter().map(|c| c.latency_ms).fold(f64::MAX, f64::min);
        let fuse_fastest =
            fuse.frontier.iter().map(|c| c.latency_ms).fold(f64::MAX, f64::min);
        assert!(
            fuse_fastest < base_fastest * 0.75,
            "fuse {fuse_fastest} vs base {base_fastest}"
        );
    }

    #[test]
    fn baseline_run_contains_no_fuse() {
        let r = tiny(false, 7);
        for c in &r.frontier {
            for s in 0..5 {
                for d in 0..c.genome.depths[s] {
                    assert!(!c.genome.blocks[s][d].fuse);
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = tiny(true, 9);
        let b = tiny(true, 9);
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert!((x.acc - y.acc).abs() < 1e-12);
            assert!((x.latency_ms - y.latency_ms).abs() < 1e-12);
        }
    }
}
