//! NAS over the OFA design space with the FuSe operator choice (paper §6.5,
//! Fig 15): evolutionary sampling of `OfaGenome`s, latency from the
//! simulator, accuracy from the calibrated OFA predictor. Run twice — with
//! `allow_fuse` off (baseline OFA curve) and on (FuSe-OFA curve) — the
//! FuSe-enabled frontier should dominate, as in the paper.

use super::super::evaluator::Evaluator;
use super::pareto::{pareto_front, pareto_ranks, Point};
use super::predictor::{predict_ofa, TrainMethod};
use super::SearchEvent;
use crate::exec::{CancelToken, Pool};
use crate::nn::models::ofa::OfaGenome;
use crate::rng::Rng;
use crate::sim::ResultCache;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct NasConfig {
    pub population: usize,
    pub iterations: usize,
    pub mutation_p: f64,
    pub allow_fuse: bool,
    pub seed: u64,
    pub threads: usize,
}

impl Default for NasConfig {
    fn default() -> NasConfig {
        NasConfig {
            population: 32,
            iterations: 16,
            mutation_p: 0.15,
            allow_fuse: true,
            seed: 42,
            threads: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct NasCandidate {
    pub genome: OfaGenome,
    pub acc: f64,
    pub latency_ms: f64,
    pub macs_millions: f64,
    pub params_millions: f64,
}

#[derive(Debug, Clone)]
pub struct NasResult {
    pub frontier: Vec<NasCandidate>,
    pub evaluated: usize,
    /// Generations actually completed (== `iterations` unless cancelled).
    pub generations: usize,
    /// The run stopped early on a tripped [`CancelToken`]; `frontier`
    /// covers everything evaluated before the stop.
    pub cancelled: bool,
}

fn evaluate(genome: OfaGenome, ev: &Evaluator, results: Option<&ResultCache>) -> NasCandidate {
    let net = genome.realize("nas");
    let (cycles, macs, params) = match results {
        // Route the whole-network simulation through the global result
        // cache: repeated genomes across generations (elites re-emitted
        // by mutation) and across concurrent searches simulate once.
        // Cycle counts are identical to the plain path — the cache runs
        // the same simulate_network_cached over the same layer cache —
        // so routing does not perturb determinism. No deadline: the
        // leader always completes, so `simulate` cannot return None.
        Some(rc) => {
            let sim = rc
                .simulate(&net, &ev.cfg, ev.cache(), None)
                .expect("deadline-free simulate always completes");
            (sim.total_cycles, net.total_macs(), net.total_params())
        }
        None => {
            let e = ev.eval(&net);
            (e.cycles, e.macs, e.params)
        }
    };
    let macs_m = macs as f64 / 1e6;
    NasCandidate {
        acc: predict_ofa(&genome, macs_m, TrainMethod::Nos),
        latency_ms: ev.cfg.cycles_to_ms(cycles),
        macs_millions: macs_m,
        params_millions: params as f64 / 1e6,
        genome,
    }
}

/// Pareto front over everything evaluated so far (latency-sorted, so the
/// emitted row order is deterministic).
fn front_of(all: &[NasCandidate]) -> Vec<NasCandidate> {
    let pts: Vec<Point<usize>> = all
        .iter()
        .enumerate()
        .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
        .collect();
    pareto_front(&pts).into_iter().map(|p| all[p.tag].clone()).collect()
}

/// Evolutionary NAS. Population evaluation is parallel (genome realization
/// + simulation dominate; the evaluator's sharded sweep-engine layer cache
/// is shared across all workers, so recurring block geometries across
/// genomes are priced once).
pub fn run_nas(ev: Arc<Evaluator>, cfg: &NasConfig) -> NasResult {
    run_nas_with(ev, cfg, None, &CancelToken::new(), |_| {})
}

/// [`run_nas`] with the serving hooks (mirrors `run_sweep_with`):
/// `on_event` fires after every completed generation with the current
/// pareto front over everything evaluated so far; `cancel` is checked
/// between generations, so a tripped token stops the run within one
/// generation (the partial frontier is still returned, flagged
/// `cancelled`); `results` optionally routes per-genome simulation
/// through the global [`ResultCache`]. Determinism is unchanged: genome
/// generation stays serial on the seeded RNG, evaluation order is
/// preserved by `scope_map`, so equal seeds give byte-equal frontiers
/// for any thread count, with or without the cache.
pub fn run_nas_with(
    ev: Arc<Evaluator>,
    cfg: &NasConfig,
    results: Option<&Arc<ResultCache>>,
    cancel: &CancelToken,
    mut on_event: impl FnMut(SearchEvent<NasCandidate>),
) -> NasResult {
    let mut rng = Rng::new(cfg.seed);
    let pool = Pool::new(cfg.threads);

    let eval_batch = |genomes: Vec<OfaGenome>, pool: &Pool, ev: &Arc<Evaluator>| {
        let ev = Arc::clone(ev);
        let rc = results.map(Arc::clone);
        pool.scope_map(genomes, move |g| evaluate(g, &ev, rc.as_deref()))
    };

    let init: Vec<OfaGenome> =
        (0..cfg.population).map(|_| OfaGenome::random(&mut rng, cfg.allow_fuse)).collect();
    let mut pop = eval_batch(init, &pool, &ev);
    let mut all = pop.clone();
    let mut generations = 0;
    let mut cancelled = false;

    for _ in 0..cfg.iterations {
        if cancel.is_cancelled() {
            cancelled = true;
            break;
        }
        let pts: Vec<Point<usize>> = pop
            .iter()
            .enumerate()
            .map(|(i, c)| Point { acc: c.acc, latency_ms: c.latency_ms, tag: i })
            .collect();
        let ranks = pareto_ranks(&pts);
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by_key(|&i| ranks[i]);
        let elite: Vec<usize> = order[..(pop.len() / 4).max(2)].to_vec();

        let mut children: Vec<OfaGenome> = Vec::with_capacity(cfg.population);
        while children.len() < cfg.population {
            let child = if rng.chance(0.5) {
                pop[*rng.choose(&elite)].genome.mutate(&mut rng, cfg.mutation_p)
            } else {
                let a = &pop[*rng.choose(&elite)].genome;
                let b = &pop[*rng.choose(&elite)].genome;
                a.crossover(b, &mut rng)
            };
            children.push(child);
        }
        pop = eval_batch(children, &pool, &ev);
        all.extend(pop.iter().cloned());
        generations += 1;
        on_event(SearchEvent::Generation {
            done: generations,
            total: cfg.iterations,
            front: &front_of(&all),
        });
    }

    NasResult { frontier: front_of(&all), evaluated: all.len(), generations, cancelled }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    fn tiny(allow_fuse: bool, seed: u64) -> NasResult {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg = NasConfig {
            population: 8,
            iterations: 4,
            allow_fuse,
            seed,
            threads: 2,
            ..NasConfig::default()
        };
        run_nas(ev, &cfg)
    }

    #[test]
    fn produces_nonempty_frontier() {
        let r = tiny(true, 5);
        assert!(!r.frontier.is_empty());
        assert_eq!(r.evaluated, 8 + 4 * 8);
    }

    #[test]
    fn fuse_frontier_dominates_baseline_in_latency() {
        // Fig 15's core claim: with FuSe in the space, the frontier reaches
        // much lower latency at comparable accuracy.
        let base = tiny(false, 6);
        let fuse = tiny(true, 6);
        let base_fastest =
            base.frontier.iter().map(|c| c.latency_ms).fold(f64::MAX, f64::min);
        let fuse_fastest =
            fuse.frontier.iter().map(|c| c.latency_ms).fold(f64::MAX, f64::min);
        assert!(
            fuse_fastest < base_fastest * 0.75,
            "fuse {fuse_fastest} vs base {base_fastest}"
        );
    }

    #[test]
    fn baseline_run_contains_no_fuse() {
        let r = tiny(false, 7);
        for c in &r.frontier {
            for s in 0..5 {
                for d in 0..c.genome.depths[s] {
                    assert!(!c.genome.blocks[s][d].fuse);
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = tiny(true, 9);
        let b = tiny(true, 9);
        assert!(!a.cancelled);
        assert_eq!(a.generations, 4);
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert!((x.acc - y.acc).abs() < 1e-12);
            assert!((x.latency_ms - y.latency_ms).abs() < 1e-12);
        }
    }

    #[test]
    fn events_fire_per_generation_with_the_running_front() {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg = NasConfig { population: 6, iterations: 3, threads: 2, ..NasConfig::default() };
        let mut seen: Vec<(usize, usize, usize)> = Vec::new();
        let r = run_nas_with(ev, &cfg, None, &CancelToken::new(), |e| {
            let SearchEvent::Generation { done, total, front } = e;
            assert!(!front.is_empty());
            seen.push((done, total, front.len()));
        });
        assert_eq!(seen.len(), 3);
        for (i, (done, total, _)) in seen.iter().enumerate() {
            assert_eq!(*done, i + 1);
            assert_eq!(*total, 3);
        }
        // the last event's front is the final frontier
        assert_eq!(seen.last().unwrap().2, r.frontier.len());
    }

    #[test]
    fn tripped_token_stops_within_one_generation() {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg =
            NasConfig { population: 6, iterations: 100, threads: 2, ..NasConfig::default() };
        let token = CancelToken::new();
        let mut events = 0;
        let r = run_nas_with(Arc::clone(&ev), &cfg, None, &token, |_| {
            events += 1;
            token.cancel(); // trip after the first generation's event
        });
        assert!(r.cancelled);
        assert_eq!(r.generations, 1);
        assert_eq!(events, 1);
        assert_eq!(r.evaluated, 6 + 6); // init + one generation, not 100
        assert!(!r.frontier.is_empty(), "partial frontier survives a cancel");
    }

    #[test]
    fn result_cache_routing_is_bit_identical_and_dedups() {
        let ev = Arc::new(Evaluator::new(SimConfig::default()));
        let cfg = NasConfig { population: 8, iterations: 3, threads: 2, ..NasConfig::default() };
        let plain = run_nas(Arc::clone(&ev), &cfg);
        let rc = Arc::new(ResultCache::new(256));
        let cached =
            run_nas_with(Arc::clone(&ev), &cfg, Some(&rc), &CancelToken::new(), |_| {});
        assert_eq!(plain.frontier.len(), cached.frontier.len());
        for (x, y) in plain.frontier.iter().zip(&cached.frontier) {
            assert_eq!(x.genome, y.genome);
            assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "acc must be bit-identical");
            assert_eq!(x.latency_ms.to_bits(), y.latency_ms.to_bits());
        }
        // repeated genomes across generations simulate once
        let stats = rc.stats();
        assert!(
            (stats.misses as usize) <= cached.evaluated,
            "misses {} > evaluated {}",
            stats.misses,
            cached.evaluated
        );
        // a second same-seed run through the same cache is all hits
        let before = rc.stats().misses;
        let again = run_nas_with(Arc::clone(&ev), &cfg, Some(&rc), &CancelToken::new(), |_| {});
        assert_eq!(again.frontier.len(), cached.frontier.len());
        assert_eq!(rc.stats().misses, before, "no new simulations on a repeat run");
    }
}
