//! Event-loop core for the epoll transports (`--transport epoll`):
//! readiness registration ([`Poller`]), a hashed timer wheel for
//! deadlines ([`TimerWheel`]), and the generic
//! accept/read/pump/flush loop ([`serve_event_loop`]) that both wire
//! frontends mount through a per-connection [`Driver`] state machine.
//!
//! Concurrency model: **one** OS thread runs the whole tier. Sockets
//! are nonblocking; epoll reports readiness level-triggered, with
//! `EPOLLOUT` interest armed only while a connection has unflushed
//! output (the classic on-demand write-interest pattern). The bounded
//! per-ticket buffers ([`STREAM_BOUND`](super::protocol)) map onto
//! write readiness: once a connection's pending output reaches
//! [`OUT_BOUND`] its driver stops draining tickets, the producers
//! park on their bounded channels, and everything resumes when the
//! socket drains — a stalled reader parks its *connection*, not a
//! thread. Deadlines (ticket waits, request-read timeouts, write
//! stalls) ride the timer wheel; expiry is advisory — the driver
//! rechecks its own clocks, so stale entries are harmless (lazy
//! cancellation).
//!
//! The epoll syscalls are declared directly (`std` already links
//! libc), keeping the tree zero-dependency. Linux-only: on other
//! platforms [`Poller::new`] reports `Unsupported` and the threaded
//! transport remains the default.

use super::net::{StopLatch, TransportGauges};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Per-connection pending-output bound, in bytes. A driver stops
/// pumping ticket frames once this much output is queued; the
/// connection resumes when the socket accepts the backlog.
pub(crate) const OUT_BOUND: usize = 256 * 1024;

/// Hard cap on buffered unparsed input per connection; past it the
/// connection is abusive and is dropped.
const INBUF_MAX: usize = 32 * 1024 * 1024;

/// Wait granularity while any connection has live reply streams: the
/// loop wakes at least this often to pump tickets.
const PUMP_INTERVAL: Duration = Duration::from_millis(1);

/// Idle wait bound: how long `epoll_wait` may sleep with no streams,
/// timers, or pending output (bounds stop-latch detection latency; a
/// latch trip also self-dials the listener, which wakes the loop
/// immediately).
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Write-stall bound, mirroring the threaded transport: a socket that
/// accepts zero bytes for this long while output is pending is
/// declared dead and closed.
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(600);

/// Read buffer size per `read` syscall.
const READ_CHUNK: usize = 64 * 1024;

/// `epoll_wait` event batch per wakeup.
const EVENT_BATCH: usize = 256;

// ---------------------------------------------------------------------------
// Raw epoll bindings (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    /// Mirror of `struct epoll_event`; packed on x86 where the kernel
    /// ABI packs it.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// One readiness report from [`Poller::wait`]. Error/hangup conditions
/// are folded into `readable`/`writable` — the next read or write
/// surfaces them as `io::Error`/EOF.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Readiness registration over one epoll instance.
#[cfg(target_os = "linux")]
pub(crate) struct Poller {
    epfd: std::os::raw::c_int,
}

/// Readiness registration stub for non-Linux hosts: every operation
/// reports `Unsupported`.
#[cfg(not(target_os = "linux"))]
pub(crate) struct Poller {}

#[cfg(not(target_os = "linux"))]
fn unsupported() -> io::Error {
    io::Error::new(io::ErrorKind::Unsupported, "the epoll transport requires linux")
}

#[cfg(target_os = "linux")]
impl Poller {
    pub(crate) fn new() -> io::Result<Poller> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn mask(readable: bool, writable: bool) -> u32 {
        let mut m = sys::EPOLLRDHUP;
        if readable {
            m |= sys::EPOLLIN;
        }
        if writable {
            m |= sys::EPOLLOUT;
        }
        m
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: i32, events: u32, token: u64) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest set.
    pub(crate) fn add(&self, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, Self::mask(readable, writable), token)
    }

    /// Change an existing registration's interest set.
    pub(crate) fn modify(
        &self,
        fd: i32,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, Self::mask(readable, writable), token)
    }

    /// Drop a registration (the fd may already be closing; errors are
    /// the caller's to ignore).
    pub(crate) fn remove(&self, fd: i32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block until readiness or `timeout` (`None` = forever), filling
    /// `out` with the batch.
    pub(crate) fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let ms: std::os::raw::c_int = match timeout {
            None => -1,
            Some(d) => {
                let ms = d.as_millis().min(i32::MAX as u128) as i32;
                if ms == 0 && !d.is_zero() {
                    1 // round a sub-millisecond wait up, not down to a spin
                } else {
                    ms
                }
            }
        };
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; EVENT_BATCH];
        loop {
            let n = unsafe {
                sys::epoll_wait(self.epfd, buf.as_mut_ptr(), EVENT_BATCH as i32, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // copy out of the (possibly packed) struct before use
                let events = ev.events;
                let data = ev.data;
                let fail = events & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
                out.push(PollEvent {
                    token: data,
                    readable: events & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 || fail,
                    writable: events & sys::EPOLLOUT != 0 || fail,
                });
            }
            return Ok(());
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.epfd);
        }
    }
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub(crate) fn new() -> io::Result<Poller> {
        Err(unsupported())
    }

    pub(crate) fn add(&self, _fd: i32, _t: u64, _r: bool, _w: bool) -> io::Result<()> {
        Err(unsupported())
    }

    pub(crate) fn modify(&self, _fd: i32, _t: u64, _r: bool, _w: bool) -> io::Result<()> {
        Err(unsupported())
    }

    pub(crate) fn remove(&self, _fd: i32) -> io::Result<()> {
        Err(unsupported())
    }

    pub(crate) fn wait(&self, _out: &mut Vec<PollEvent>, _t: Option<Duration>) -> io::Result<()> {
        Err(unsupported())
    }
}

// ---------------------------------------------------------------------------
// Timer wheel
// ---------------------------------------------------------------------------

const WHEEL_SLOTS: usize = 256;
const WHEEL_GRANULARITY: Duration = Duration::from_millis(25);

/// Hashed timer wheel: `schedule` hashes a deadline into one of
/// [`WHEEL_SLOTS`] buckets of [`WHEEL_GRANULARITY`]; deadlines beyond
/// the wheel's span land in the far bucket and cascade (re-hash) each
/// revolution. Cancellation is lazy — expiry only *wakes* the owner,
/// which rechecks its real deadline state, so stale entries cost one
/// spurious wakeup instead of bookkeeping.
pub(crate) struct TimerWheel {
    slots: Vec<Vec<(u64, Instant)>>,
    /// Bucket whose window starts at `base`.
    hand: usize,
    base: Instant,
    live: usize,
    earliest: Option<Instant>,
}

impl Default for TimerWheel {
    fn default() -> TimerWheel {
        TimerWheel::new()
    }
}

impl TimerWheel {
    pub(crate) fn new() -> TimerWheel {
        TimerWheel {
            slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            hand: 0,
            base: Instant::now(),
            live: 0,
            earliest: None,
        }
    }

    /// Arm a wakeup for `token` at `deadline` (already-past deadlines
    /// fire on the next `expire`).
    pub(crate) fn schedule(&mut self, token: u64, deadline: Instant) {
        let ticks = deadline.saturating_duration_since(self.base).as_nanos()
            / WHEEL_GRANULARITY.as_nanos();
        let offset = (ticks as usize).min(WHEEL_SLOTS - 1);
        self.slots[(self.hand + offset) % WHEEL_SLOTS].push((token, deadline));
        self.live += 1;
        if self.earliest.is_none_or(|e| deadline < e) {
            self.earliest = Some(deadline);
        }
    }

    /// Time until the nearest armed deadline (zero if already due);
    /// `None` when the wheel is empty.
    pub(crate) fn next_timeout(&self, now: Instant) -> Option<Duration> {
        self.earliest.map(|e| e.saturating_duration_since(now))
    }

    /// Advance the hand to `now`, appending every due token to `due`.
    /// Not-yet-due entries passed over (or sharing the hand bucket)
    /// are re-hashed — this is the cascade.
    pub(crate) fn expire(&mut self, now: Instant, due: &mut Vec<u64>) {
        due.clear();
        if self.live == 0 {
            self.base = now; // fast-forward an idle wheel
            return;
        }
        while self.base + WHEEL_GRANULARITY <= now {
            let drained = std::mem::take(&mut self.slots[self.hand]);
            self.hand = (self.hand + 1) % WHEEL_SLOTS;
            self.base += WHEEL_GRANULARITY;
            for (token, deadline) in drained {
                self.live -= 1;
                if deadline <= now {
                    due.push(token);
                } else {
                    self.schedule(token, deadline);
                }
            }
        }
        // the hand bucket's own window may hold already-due entries
        let bucket = std::mem::take(&mut self.slots[self.hand]);
        let mut keep = Vec::with_capacity(bucket.len());
        for (token, deadline) in bucket {
            if deadline <= now {
                self.live -= 1;
                due.push(token);
            } else {
                keep.push((token, deadline));
            }
        }
        self.slots[self.hand] = keep;
        if !due.is_empty() || self.earliest.is_some_and(|e| e <= now) {
            self.earliest = self.slots.iter().flatten().map(|&(_, d)| d).min();
        }
    }
}

// ---------------------------------------------------------------------------
// Driver interface
// ---------------------------------------------------------------------------

/// Mutable per-connection surfaces a [`Driver`] works against. The
/// flags are *requests to the loop*: `close_after_flush` closes the
/// connection once output drains and no streams remain;
/// `trip_after_flush` additionally trips the stop latch at that point
/// (the shutdown ack path); `wake_at` asks for a timer wakeup.
pub(crate) struct ConnCx<'a> {
    /// Unparsed input bytes (consume what's complete).
    pub inbuf: &'a mut Vec<u8>,
    /// Pending output bytes (append encoded frames/responses).
    pub out: &'a mut Vec<u8>,
    pub close_after_flush: &'a mut bool,
    pub trip_after_flush: &'a mut bool,
    /// Earliest instant the driver needs a wakeup at (deadline
    /// checks); cleared by the loop before every driver call.
    pub wake_at: &'a mut Option<Instant>,
}

/// Per-connection protocol state machine mounted on the event loop:
/// the frame transport and the HTTP transport each implement one.
pub(crate) trait Driver {
    /// New bytes landed in `cx.inbuf` — consume complete units.
    fn on_data(&mut self, cx: &mut ConnCx<'_>, now: Instant);
    /// Peer closed its write side; buffered input may still be
    /// pending, and replies may still be flushing.
    fn on_eof(&mut self, cx: &mut ConnCx<'_>);
    /// Poll in-flight tickets and deadline state; called on every
    /// loop pass while [`Driver::is_streaming`], and on timer expiry.
    fn pump(&mut self, cx: &mut ConnCx<'_>, now: Instant);
    /// Live reply streams in flight?
    fn is_streaming(&self) -> bool;
}

// ---------------------------------------------------------------------------
// The loop
// ---------------------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    driver: Box<dyn Driver>,
    inbuf: Vec<u8>,
    out: Vec<u8>,
    /// Prefix of `out` already written to the socket.
    sent: usize,
    close_after_flush: bool,
    trip_after_flush: bool,
    wake_at: Option<Instant>,
    /// Last deadline actually handed to the wheel (dedup).
    armed_timer: Option<Instant>,
    /// EOF observed on the read side (read interest disarmed).
    read_eof: bool,
    /// EPOLLOUT currently armed.
    want_write: bool,
    last_write_progress: Instant,
    _gauge: super::net::GaugeGuard,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.sent == self.out.len()
    }
}

/// Accept-and-serve on a single thread until the stop latch trips and
/// every connection drains. `make_driver` builds one [`Driver`] per
/// accepted connection.
#[cfg(target_os = "linux")]
pub(crate) fn serve_event_loop(
    listener: TcpListener,
    stop: StopLatch,
    gauges: TransportGauges,
    mut make_driver: impl FnMut() -> Box<dyn Driver>,
) -> io::Result<()> {
    use std::os::fd::AsRawFd;

    const LISTENER_TOKEN: u64 = 0;
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    poller.add(listener.as_raw_fd(), LISTENER_TOKEN, true, false)?;
    let _thread_gauge = gauges.thread_started();

    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut live = 0usize;
    let mut free: Vec<usize> = Vec::new();
    let mut events: Vec<PollEvent> = Vec::with_capacity(EVENT_BATCH);
    let mut due: Vec<u64> = Vec::new();
    let mut wheel = TimerWheel::new();
    let mut scratch = vec![0u8; READ_CHUNK];

    loop {
        let draining = stop.stopped();
        if draining && live == 0 {
            return Ok(());
        }
        let now = Instant::now();
        let mut timeout = IDLE_POLL;
        if conns.iter().flatten().any(|c| c.driver.is_streaming()) {
            timeout = PUMP_INTERVAL;
        } else if let Some(d) = wheel.next_timeout(now) {
            timeout = timeout.min(d);
        }
        poller.wait(&mut events, Some(timeout))?;
        let now = Instant::now();
        let draining = stop.stopped();

        // --- socket readiness ---
        for &ev in &events {
            if ev.token == LISTENER_TOKEN {
                accept_burst(
                    &listener, &poller, &gauges, &mut conns, &mut free, &mut live, draining,
                    &mut make_driver, now,
                );
                continue;
            }
            let idx = (ev.token - 1) as usize;
            if !conns.get(idx).is_some_and(|c| c.is_some()) {
                continue; // already closed this pass
            }
            if ev.readable {
                let gone = {
                    let conn = conns[idx].as_mut().expect("live conn");
                    !read_burst(conn, &mut scratch, now)
                };
                if gone {
                    close_conn(&poller, &mut conns, &mut free, &mut live, idx);
                    continue;
                }
            }
            service_conn(&poller, &stop, &mut wheel, &mut conns, &mut free, &mut live, idx, now);
        }

        // --- timer expiry (advisory wakeups; drivers recheck) ---
        wheel.expire(now, &mut due);
        for &token in &due {
            if token == LISTENER_TOKEN {
                continue;
            }
            let idx = (token - 1) as usize;
            if let Some(c) = conns.get_mut(idx).and_then(Option::as_mut) {
                c.armed_timer = None;
            } else {
                continue;
            }
            service_conn(&poller, &stop, &mut wheel, &mut conns, &mut free, &mut live, idx, now);
        }

        // --- pump every streaming connection; close drained ones ---
        for idx in 0..conns.len() {
            let needs_visit = match &conns[idx] {
                Some(c) => {
                    c.driver.is_streaming()
                        || (c.flushed() && c.close_after_flush)
                        || (draining && c.flushed())
                }
                None => false,
            };
            if needs_visit {
                service_conn(
                    &poller, &stop, &mut wheel, &mut conns, &mut free, &mut live, idx, now,
                );
            }
        }
    }
}

/// Non-Linux stub: the epoll transport is unavailable.
#[cfg(not(target_os = "linux"))]
pub(crate) fn serve_event_loop(
    _listener: TcpListener,
    _stop: StopLatch,
    _gauges: TransportGauges,
    _make_driver: impl FnMut() -> Box<dyn Driver>,
) -> io::Result<()> {
    Err(unsupported())
}

#[cfg(target_os = "linux")]
#[allow(clippy::too_many_arguments)]
fn accept_burst(
    listener: &TcpListener,
    poller: &Poller,
    gauges: &TransportGauges,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    live: &mut usize,
    draining: bool,
    make_driver: &mut impl FnMut() -> Box<dyn Driver>,
    now: Instant,
) {
    use std::os::fd::AsRawFd;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                // post-shutdown accepts (including the latch's wakeup
                // self-dial) are closed on the floor
                if draining {
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let idx = free.pop().unwrap_or_else(|| {
                    conns.push(None);
                    conns.len() - 1
                });
                let token = idx as u64 + 1;
                if poller.add(stream.as_raw_fd(), token, true, false).is_err() {
                    free.push(idx);
                    continue;
                }
                conns[idx] = Some(Conn {
                    stream,
                    driver: make_driver(),
                    inbuf: Vec::new(),
                    out: Vec::new(),
                    sent: 0,
                    close_after_flush: false,
                    trip_after_flush: false,
                    wake_at: None,
                    armed_timer: None,
                    read_eof: false,
                    want_write: false,
                    last_write_progress: now,
                    _gauge: gauges.conn_opened(),
                });
                *live += 1;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Drain the socket's readable bytes into `inbuf`. Returns `false`
/// when the connection is dead (hard error or input-flood cap).
#[cfg(target_os = "linux")]
fn read_burst(conn: &mut Conn, scratch: &mut [u8], _now: Instant) -> bool {
    if conn.read_eof {
        return true;
    }
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.read_eof = true;
                return true;
            }
            Ok(n) => {
                conn.inbuf.extend_from_slice(&scratch[..n]);
                if conn.inbuf.len() > INBUF_MAX {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Run one connection through its driver, flush, and apply the close /
/// trip / timer flags. The single place connection state advances.
#[cfg(target_os = "linux")]
#[allow(clippy::too_many_arguments)]
fn service_conn(
    poller: &Poller,
    stop: &StopLatch,
    wheel: &mut TimerWheel,
    conns: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    live: &mut usize,
    idx: usize,
    now: Instant,
) {
    let Some(conn) = conns[idx].as_mut() else { return };
    let token = idx as u64 + 1;

    // drive the protocol state machine
    {
        let Conn { driver, inbuf, out, close_after_flush, trip_after_flush, wake_at, read_eof, .. } =
            conn;
        *wake_at = None;
        let mut cx = ConnCx { inbuf, out, close_after_flush, trip_after_flush, wake_at };
        driver.on_data(&mut cx, now);
        if *read_eof {
            driver.on_eof(&mut cx);
        }
        driver.pump(&mut cx, now);
    }

    // flush pending output opportunistically (don't wait for EPOLLOUT)
    let dead = !flush_burst(conn, now);
    let stalled = !conn.flushed()
        && now.duration_since(conn.last_write_progress) > WRITE_STALL_TIMEOUT;
    if dead || stalled {
        close_conn(poller, conns, free, live, idx);
        return;
    }

    let conn = conns[idx].as_mut().expect("live conn");
    if conn.flushed() && !conn.driver.is_streaming() {
        if conn.trip_after_flush {
            conn.trip_after_flush = false;
            stop.trip();
        }
        if conn.close_after_flush || stop.stopped() || (conn.read_eof && conn.inbuf.is_empty()) {
            close_conn(poller, conns, free, live, idx);
            return;
        }
    }

    // (re)arm interest and timers
    let want_write = !conn.flushed();
    let want_read = !conn.read_eof;
    if want_write != conn.want_write {
        use std::os::fd::AsRawFd;
        conn.want_write = want_write;
        let _ = poller.modify(conn.stream.as_raw_fd(), token, want_read, want_write);
        if want_write {
            // write-stall watchdog for non-streaming conns that
            // nothing else would revisit
            wheel.schedule(token, now + WRITE_STALL_TIMEOUT);
        }
    }
    if let Some(at) = conn.wake_at {
        if conn.armed_timer != Some(at) {
            conn.armed_timer = Some(at);
            wheel.schedule(token, at);
        }
    }
}

/// Write as much pending output as the socket accepts. Returns `false`
/// when the connection is dead.
#[cfg(target_os = "linux")]
fn flush_burst(conn: &mut Conn, now: Instant) -> bool {
    while conn.sent < conn.out.len() {
        match conn.stream.write(&conn.out[conn.sent..]) {
            Ok(0) => return false,
            Ok(n) => {
                conn.sent += n;
                conn.last_write_progress = now;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.sent == conn.out.len() {
        conn.out.clear();
        conn.sent = 0;
        conn.last_write_progress = now;
    } else if conn.sent > 0 {
        conn.out.drain(..conn.sent);
        conn.sent = 0;
    }
    true
}

#[cfg(target_os = "linux")]
fn close_conn(
    poller: &Poller,
    conns: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    live: &mut usize,
    idx: usize,
) {
    use std::os::fd::AsRawFd;
    if let Some(conn) = conns[idx].take() {
        let _ = poller.remove(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        free.push(idx);
        *live -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wheel_fires_due_and_keeps_pending() {
        let mut w = TimerWheel::new();
        let now = Instant::now();
        w.schedule(1, now); // already due
        w.schedule(2, now + Duration::from_secs(60)); // far future (cascades)
        let mut due = Vec::new();
        w.expire(now + Duration::from_millis(1), &mut due);
        assert_eq!(due, vec![1]);
        assert!(w.next_timeout(now).is_some());
        // the far deadline survives many revolutions of the wheel
        w.expire(now + Duration::from_secs(30), &mut due);
        assert!(due.is_empty());
        w.expire(now + Duration::from_secs(61), &mut due);
        assert_eq!(due, vec![2]);
        assert!(w.next_timeout(now).is_none());
    }

    #[test]
    fn wheel_empty_fast_forwards() {
        let mut w = TimerWheel::new();
        let mut due = Vec::new();
        w.expire(Instant::now() + Duration::from_secs(3600), &mut due);
        assert!(due.is_empty());
        assert!(w.next_timeout(Instant::now()).is_none());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn poller_reports_listener_readable() {
        use std::os::fd::AsRawFd;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let poller = Poller::new().unwrap();
        poller.add(listener.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "nothing connected yet");
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
    }
}
