//! Dynamic request batcher for the inference-serving driver (DESIGN.md
//! S11). Requests accumulate until either the batch is full or the oldest
//! request has waited `max_wait`; the resulting batch goes to the engine.
//! This is the standard edge-serving policy: batch-1 latency when idle,
//! larger batches under load.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued request with its arrival time.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub arrived: Instant,
}

/// Batch-forming policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// FIFO queue with batch extraction.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher { queue: VecDeque::new(), policy }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push_back(Pending { item, arrived: Instant::now() });
    }

    pub fn push_at(&mut self, item: T, arrived: Instant) {
        self.queue.push_back(Pending { item, arrived });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be dispatched *now*?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.policy.max_batch
            || now.duration_since(self.queue.front().unwrap().arrived) >= self.policy.max_wait
    }

    /// Extract up to `max_batch` oldest requests.
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }

    /// Time until the oldest request hits its deadline (None if empty).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            let waited = now.duration_since(p.arrived);
            self.policy.max_wait.saturating_sub(waited)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(ms) }
    }

    #[test]
    fn dispatches_when_full() {
        let mut b = Batcher::new(policy(4, 1000));
        let now = Instant::now();
        for i in 0..4 {
            b.push_at(i, now);
        }
        assert!(b.ready(now));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn not_ready_below_batch_before_deadline() {
        let mut b = Batcher::new(policy(4, 1000));
        let now = Instant::now();
        b.push_at(1, now);
        assert!(!b.ready(now));
    }

    #[test]
    fn deadline_triggers_partial_batch() {
        let mut b = Batcher::new(policy(4, 10));
        let t0 = Instant::now();
        b.push_at(1, t0);
        let later = t0 + Duration::from_millis(11);
        assert!(b.ready(later));
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn take_batch_caps_at_max() {
        let mut b = Batcher::new(policy(3, 0));
        let now = Instant::now();
        for i in 0..7 {
            b.push_at(i, now);
        }
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(policy(8, 0));
        let now = Instant::now();
        for i in 0..5 {
            b.push_at(i, now);
        }
        let items: Vec<i32> = b.take_batch().into_iter().map(|p| p.item).collect();
        assert_eq!(items, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_at_out_of_order_arrivals_stay_fifo() {
        // The queue is FIFO by *insertion*, not by arrival stamp: a late
        // insert with an early arrival time must not jump the line, and
        // readiness keys off the front entry's stamp.
        let mut b = Batcher::new(policy(8, 10));
        let t0 = Instant::now();
        b.push_at(0, t0 + Duration::from_millis(5)); // inserted first, arrived later
        b.push_at(1, t0); // inserted second, arrived earlier
        b.push_at(2, t0 + Duration::from_millis(2));
        // deadline follows the front entry (arrival t0+5ms), not the
        // globally oldest stamp
        let d = b.next_deadline(t0 + Duration::from_millis(5)).unwrap();
        assert_eq!(d, Duration::from_millis(10));
        assert!(!b.ready(t0 + Duration::from_millis(12)));
        assert!(b.ready(t0 + Duration::from_millis(15)));
        let items: Vec<i32> = b.take_batch().into_iter().map(|p| p.item).collect();
        assert_eq!(items, vec![0, 1, 2], "insertion order preserved");
    }

    #[test]
    fn zero_max_wait_is_batch_one_latency() {
        // max_wait == 0: a single queued request is due immediately —
        // the dispatcher must not stall waiting to accumulate a batch.
        let mut b = Batcher::new(policy(64, 0));
        let t0 = Instant::now();
        assert!(b.next_deadline(t0).is_none(), "empty queue has no deadline");
        b.push_at(7, t0);
        assert_eq!(b.next_deadline(t0).unwrap(), Duration::ZERO);
        assert!(b.ready(t0));
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = Batcher::new(policy(8, 10));
        let t0 = Instant::now();
        b.push_at(0, t0);
        let d = b.next_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
        assert!(b.next_deadline(t0 + Duration::from_millis(20)).unwrap() == Duration::ZERO);
    }
}
