//! `fuseconv bench` — an open-loop load generator for a running
//! `fuseconv serve` / `fuseconv shard` frame endpoint, and the producer
//! of the repo's perf-trajectory points (`BENCH_<n>.json`).
//!
//! Open loop means the send schedule is fixed by the target rate, not
//! by completions: requests go out every `1/rps` seconds across a pool
//! of persistent connections whether or not earlier replies have come
//! back, so a slow server shows up as rising latency and falling
//! achieved RPS instead of a politely self-throttling client
//! (closed-loop generators hide exactly the overload the benchmark
//! exists to measure). The client itself is a single thread over the
//! same epoll [`Poller`](crate::coordinator::reactor) the serving tier
//! uses — it comfortably drives more connections than the
//! thread-per-connection transport could host.
//!
//! The run has three phases: a ramped **warmup** (rate climbs linearly
//! to the target; samples discarded), the **measured window** (every
//! completion's latency recorded), and a **drain** (no new sends;
//! in-flight requests get a bounded grace to finish). The report —
//! written as single-line JSON, schema checked by `ci/check_bench.py` —
//! records achieved RPS, p50/p95/p99/p999 latency, error counts split
//! into *app* errors (typed protocol errors: `busy`, `deadline`, …)
//! and *transport* errors (dead sockets, undecodable frames — always a
//! bug somewhere), peak in-flight depth, and a post-run server stats
//! snapshot whose gauges document the `O(threads) ≪ O(connections)`
//! claim while the full connection pool is still open.

use crate::cli::Cli;
use crate::coordinator::protocol::{
    ConfigPatch, ModelSpec, Reply, Request, RequestBody, ServeError,
};
use crate::coordinator::reactor::{PollEvent, Poller};
use crate::coordinator::wire::{decode_frame, encode_request, Json};
use crate::coordinator::{request_once, Frame};
use crate::sim::FuseVariant;
use crate::stats::percentile_sorted;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// How long the drain phase waits for still-in-flight replies after the
/// measured window closes.
const DRAIN_GRACE: Duration = Duration::from_secs(10);

/// Socket-level timeout for the post-run stats snapshot.
const SNAPSHOT_TIMEOUT: Duration = Duration::from_secs(5);

/// Floor on the instantaneous send rate during ramp (requests/second).
const MIN_RATE: f64 = 1.0;

/// The operations the generator can mix, with the request each renders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpKind {
    Simulate,
    Infer,
    Sweep,
}

impl OpKind {
    fn parse(s: &str) -> Option<OpKind> {
        match s {
            "simulate" => Some(OpKind::Simulate),
            "infer" => Some(OpKind::Infer),
            "sweep" => Some(OpKind::Sweep),
            _ => None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            OpKind::Simulate => "simulate",
            OpKind::Infer => "infer",
            OpKind::Sweep => "sweep",
        }
    }

    /// The request this op sends. Payloads are deliberately small and
    /// repetitive (two simulate configs, one-cell sweep grids) so the
    /// server's layer cache converges and the benchmark measures the
    /// serving tier, not simulator throughput.
    fn request(self, id: u64) -> Request {
        let body = match self {
            OpKind::Simulate => RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Half,
                config: ConfigPatch::sized(if id % 2 == 0 { 8 } else { 16 }),
            },
            OpKind::Infer => RequestBody::Infer { input: vec![0.5, -0.5, 0.25, -0.25] },
            OpKind::Sweep => RequestBody::Sweep {
                models: vec!["mobilenet-v2".into()],
                variants: vec![FuseVariant::Base],
                configs: vec![ConfigPatch::sized(8)],
            },
        };
        Request::new(id, body)
    }
}

/// Smooth weighted round-robin over the op mix: deterministic (no RNG —
/// runs are reproducible) and evenly interleaved, unlike drawing from
/// a shuffled block.
struct MixPicker {
    ops: Vec<(OpKind, f64)>,
    credit: Vec<f64>,
    total: f64,
}

impl MixPicker {
    /// Parse `"simulate=80,infer=10,sweep=10"`. Zero-weight entries are
    /// dropped; at least one positive weight is required.
    fn parse(spec: &str) -> Result<MixPicker, String> {
        let mut ops: Vec<(OpKind, f64)> = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((name, weight)) = part.split_once('=') else {
                return Err(format!("bad mix entry {part:?} (want op=weight)"));
            };
            let op = OpKind::parse(name.trim())
                .ok_or_else(|| format!("unknown mix op {name:?} (want simulate|infer|sweep)"))?;
            let w: f64 = weight
                .trim()
                .parse()
                .map_err(|_| format!("bad mix weight {weight:?}"))?;
            if w < 0.0 {
                return Err(format!("negative mix weight {weight:?}"));
            }
            if ops.iter().any(|(o, _)| *o == op) {
                return Err(format!("duplicate mix op {name:?}"));
            }
            if w > 0.0 {
                ops.push((op, w));
            }
        }
        if ops.is_empty() {
            return Err("op mix needs at least one positive weight".into());
        }
        let total = ops.iter().map(|(_, w)| w).sum();
        let credit = vec![0.0; ops.len()];
        Ok(MixPicker { ops, credit, total })
    }

    fn next(&mut self) -> OpKind {
        let mut best = 0;
        for (i, (_, w)) in self.ops.iter().enumerate() {
            self.credit[i] += w;
            if self.credit[i] > self.credit[best] {
                best = i;
            }
        }
        self.credit[best] -= self.total;
        self.ops[best].0
    }
}

/// One persistent bench connection.
struct BenchConn {
    stream: TcpStream,
    /// Bytes queued but not yet accepted by the socket.
    out: Vec<u8>,
    /// Raw bytes read but not yet framed into reply lines.
    inbuf: Vec<u8>,
    /// EPOLLOUT currently armed.
    want_write: bool,
    dead: bool,
}

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::fd::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    -1
}

/// Everything `run_bench` needs, parsed off the CLI.
struct BenchOpts {
    connect: String,
    rps: f64,
    connections: usize,
    duration: Duration,
    warmup: Duration,
    mix: MixPicker,
    transport_label: String,
}

/// The finished report, ready to render.
struct BenchReport {
    json: Json,
    achieved_rps: f64,
    p50: f64,
    p99: f64,
    transport_errors: u64,
}

/// One in-flight request: send time, whether it falls in the measured
/// window, and the owning connection (so a dying socket can fail its
/// own requests and nothing else's).
struct InFlight {
    at: Instant,
    measured: bool,
    conn: usize,
}

pub fn cmd_bench(argv: &[String]) -> i32 {
    let cli = Cli::new("bench", "open-loop load generator against a frame-protocol endpoint")
        .opt("connect", "target address of a running serve/shard", Some("127.0.0.1:7878"))
        .opt("rps", "target requests/second across all connections", Some("500"))
        .opt("connections", "persistent connections to spread load over", Some("512"))
        .opt("duration-secs", "measured window (after warmup)", Some("15"))
        .opt("warmup-secs", "linear ramp to target rate, excluded from stats", Some("3"))
        .opt("mix", "op mix weights", Some("simulate=80,infer=10,sweep=10"))
        .opt("transport", "server transport label recorded in the report", Some("epoll"))
        .opt("out", "write the JSON report here", Some("BENCH_7.json"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let (rps, connections, duration_s, warmup_s) = match (
        args.u64("rps"),
        args.usize("connections"),
        args.u64("duration-secs"),
        args.u64("warmup-secs"),
    ) {
        (Ok(r), Ok(c), Ok(d), Ok(w)) if r > 0 && c > 0 && d > 0 => (r, c, d, w),
        _ => {
            eprintln!("bad or zero numeric option\n{}", cli.usage());
            return 2;
        }
    };
    let mix = match MixPicker::parse(&args.str("mix")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let opts = BenchOpts {
        connect: args.str("connect"),
        rps: rps as f64,
        connections,
        duration: Duration::from_secs(duration_s),
        warmup: Duration::from_secs(warmup_s),
        mix,
        transport_label: args.str("transport"),
    };
    let out_path = args.str("out");
    match run_bench(opts) {
        Ok(report) => {
            let mut text = String::new();
            report.json.write(&mut text);
            text.push('\n');
            if let Err(e) = std::fs::write(&out_path, &text) {
                eprintln!("writing {out_path}: {e}");
                return 1;
            }
            eprintln!(
                "fuseconv bench: {:.1} req/s achieved, p50 {:.2} ms, p99 {:.2} ms, \
                 {} transport error(s) — report in {out_path}",
                report.achieved_rps, report.p50, report.p99, report.transport_errors
            );
            0
        }
        Err(e) => {
            eprintln!("fuseconv bench: {e}");
            1
        }
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Round to two decimals so the report doesn't encode float noise.
fn ms(x: f64) -> Json {
    Json::Num((x * 100.0).round() / 100.0)
}

fn run_bench(mut opts: BenchOpts) -> Result<BenchReport, String> {
    // --- connect the pool (blocking connects, then nonblocking I/O) ---
    let poller = Poller::new().map_err(|e| format!("epoll setup: {e}"))?;
    let mut conns: Vec<BenchConn> = Vec::with_capacity(opts.connections);
    for i in 0..opts.connections {
        let stream = TcpStream::connect(&opts.connect)
            .map_err(|e| format!("connect {} (conn {i}): {e}", opts.connect))?;
        stream
            .set_nonblocking(true)
            .map_err(|e| format!("set_nonblocking: {e}"))?;
        let _ = stream.set_nodelay(true);
        poller
            .add(raw_fd(&stream), i as u64, true, false)
            .map_err(|e| format!("epoll register: {e}"))?;
        conns.push(BenchConn {
            stream,
            out: Vec::new(),
            inbuf: Vec::new(),
            want_write: false,
            dead: false,
        });
    }

    // --- load loop state ---
    let start = Instant::now();
    let measure_start = start + opts.warmup;
    let load_end = measure_start + opts.duration;
    let mut next_send = start;
    let mut next_id: u64 = 1;
    let mut rr = 0usize; // connection round-robin cursor
    let mut in_flight: HashMap<u64, InFlight> = HashMap::new();
    let mut peak_inflight = 0usize;
    let mut sent: u64 = 0; // measured-window sends
    let mut completed: u64 = 0; // measured-window finals
    let mut warmup_sent: u64 = 0;
    let mut latencies_ms: Vec<f64> = Vec::new();
    let mut app_errors: u64 = 0;
    let mut errors_by_code: HashMap<&'static str, u64> = HashMap::new();
    let mut transport_errors: u64 = 0;
    let mut events: Vec<PollEvent> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];

    loop {
        let now = Instant::now();
        if now >= load_end && (in_flight.is_empty() || now >= load_end + DRAIN_GRACE) {
            break;
        }

        // --- open-loop send phase: emit every send that is due ---
        if now < load_end {
            while next_send <= now {
                // linear ramp to the target rate across the warmup
                let rate = if opts.warmup.is_zero() || now >= measure_start {
                    opts.rps
                } else {
                    let frac = now.duration_since(start).as_secs_f64()
                        / opts.warmup.as_secs_f64();
                    (opts.rps * frac).max(MIN_RATE)
                };
                // next live connection, round-robin
                let Some(c) = pick_conn(&conns, &mut rr) else {
                    return Err("every connection died under load".into());
                };
                let id = next_id;
                next_id += 1;
                let op = opts.mix.next();
                let mut line = encode_request(&op.request(id));
                line.push('\n');
                conns[c].out.extend_from_slice(line.as_bytes());
                let measured = now >= measure_start;
                if measured {
                    sent += 1;
                } else {
                    warmup_sent += 1;
                }
                in_flight.insert(id, InFlight { at: now, measured, conn: c });
                peak_inflight = peak_inflight.max(in_flight.len());
                flush_conn(&poller, &mut conns[c], c);
                next_send += Duration::from_secs_f64(1.0 / rate.max(MIN_RATE));
            }
        }

        // --- wait for readiness (bounded by the next scheduled send) ---
        let wait_until =
            if now < load_end { next_send.min(load_end) } else { load_end + DRAIN_GRACE };
        let timeout = wait_until
            .saturating_duration_since(now)
            .min(Duration::from_millis(100))
            .max(Duration::from_millis(1));
        poller.wait(&mut events, Some(timeout)).map_err(|e| format!("epoll wait: {e}"))?;

        // --- service readiness ---
        for &ev in &events {
            let c = ev.token as usize;
            if c >= conns.len() || conns[c].dead {
                continue;
            }
            if ev.writable {
                flush_conn(&poller, &mut conns[c], c);
            }
            if ev.readable {
                read_conn(
                    &mut conns[c],
                    &mut scratch,
                    &mut in_flight,
                    &mut latencies_ms,
                    &mut completed,
                    &mut app_errors,
                    &mut errors_by_code,
                    &mut transport_errors,
                );
            }
            if conns[c].dead {
                reap_conn(&poller, &mut conns, c, &mut in_flight, &mut transport_errors);
            }
        }
    }

    // requests the grace period never answered
    let unanswered = in_flight.len() as u64;

    // --- stats snapshot while the pool is still connected: the gauges
    // show open_conns ≈ the pool size against a flat thread count ---
    let server_stats = request_once(
        &opts.connect,
        &Request::new(0, RequestBody::Stats),
        SNAPSHOT_TIMEOUT,
    )
    .ok()
    .and_then(|resp| match resp.result {
        Ok(Reply::Stats(s)) => Some(s),
        _ => None,
    });

    // --- report ---
    if latencies_ms.is_empty() {
        return Err("no requests completed inside the measured window".into());
    }
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let measured_secs = opts.duration.as_secs_f64();
    let achieved_rps = completed as f64 / measured_secs;
    let mean = latencies_ms.iter().sum::<f64>() / latencies_ms.len() as f64;
    let p50 = percentile_sorted(&latencies_ms, 50.0);
    let p95 = percentile_sorted(&latencies_ms, 95.0);
    let p99 = percentile_sorted(&latencies_ms, 99.0);
    let p999 = percentile_sorted(&latencies_ms, 99.9);
    let max = *latencies_ms.last().expect("nonempty");

    let mut code_pairs: Vec<(&str, Json)> = errors_by_code
        .iter()
        .map(|(code, n)| (*code, Json::UInt(*n)))
        .collect();
    code_pairs.sort_by_key(|(code, _)| *code);

    let server = match server_stats {
        Some(s) => {
            // result-cache effectiveness over the whole run (warmup
            // included: the warmup IS what warms the cache)
            let served = s.result_hits + s.result_coalesced;
            let looked = served + s.result_misses;
            let hit_rate = if looked == 0 { 0.0 } else { served as f64 / looked as f64 };
            obj(vec![
                (
                    "gauges",
                    obj(vec![
                        ("open_conns", Json::UInt(s.open_conns)),
                        ("active_streams", Json::UInt(s.active_streams)),
                        ("transport_threads", Json::UInt(s.transport_threads)),
                    ]),
                ),
                (
                    "cache",
                    obj(vec![
                        ("result_hits", Json::UInt(s.result_hits)),
                        ("result_misses", Json::UInt(s.result_misses)),
                        ("result_coalesced", Json::UInt(s.result_coalesced)),
                        ("result_evicted", Json::UInt(s.result_evicted)),
                        ("result_entries", Json::UInt(s.result_entries)),
                        ("result_bytes", Json::UInt(s.result_bytes)),
                        ("hit_rate", Json::Num((hit_rate * 10_000.0).round() / 10_000.0)),
                    ]),
                ),
                (
                    "search",
                    obj(vec![
                        ("started", Json::UInt(s.search_started)),
                        ("completed", Json::UInt(s.search_completed)),
                        ("cancelled", Json::UInt(s.search_cancelled)),
                    ]),
                ),
            ])
        }
        None => Json::Null,
    };

    let json = obj(vec![
        ("bench", Json::UInt(7)),
        ("transport", Json::Str(opts.transport_label.clone())),
        ("target_rps", Json::Num(opts.rps)),
        ("achieved_rps", ms(achieved_rps)),
        ("duration_s", Json::Num(measured_secs)),
        ("warmup_s", Json::Num(opts.warmup.as_secs_f64())),
        ("connections", Json::UInt(opts.connections as u64)),
        ("peak_inflight", Json::UInt(peak_inflight as u64)),
        (
            "requests",
            obj(vec![
                ("sent", Json::UInt(sent)),
                ("completed", Json::UInt(completed)),
                ("warmup_sent", Json::UInt(warmup_sent)),
                ("unanswered", Json::UInt(unanswered)),
                ("app_errors", Json::UInt(app_errors)),
                ("transport_errors", Json::UInt(transport_errors)),
            ]),
        ),
        (
            "latency_ms",
            obj(vec![
                ("p50", ms(p50)),
                ("p95", ms(p95)),
                ("p99", ms(p99)),
                ("p999", ms(p999)),
                ("mean", ms(mean)),
                ("max", ms(max)),
            ]),
        ),
        (
            "op_mix",
            obj(opts.mix.ops.iter().map(|(op, w)| (op.name(), Json::Num(*w))).collect()),
        ),
        ("errors_by_code", obj(code_pairs)),
        ("server", server),
    ]);

    Ok(BenchReport { json, achieved_rps, p50, p99, transport_errors })
}

/// Next live connection at or after the cursor; `None` if all are dead.
fn pick_conn(conns: &[BenchConn], rr: &mut usize) -> Option<usize> {
    for _ in 0..conns.len() {
        let c = *rr % conns.len();
        *rr = (*rr + 1) % conns.len();
        if !conns[c].dead {
            return Some(c);
        }
    }
    None
}

/// Push pending output; arms/disarms EPOLLOUT as the socket accepts it.
fn flush_conn(poller: &Poller, conn: &mut BenchConn, token: usize) {
    while !conn.out.is_empty() {
        match conn.stream.write(&conn.out) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => {
                conn.out.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    let want = !conn.out.is_empty();
    if want != conn.want_write {
        conn.want_write = want;
        let _ = poller.modify(raw_fd(&conn.stream), token as u64, true, want);
    }
}

/// Drain readable bytes and account every complete reply line.
#[allow(clippy::too_many_arguments)]
fn read_conn(
    conn: &mut BenchConn,
    scratch: &mut [u8],
    in_flight: &mut HashMap<u64, InFlight>,
    latencies_ms: &mut Vec<f64>,
    completed: &mut u64,
    app_errors: &mut u64,
    errors_by_code: &mut HashMap<&'static str, u64>,
    transport_errors: &mut u64,
) {
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => conn.inbuf.extend_from_slice(&scratch[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    while let Some(pos) = conn.inbuf.iter().position(|&b| b == b'\n') {
        let line_bytes: Vec<u8> = conn.inbuf.drain(..=pos).collect();
        let parsed = std::str::from_utf8(&line_bytes)
            .ok()
            .map(str::trim)
            .filter(|l| !l.is_empty())
            .map(decode_frame);
        let Some(decoded) = parsed else { continue };
        let Ok((id, frame)) = decoded else {
            // an undecodable frame means the stream is desynchronized
            *transport_errors += 1;
            conn.dead = true;
            return;
        };
        let Frame::Final(result) = frame else {
            continue; // progress / row frames of in-flight sweeps
        };
        let Some(fl) = in_flight.remove(&id) else { continue };
        let now = Instant::now();
        if fl.measured {
            *completed += 1;
            latencies_ms.push(now.duration_since(fl.at).as_secs_f64() * 1000.0);
            if let Err(e) = &result {
                *app_errors += 1;
                *errors_by_code.entry(error_code(e)).or_insert(0) += 1;
            }
        }
    }
}

fn error_code(e: &ServeError) -> &'static str {
    e.code()
}

/// Unregister a dead connection and fail everything it still owed.
fn reap_conn(
    poller: &Poller,
    conns: &mut [BenchConn],
    c: usize,
    in_flight: &mut HashMap<u64, InFlight>,
    transport_errors: &mut u64,
) {
    let _ = poller.remove(raw_fd(&conns[c].stream));
    let before = in_flight.len();
    in_flight.retain(|_, fl| fl.conn != c);
    *transport_errors += (before - in_flight.len()) as u64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_picker_is_deterministic_and_weighted() {
        let mut m = MixPicker::parse("simulate=80,infer=10,sweep=10").unwrap();
        let mut counts = HashMap::new();
        for _ in 0..100 {
            *counts.entry(m.next().name()).or_insert(0u32) += 1;
        }
        assert_eq!(counts["simulate"], 80);
        assert_eq!(counts["infer"], 10);
        assert_eq!(counts["sweep"], 10);
        // weighted round-robin interleaves: the first ten draws are not
        // all the heavy op's
        let mut m2 = MixPicker::parse("simulate=80,infer=10,sweep=10").unwrap();
        let first: Vec<&str> = (0..10).map(|_| m2.next().name()).collect();
        assert!(first.iter().any(|op| *op != "simulate"));
    }

    #[test]
    fn mix_picker_rejects_junk() {
        assert!(MixPicker::parse("").is_err());
        assert!(MixPicker::parse("simulate").is_err());
        assert!(MixPicker::parse("simulate=0").is_err());
        assert!(MixPicker::parse("teleport=5").is_err());
        assert!(MixPicker::parse("simulate=1,simulate=2").is_err());
        assert!(MixPicker::parse("simulate=-1").is_err());
    }

    #[test]
    fn op_requests_use_distinct_ids_and_ops() {
        for (op, want) in [
            (OpKind::Simulate, "simulate"),
            (OpKind::Infer, "infer"),
            (OpKind::Sweep, "sweep"),
        ] {
            let req = op.request(7);
            assert_eq!(req.id, 7);
            assert_eq!(req.body.op(), want);
        }
    }
}
