//! Property-based testing kit (proptest is unavailable offline).
//!
//! `forall` runs a property over `cases` randomly generated inputs; on
//! failure it performs greedy shrinking via the caller-provided `shrink`
//! steps and reports the minimal failing case with the seed needed to
//! replay it. The simulator/coordinator invariants (routing, batching,
//! fold accounting, MAC conservation) are tested through this module.

use crate::rng::Rng;

/// Outcome of a property check over one generated case.
pub enum Check {
    Pass,
    Fail(String),
}

impl Check {
    pub fn from_bool(ok: bool, msg: &str) -> Check {
        if ok {
            Check::Pass
        } else {
            Check::Fail(msg.to_string())
        }
    }
}

/// Assert-style helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return $crate::testkit::Check::Fail(format!($($fmt)*));
        }
    };
}

/// Run `prop` over `cases` inputs drawn by `gen`. On a failure, applies
/// `shrink` (which returns candidate smaller inputs) greedily until no
/// candidate still fails, then panics with the minimal case.
pub fn forall<T, G, P, S>(seed: u64, cases: usize, gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Check,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Check::Fail(msg) = prop(&input) {
            // Greedy shrink
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 1000usize;
            'outer: loop {
                for cand in shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Check::Fail(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case}/{cases})\n  minimal input: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// No-op shrinker for types where shrinking isn't worth it.
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrinker for usize tuples/scalars: try halving and decrementing.
pub fn shrink_usize(x: &usize) -> Vec<usize> {
    let mut out = Vec::new();
    if *x > 0 {
        out.push(x / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

/// Approximate float equality with relative + absolute tolerance.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            1,
            200,
            |r| r.below(1000),
            shrink_usize,
            |&x| Check::from_bool(x < 1000, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 100, |r| r.below(100), shrink_usize, |&x| {
            Check::from_bool(x < 50, "x must be < 50")
        });
    }

    #[test]
    #[should_panic(expected = "minimal input: 50")]
    fn shrinks_to_minimal_counterexample() {
        // Failing iff x >= 50; greedy shrink should land exactly on 50.
        forall(3, 200, |r| 50 + r.below(1000), shrink_usize, |&x| {
            Check::from_bool(x < 50, "x must be < 50")
        });
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(approx_eq(0.0, 1e-12, 0.0, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3, 1e-3));
    }

    #[test]
    fn prop_assert_macro_produces_fail() {
        fn p(x: usize) -> Check {
            prop_assert!(x != 7, "x was {}", x);
            Check::Pass
        }
        assert!(matches!(p(7), Check::Fail(_)));
        assert!(matches!(p(8), Check::Pass));
    }
}
