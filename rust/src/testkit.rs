//! Property-based testing kit (proptest is unavailable offline) plus
//! the deterministic fault-injection harness behind the self-healing
//! fleet acceptance tests.
//!
//! `forall` runs a property over `cases` randomly generated inputs; on
//! failure it performs greedy shrinking via the caller-provided `shrink`
//! steps and reports the minimal failing case with the seed needed to
//! replay it. The simulator/coordinator invariants (routing, batching,
//! fold accounting, MAC conservation) are tested through this module.
//!
//! [`ChaosProxy`] is a TCP interposer that sits between a shard front
//! tier and one backend and injects *deterministic* transport faults —
//! refused connections, black holes, a cut at an exact reply-frame
//! boundary, per-frame delay — switchable at runtime, so failover paths
//! are exercised by reproducible faults instead of `kill -9` races.
//!
//! [`TestServer`] / [`TestShard`] are RAII guards around the
//! bind-ephemeral / spawn-run / connect / shutdown-and-join boilerplate
//! every serving integration test used to hand-roll.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::{
    http_call_auth, request_once, HttpServer, MockEngine, Reply, Request, RequestBody, Router,
    Server, Service, ShardRouter, SimServer, WireClient, WireServer,
};
use crate::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown as SockShutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Outcome of a property check over one generated case.
pub enum Check {
    Pass,
    Fail(String),
}

impl Check {
    pub fn from_bool(ok: bool, msg: &str) -> Check {
        if ok {
            Check::Pass
        } else {
            Check::Fail(msg.to_string())
        }
    }
}

/// Assert-style helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return $crate::testkit::Check::Fail(format!($($fmt)*));
        }
    };
}

/// Run `prop` over `cases` inputs drawn by `gen`. On a failure, applies
/// `shrink` (which returns candidate smaller inputs) greedily until no
/// candidate still fails, then panics with the minimal case.
pub fn forall<T, G, P, S>(seed: u64, cases: usize, gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Check,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Check::Fail(msg) = prop(&input) {
            // Greedy shrink
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 1000usize;
            'outer: loop {
                for cand in shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Check::Fail(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case}/{cases})\n  minimal input: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// No-op shrinker for types where shrinking isn't worth it.
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrinker for usize tuples/scalars: try halving and decrementing.
pub fn shrink_usize(x: &usize) -> Vec<usize> {
    let mut out = Vec::new();
    if *x > 0 {
        out.push(x / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

/// Approximate float equality with relative + absolute tolerance.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

/// Poll `cond` (every 5 ms, up to ~10 s) until it holds; panic with
/// `what` if it never does. The standard way the integration tests wait
/// for asynchronous state (gauges draining, probes tripping) without
/// fixed sleeps.
pub fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..2000 {
        if cond() {
            return;
        }
        thread::sleep(Duration::from_millis(5));
    }
    panic!("timed out waiting for {what}");
}

// ---------------------------------------------------------------------------
// Wire-stream test helpers
// ---------------------------------------------------------------------------

/// A `Sweep` request over zoo `names` × `variants` × square `sizes`.
pub fn sweep_req(
    id: u64,
    names: &[&str],
    variants: &[crate::sim::FuseVariant],
    sizes: &[usize],
) -> Request {
    Request::new(
        id,
        RequestBody::Sweep {
            models: names.iter().map(|s| s.to_string()).collect(),
            variants: variants.to_vec(),
            configs: sizes.iter().map(|&s| crate::coordinator::ConfigPatch::sized(s)).collect(),
        },
    )
}

/// Drain one request's reply stream into its raw frame sequence
/// (everything up to and including the terminal `Final`).
pub fn stream_frames(client: &mut WireClient, id: u64) -> Vec<crate::coordinator::Frame> {
    let mut frames = Vec::new();
    loop {
        let frame = client.recv_frame(id).expect("stream frame");
        let last = frame.is_final();
        frames.push(frame);
        if last {
            return frames;
        }
    }
}

/// The stream's `Row` frames re-encoded under `id`, for byte-for-byte
/// stream comparison.
pub fn row_frames(frames: &[crate::coordinator::Frame], id: u64) -> Vec<String> {
    frames
        .iter()
        .filter(|f| matches!(f, crate::coordinator::Frame::Row(_)))
        .map(|f| crate::coordinator::wire::encode_frame(id, f))
        .collect()
}

/// The stream's `(done, total)` progress walk, in arrival order.
pub fn progress_frames(frames: &[crate::coordinator::Frame]) -> Vec<(u64, u64)> {
    frames
        .iter()
        .filter_map(|f| match f {
            crate::coordinator::Frame::Progress { done, total } => Some((*done, *total)),
            _ => None,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Deterministic TCP fault injection
// ---------------------------------------------------------------------------

/// What a [`ChaosProxy`] does to traffic, switchable at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosMode {
    /// Relay faithfully (the do-no-harm baseline).
    Pass,
    /// Close every accepted connection immediately: a connect "succeeds"
    /// then dies on first use — the deterministic stand-in for a
    /// refused/reset connection.
    Refuse,
    /// Accept and hold connections open but never answer: the client
    /// sees pure silence until its own timeout — the deterministic
    /// stand-in for a hung or partitioned node.
    BlackHole,
    /// Relay exactly N upstream reply frames (newline-delimited wire
    /// frames), then sever both directions — a crash at an exact,
    /// reproducible frame boundary mid-stream.
    DropAfterFrames(usize),
    /// Relay, sleeping this long before each forwarded reply frame.
    DelayMs(u64),
}

/// A TCP interposer for deterministic fault injection: listens on its
/// own ephemeral port, forwards to `upstream`, and applies the current
/// [`ChaosMode`] — checked per accepted connection (`Refuse`,
/// `BlackHole`) and per relayed reply frame (`DropAfterFrames`,
/// `DelayMs`, and live switches *into* `BlackHole`). Point a shard
/// front tier at `proxy.addr()` instead of the backend and the backend
/// "crashes" exactly where the test says it does.
pub struct ChaosProxy {
    addr: String,
    mode: Arc<Mutex<ChaosMode>>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Start interposing in front of `upstream` (mode: [`ChaosMode::Pass`]).
    pub fn start(upstream: &str) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind chaos proxy");
        listener.set_nonblocking(true).expect("nonblocking chaos accept");
        let addr = listener.local_addr().expect("chaos proxy addr").to_string();
        let mode = Arc::new(Mutex::new(ChaosMode::Pass));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let upstream = upstream.to_string();
            let (mode, stop, conns) =
                (Arc::clone(&mode), Arc::clone(&stop), Arc::clone(&conns));
            thread::Builder::new()
                .name("chaos-proxy-accept".into())
                .spawn(move || loop {
                    if stop.load(Ordering::Acquire) {
                        return;
                    }
                    match listener.accept() {
                        Ok((client, _)) => {
                            let _ = client.set_nonblocking(false);
                            let decided = *mode.lock().unwrap_or_else(|e| e.into_inner());
                            match decided {
                                ChaosMode::Refuse => drop(client),
                                ChaosMode::BlackHole => {
                                    // Hold it open, never read or reply.
                                    conns.lock().unwrap_or_else(|e| e.into_inner()).push(client);
                                }
                                _ => {
                                    let Ok(up) = TcpStream::connect(&upstream) else {
                                        drop(client);
                                        continue;
                                    };
                                    register(&conns, &client);
                                    register(&conns, &up);
                                    spawn_relay_pair(
                                        client,
                                        up,
                                        Arc::clone(&mode),
                                        Arc::clone(&stop),
                                    );
                                }
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => return,
                    }
                })
                .expect("spawn chaos accept")
        };
        ChaosProxy { addr, mode, stop, conns, accept: Some(accept) }
    }

    /// The address clients (the front tier) should connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Switch fault mode; applies to new connections immediately and to
    /// in-flight relays at their next reply frame.
    pub fn set_mode(&self, m: ChaosMode) {
        *self.mode.lock().unwrap_or_else(|e| e.into_inner()) = m;
    }

    /// Hard-close every connection the proxy has carried so far (both
    /// halves) — the "node dropped off the network" event.
    pub fn kill_connections(&self) {
        let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        for c in conns.drain(..) {
            let _ = c.shutdown(SockShutdown::Both);
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.kill_connections();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn register(conns: &Arc<Mutex<Vec<TcpStream>>>, stream: &TcpStream) {
    if let Ok(clone) = stream.try_clone() {
        conns.lock().unwrap_or_else(|e| e.into_inner()).push(clone);
    }
}

/// One relay thread per direction. Requests (client→upstream) always
/// copy raw bytes; replies (upstream→client) are relayed frame by frame
/// (newline-delimited) so `DropAfterFrames` cuts at an exact boundary.
fn spawn_relay_pair(
    client: TcpStream,
    up: TcpStream,
    mode: Arc<Mutex<ChaosMode>>,
    stop: Arc<AtomicBool>,
) {
    let (client_rd, up_wr) = (client.try_clone(), up.try_clone());
    if let (Ok(mut client_rd), Ok(mut up_wr)) = (client_rd, up_wr) {
        thread::Builder::new()
            .name("chaos-proxy-up".into())
            .spawn(move || {
                let mut buf = [0u8; 4096];
                loop {
                    match client_rd.read(&mut buf) {
                        Ok(0) | Err(_) => {
                            let _ = up_wr.shutdown(SockShutdown::Both);
                            return;
                        }
                        Ok(n) => {
                            if up_wr.write_all(&buf[..n]).is_err() {
                                let _ = client_rd.shutdown(SockShutdown::Both);
                                return;
                            }
                        }
                    }
                }
            })
            .expect("spawn chaos relay");
    }
    thread::Builder::new()
        .name("chaos-proxy-down".into())
        .spawn(move || {
            let mut client = client;
            let mut reader = BufReader::new(up);
            let mut forwarded = 0usize;
            let mut line = Vec::new();
            loop {
                line.clear();
                match reader.read_until(b'\n', &mut line) {
                    Ok(0) | Err(_) => {
                        let _ = client.shutdown(SockShutdown::Both);
                        return;
                    }
                    Ok(_) => {}
                }
                // Apply the *current* mode to this frame.
                loop {
                    if stop.load(Ordering::Acquire) {
                        return;
                    }
                    match *mode.lock().unwrap_or_else(|e| e.into_inner()) {
                        ChaosMode::BlackHole => thread::sleep(Duration::from_millis(10)),
                        ChaosMode::DelayMs(ms) => {
                            thread::sleep(Duration::from_millis(ms));
                            break;
                        }
                        ChaosMode::DropAfterFrames(n) if forwarded >= n => {
                            let _ = client.shutdown(SockShutdown::Both);
                            let _ = reader.get_ref().shutdown(SockShutdown::Both);
                            return;
                        }
                        _ => break,
                    }
                }
                if client.write_all(&line).is_err() {
                    let _ = reader.get_ref().shutdown(SockShutdown::Both);
                    return;
                }
                forwarded += 1;
            }
        })
        .expect("spawn chaos relay");
}

// ---------------------------------------------------------------------------
// RAII server guards
// ---------------------------------------------------------------------------

enum Flavor {
    Tcp,
    Http,
}

/// One running serving frontend on an ephemeral port, shut down and
/// joined on drop (best-effort) or via [`TestServer::shutdown`]
/// (asserting). Wraps the bind / spawn-`run` / connect / shutdown
/// boilerplate every integration test used to duplicate.
pub struct TestServer {
    addr: String,
    flavor: Flavor,
    token: Option<String>,
    handle: Option<thread::JoinHandle<()>>,
}

impl TestServer {
    /// Run an already-configured TCP frontend (use this when the test
    /// needs `with_transport`/`with_gauges`/`with_auth_token` builders).
    pub fn from_wire(server: WireServer) -> TestServer {
        let addr = server.local_addr().to_string();
        let handle = thread::spawn(move || server.run().expect("test wire server run"));
        TestServer { addr, flavor: Flavor::Tcp, token: None, handle: Some(handle) }
    }

    /// Run an already-configured HTTP frontend.
    pub fn from_http(server: HttpServer) -> TestServer {
        let addr = server.local_addr().to_string();
        let handle = thread::spawn(move || server.run().expect("test http server run"));
        TestServer { addr, flavor: Flavor::Http, token: None, handle: Some(handle) }
    }

    /// Mount `service` behind a plain TCP frontend on an ephemeral port.
    pub fn wire(service: Arc<dyn Service>) -> TestServer {
        Self::from_wire(WireServer::bind("127.0.0.1:0", service).expect("bind test server"))
    }

    /// Mount `service` behind a plain HTTP frontend on an ephemeral port.
    pub fn http(service: Arc<dyn Service>) -> TestServer {
        Self::from_http(HttpServer::bind("127.0.0.1:0", service).expect("bind test http"))
    }

    /// One full mock backend — the standard `fuseconv serve` shape
    /// (mock inference engine + sim pool) on a TCP port.
    pub fn mock_backend() -> TestServer {
        Self::wire(Arc::new(mock_router()))
    }

    /// Token to present on the drop/shutdown round-trip (for frontends
    /// started `with_auth_token`).
    pub fn with_token(mut self, token: &str) -> TestServer {
        self.token = Some(token.to_string());
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Open a wire client to this server (TCP flavor only).
    pub fn client(&self, timeout: Duration) -> WireClient {
        WireClient::connect(&self.addr, timeout).expect("connect test server")
    }

    /// Strict shutdown: the round-trip must succeed and ack `Done`.
    pub fn shutdown(mut self) {
        let handle = self.handle.take().expect("server already shut down");
        let result = self.send_shutdown();
        handle.join().expect("test server thread");
        assert_eq!(result, Some(Ok(Reply::Done)), "shutdown ack");
    }

    /// Join a server something *else* already stopped (e.g. a front
    /// tier's shutdown fan-out). Sends nothing — if the server is in
    /// fact still running, this hangs until the test times out, which
    /// is exactly the proof the caller wants.
    pub fn join_stopped(mut self) {
        let handle = self.handle.take().expect("server already shut down");
        handle.join().expect("test server thread");
    }

    /// Returns the shutdown round-trip's typed result, `None` if the
    /// transport failed (already-stopped servers land here).
    fn send_shutdown(&self) -> Option<Result<Reply, crate::coordinator::ServeError>> {
        let t = Duration::from_secs(10);
        match self.flavor {
            Flavor::Tcp => {
                let mut req = Request::new(u64::MAX, RequestBody::Shutdown);
                if let Some(tok) = &self.token {
                    req = req.with_token(tok.clone());
                }
                request_once(&self.addr, &req, t).ok().map(|resp| resp.result)
            }
            Flavor::Http => http_call_auth(
                &self.addr,
                "/v1/shutdown",
                Some("{}"),
                None,
                self.token.as_deref(),
                t,
            )
            .ok()
            .and_then(|reply| reply.response().ok())
            .map(|resp| resp.result),
        }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        if let Some(handle) = self.handle.take() {
            // Best-effort: a front tier's shutdown fan-out may already
            // have stopped this server, in which case the round-trip
            // fails to connect and the join returns immediately.
            let _ = self.send_shutdown();
            let _ = handle.join();
        }
    }
}

/// The standard full-stack mock router (mock inference engine + sim
/// pool) that backend-shaped tests mount.
pub fn mock_router() -> Router {
    Router::new(SimServer::new(2)).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ))
}

/// A whole sharded deployment under RAII: N mock backends plus a shard
/// front tier over them. Declared front-first so the front tier drops
/// (and fans its shutdown out) before the backend guards run.
pub struct TestShard {
    pub front: TestServer,
    pub backends: Vec<TestServer>,
}

impl TestShard {
    /// N mock backends behind a default-config front tier.
    pub fn start(n: usize) -> TestShard {
        Self::start_with(n, |addrs| ShardRouter::new(addrs, Duration::from_secs(120)))
    }

    /// N mock backends behind a front tier the test configures itself
    /// (probes, inflight bounds, extra/proxied backend addresses).
    pub fn start_with(
        n: usize,
        make: impl FnOnce(Vec<String>) -> ShardRouter,
    ) -> TestShard {
        let backends: Vec<TestServer> = (0..n).map(|_| TestServer::mock_backend()).collect();
        let addrs = backends.iter().map(|b| b.addr().to_string()).collect();
        let front = TestServer::wire(Arc::new(make(addrs)));
        TestShard { front, backends }
    }

    pub fn front_addr(&self) -> &str {
        self.front.addr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            1,
            200,
            |r| r.below(1000),
            shrink_usize,
            |&x| Check::from_bool(x < 1000, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 100, |r| r.below(100), shrink_usize, |&x| {
            Check::from_bool(x < 50, "x must be < 50")
        });
    }

    #[test]
    #[should_panic(expected = "minimal input: 50")]
    fn shrinks_to_minimal_counterexample() {
        // Failing iff x >= 50; greedy shrink should land exactly on 50.
        forall(3, 200, |r| 50 + r.below(1000), shrink_usize, |&x| {
            Check::from_bool(x < 50, "x must be < 50")
        });
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(approx_eq(0.0, 1e-12, 0.0, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3, 1e-3));
    }

    #[test]
    fn prop_assert_macro_produces_fail() {
        fn p(x: usize) -> Check {
            prop_assert!(x != 7, "x was {}", x);
            Check::Pass
        }
        assert!(matches!(p(7), Check::Fail(_)));
        assert!(matches!(p(8), Check::Pass));
    }
}
