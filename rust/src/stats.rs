//! Small statistics toolkit: summaries, percentiles, and online accumulators.
//!
//! Used by the simulator's bandwidth reports (Fig 11 needs per-layer average
//! and maximum bandwidth), the serving driver's latency stats, and the bench
//! harness (criterion is unavailable offline).

/// Summary of a sample: n, mean, std-dev, min/max, and selected percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/max accumulator — O(1) memory; the simulator feeds it one
/// value per fold window so whole-network runs never buffer cycle series.
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub n: u64,
    sum: f64,
    weight: f64,
    pub max: f64,
    pub min: f64,
}

impl Online {
    pub fn new() -> Online {
        Online { n: 0, sum: 0.0, weight: 0.0, max: f64::NEG_INFINITY, min: f64::INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
    }

    /// Weighted push: value `x` observed over `w` units (e.g. bandwidth held
    /// for `w` cycles). Mean becomes time-weighted; max is still pointwise.
    #[inline]
    pub fn push_weighted(&mut self, x: f64, w: f64) {
        self.n += 1;
        self.sum += x * w;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
        self.weight += w;
    }

    pub fn mean(&self) -> f64 {
        if self.weight > 0.0 {
            self.sum / self.weight
        } else if self.n > 0 {
            self.sum / self.n as f64
        } else {
            0.0
        }
    }
}

/// Geometric mean of positive values — the paper reports speedups as ranges;
/// geomean is the right aggregate across networks.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn online_tracks_mean_max() {
        let mut o = Online::new();
        for x in [2.0, 4.0, 6.0] {
            o.push(x);
        }
        assert!((o.mean() - 4.0).abs() < 1e-12);
        assert_eq!(o.max, 6.0);
        assert_eq!(o.min, 2.0);
        assert_eq!(o.n, 3);
    }

    #[test]
    fn online_weighted_mean() {
        let mut o = Online::new();
        o.push_weighted(10.0, 1.0);
        o.push_weighted(0.0, 9.0);
        assert!((o.mean() - 1.0).abs() < 1e-12);
        assert_eq!(o.max, 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[4.0, 9.0]);
        assert!((g - 6.0).abs() < 1e-12);
    }
}
