//! Output-stationary and weight-stationary schedules for GEMM-shaped
//! operators (standard conv via implicit im2col, pointwise conv, FC, the
//! per-channel matrices of depthwise conv).
//!
//! Model granularity mirrors SCALE-Sim: per *fold* (one operand tiling of
//! the array) we account compute cycles including the systolic skew
//! fill/drain, active-PE cycles, SRAM demand, and the DRAM working set the
//! double-buffered SRAMs must prefetch for that fold.

use super::config::SimConfig;
use super::fold::{Fold, FoldSet};

/// A GEMM view of an operator: `C[m,n] += A[m,k] · B[k,n]`, with the unique
/// backing-store footprints (before im2col replication) used for DRAM
/// accounting.
#[derive(Debug, Clone, Copy)]
pub struct Gemm {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Unique input elements behind A (im2col replicates; DRAM holds these).
    pub ifmap_unique: u64,
    /// Unique weight elements behind B.
    pub weight_unique: u64,
}

/// Words per cycle the im2col gather unit can fetch from the ifmap SRAM.
/// One gathered input row is *shared across all active columns* (filter
/// reuse — Fig 3a); depthwise has a single active column, so its gather
/// cannot be amortized and serializes the array (§2.3).
pub const GATHER_WIDTH: usize = 4;

/// Output-stationary schedule (paper Fig 1d): output tiles of
/// `rows × cols` stay pinned in PEs while the k-dimension streams through.
///
/// Two regimes, decided per column pass by whether the im2col gather can
/// keep the array streaming (`r_used ≤ GATHER_WIDTH · c_used`):
///
/// * **streaming** — row-folds within a column pass share the weight tile;
///   with double-buffered accumulators each subsequent fold costs only the
///   reduction (`k + 2`) while the previous tile drains. The first fold of
///   the pass pays the full systolic skew.
/// * **gather-bound** (depthwise: `c_used = 1`) — every fold pays the full
///   skew fill/drain *plus* the serialized window gather
///   (`r_used·k / (GATHER_WIDTH·c_used)` cycles). This is the formal §2.2
///   "not a systolic algorithm" pathology showing up as hardware time.
pub fn os_schedule(g: &Gemm, cfg: &SimConfig) -> FoldSet {
    let (r, c) = (cfg.rows, cfg.cols);
    let bpe = cfg.bytes_per_elem as u64;
    let rt = g.m.div_ceil(r);
    let ct = g.n.div_ceil(c);

    // Does the whole ifmap fit in its SRAM? If not, every column-tile pass
    // re-reads it from DRAM.
    let ifmap_bytes = g.ifmap_unique * bpe;
    let ifmap_passes = if ifmap_bytes <= cfg.ifmap_sram_bytes() as u64 { 1 } else { ct as u64 };
    // Weights for one column tile are loaded once per tile (reuse across
    // row tiles is what makes standard conv efficient — Fig 3a).
    let weight_tile_bytes = |c_used: usize| (g.k * c_used) as u64 * bpe;
    // Ifmap rows for one row tile.
    let ifmap_tile_bytes = |r_used: usize| {
        // Unique inputs behind r_used output rows ≈ proportional share.
        (g.ifmap_unique * r_used as u64 / g.m as u64).max(1) * bpe
    };

    let mut fs = FoldSet::new();
    for cti in 0..ct {
        let c_used = if cti == ct - 1 { g.n - cti * c } else { c };
        for rti in 0..rt {
            let r_used = if rti == rt - 1 { g.m - rti * r } else { r };
            let streaming = r_used <= GATHER_WIDTH * c_used;
            let duration = if streaming {
                if rti == 0 {
                    // first fold of the pass: skewed fill + reduce + drain
                    (2 * r_used + c_used + g.k).saturating_sub(2) as u64
                } else {
                    // steady state: reduction + handoff beat
                    (g.k + 2) as u64
                }
            } else {
                // gather-bound: full skew every fold + serialized gather
                let skew = (2 * r_used + c_used + g.k).saturating_sub(2);
                let gather = (r_used * g.k).div_ceil(GATHER_WIDTH * c_used);
                (skew + gather) as u64
            };
            let mut f = Fold::once(duration);
            f.pe_cycles = (r_used * c_used * g.k) as u64;
            f.ifmap_reads = (r_used * g.k) as u64;
            f.weight_reads = (c_used * g.k) as u64;
            f.ofmap_writes = (r_used * c_used) as u64;
            // DRAM: weight tile arrives once per column tile (first row
            // fold); ifmap tile arrives per fold on re-read passes, or only
            // during the first pass when it fits.
            if rti == 0 {
                f.dram_read_bytes += weight_tile_bytes(c_used);
            }
            if ifmap_passes > 1 || cti == 0 {
                f.dram_read_bytes += ifmap_tile_bytes(r_used);
            }
            f.dram_write_bytes = (r_used * c_used) as u64 * bpe;
            fs.push(f);
        }
    }
    fs
}

/// Weight-stationary schedule: a `rows × cols` weight tile is preloaded,
/// then all `m` activations stream through; partial sums flow down and
/// accumulate in the ofmap SRAM across k-tiles.
pub fn ws_schedule(g: &Gemm, cfg: &SimConfig) -> FoldSet {
    let (r, c) = (cfg.rows, cfg.cols);
    let bpe = cfg.bytes_per_elem as u64;
    let kt = g.k.div_ceil(r);
    let ct = g.n.div_ceil(c);

    let ifmap_bytes = g.ifmap_unique * bpe;
    let ifmap_passes = if ifmap_bytes <= cfg.ifmap_sram_bytes() as u64 { 1 } else { ct as u64 };
    // Partial sums across k-tiles must round-trip the ofmap SRAM; if they
    // do not fit they spill to DRAM (2× traffic per extra k-tile).
    let ofmap_tile_bytes = (g.m.min(1 << 20) * c) as u64 * bpe;
    let psum_spills = kt > 1 && ofmap_tile_bytes > cfg.ofmap_sram_bytes() as u64;

    let mut fs = FoldSet::new();
    for cti in 0..ct {
        let c_used = if cti == ct - 1 { g.n - cti * c } else { c };
        for kti in 0..kt {
            let r_used = if kti == kt - 1 { g.k - kti * r } else { r };
            // preload weights (r_used) + stream m inputs + skew drain.
            let duration = (r_used + g.m + r_used + c_used).saturating_sub(2) as u64;
            let mut f = Fold::once(duration);
            f.pe_cycles = (r_used * c_used * g.m) as u64;
            f.ifmap_reads = (g.m * r_used) as u64;
            f.weight_reads = (r_used * c_used) as u64;
            f.ofmap_writes = (g.m * c_used) as u64;
            f.dram_read_bytes = (r_used * c_used) as u64 * bpe; // its weights
            if ifmap_passes > 1 || (cti == 0 && kti == 0) {
                f.dram_read_bytes += (g.ifmap_unique * r_used as u64 / g.k as u64).max(1) * bpe;
            }
            if psum_spills && kti > 0 {
                f.dram_read_bytes += (g.m * c_used) as u64 * bpe;
                f.dram_write_bytes += (g.m * c_used) as u64 * bpe;
            }
            if kti == kt - 1 {
                f.dram_write_bytes += (g.m * c_used) as u64 * bpe;
            }
            fs.push(f);
        }
    }
    fs
}

/// Input-stationary schedule (the EcoFlow-style dataflow): an `m × k`
/// *activation* tile is pinned onto the array (m-dim on rows, k-dim on
/// cols), then all `n` weight columns stream past it while partial sums
/// accumulate per output row.
///
/// The defining property — and why this dataflow exists in the sweep
/// space — is that inputs are loaded *explicitly, once*: there is no
/// im2col gather walking a zero-inserted (transposed conv) or
/// zero-padded-tap (dilated conv) window, so those operators schedule
/// their compact GEMMs here and keep their utilization, where `os`/`ws`
/// burn array residency on inserted zeros.
pub fn is_schedule(g: &Gemm, cfg: &SimConfig) -> FoldSet {
    let (r, c) = (cfg.rows, cfg.cols);
    let bpe = cfg.bytes_per_elem as u64;
    let mt = g.m.div_ceil(r);
    let kt = g.k.div_ceil(c);

    // Weights re-stream once per pinned activation tile; if they all fit
    // in the weight SRAM only the first m-tile pays DRAM for them.
    let weight_bytes = g.weight_unique * bpe;
    let weight_passes = if weight_bytes <= cfg.weight_sram_bytes() as u64 { 1 } else { mt as u64 };
    // Partial sums across k-tiles round-trip the ofmap SRAM; spill to
    // DRAM when an m-tile's psum slab does not fit (mirrors ws).
    let psum_tile_bytes = (r.min(g.m) * g.n) as u64 * bpe;
    let psum_spills = kt > 1 && psum_tile_bytes > cfg.ofmap_sram_bytes() as u64;

    let mut fs = FoldSet::new();
    for mti in 0..mt {
        let r_used = if mti == mt - 1 { g.m - mti * r } else { r };
        for kti in 0..kt {
            let c_used = if kti == kt - 1 { g.k - kti * c } else { c };
            // pin the tile (c_used columns stream in) + n weight columns
            // through the skewed array + drain.
            let duration = (c_used + g.n + r_used + c_used).saturating_sub(2) as u64;
            let mut f = Fold::once(duration);
            f.pe_cycles = (r_used * c_used * g.n) as u64;
            // stationary: each pinned activation is read from SRAM once
            f.ifmap_reads = (r_used * c_used) as u64;
            f.weight_reads = (c_used * g.n) as u64;
            f.ofmap_writes = (r_used * g.n) as u64;
            // DRAM: the activation tile's share of the unique ifmap
            // arrives exactly once over the whole GEMM — the dataflow's
            // headline win for scatter-style operators.
            let tile_share = (r_used * c_used) as u64;
            let total = (g.m * g.k) as u64;
            f.dram_read_bytes = (g.ifmap_unique * tile_share / total.max(1)).max(1) * bpe;
            if weight_passes > 1 || mti == 0 {
                f.dram_read_bytes += (c_used * g.n) as u64 * bpe;
            }
            if psum_spills && kti > 0 {
                f.dram_read_bytes += (r_used * g.n) as u64 * bpe;
                f.dram_write_bytes += (r_used * g.n) as u64 * bpe;
            }
            if kti == kt - 1 {
                f.dram_write_bytes += (r_used * g.n) as u64 * bpe;
            }
            fs.push(f);
        }
    }
    fs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pointwise_gemm() -> Gemm {
        // 28×28 ifmap, 96 -> 192 channels
        Gemm { m: 784, n: 192, k: 96, ifmap_unique: 784 * 96, weight_unique: 96 * 192 }
    }

    #[test]
    fn os_mac_conservation() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        assert_eq!(fs.pe_cycles(), (g.m * g.n * g.k) as u64);
    }

    #[test]
    fn ws_mac_conservation() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = ws_schedule(&g, &cfg);
        assert_eq!(fs.pe_cycles(), (g.m * g.n * g.k) as u64);
    }

    #[test]
    fn is_mac_conservation() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = is_schedule(&g, &cfg);
        assert_eq!(fs.pe_cycles(), (g.m * g.n * g.k) as u64);
    }

    #[test]
    fn is_fold_count_and_utilization() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = is_schedule(&g, &cfg);
        // ceil(784/16)=49 m-tiles × ceil(96/16)=6 k-tiles
        assert_eq!(fs.num_folds(), 49 * 6);
        let util = fs.pe_cycles() as f64 / (fs.compute_cycles() * 256) as f64;
        // n = 192 streamed beats dominate the per-fold overheads
        assert!(util > 0.7 && util <= 1.0, "util {util}");
    }

    #[test]
    fn is_reads_each_input_once_from_dram() {
        let g = Gemm {
            m: 128 * 128,
            n: 64,
            k: 256,
            ifmap_unique: 128 * 128 * 256, // 4 MiB >> 64 KiB ifmap SRAM
            weight_unique: 256 * 64,
        };
        let cfg = SimConfig::default();
        let fs = is_schedule(&g, &cfg);
        // Unlike os (which re-fetches per column tile when the ifmap
        // outgrows SRAM), the pinned tiles arrive exactly once. Allow
        // rounding slack from per-fold `.max(1)` floors.
        let reads = fs.dram_read_bytes();
        let weights_worst = g.weight_unique * (g.m.div_ceil(cfg.rows) as u64);
        assert!(
            reads <= g.ifmap_unique + weights_worst + fs.num_folds(),
            "{reads} vs ifmap {} + weights {weights_worst}",
            g.ifmap_unique
        );
        let os_reads = os_schedule(&g, &cfg).dram_read_bytes();
        assert!(reads < os_reads, "is {reads} should undercut os {os_reads}");
    }

    #[test]
    fn os_fold_count() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        // ceil(784/16)=49 row tiles × ceil(192/16)=12 col tiles
        assert_eq!(fs.num_folds(), 49 * 12);
    }

    #[test]
    fn os_utilization_reasonable_for_big_gemm() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        let util = fs.pe_cycles() as f64 / (fs.compute_cycles() * 256) as f64;
        // streaming regime: row-folds pipeline, skew paid once per pass
        assert!(util > 0.8 && util <= 1.0, "util {util}");
    }

    #[test]
    fn os_depthwise_channel_is_single_column() {
        // one depthwise channel: m = 28*28 outputs, n = 1, k = 9
        let g = Gemm { m: 784, n: 1, k: 9, ifmap_unique: 900, weight_unique: 9 };
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        let util = fs.pe_cycles() as f64 / (fs.compute_cycles() * 256) as f64;
        // single column + short reduction => ~1% utilization (§2.3)
        assert!(util < 0.03, "util {util}");
    }

    #[test]
    fn edge_tiles_partial_pes() {
        // m = 20 on a 16-row array: second row-tile uses 4 rows
        let g = Gemm { m: 20, n: 16, k: 8, ifmap_unique: 160, weight_unique: 128 };
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        assert_eq!(fs.pe_cycles(), (20 * 16 * 8) as u64);
        assert_eq!(fs.num_folds(), 2);
    }

    #[test]
    fn dram_reads_cover_unique_footprint() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        let total = fs.dram_read_bytes();
        // at least the unique ifmap + weights once
        assert!(total >= g.ifmap_unique + g.weight_unique);
        // writes exactly the ofmap
        assert_eq!(fs.dram_write_bytes(), (g.m * g.n) as u64);
    }

    #[test]
    fn os_ifmap_refetch_when_sram_too_small() {
        let g = Gemm {
            m: 128 * 128,
            n: 64,
            k: 256,
            ifmap_unique: 128 * 128 * 256, // 4 MiB >> 64 KiB SRAM
            weight_unique: 256 * 64,
        };
        let cfg = SimConfig::default();
        let fs = os_schedule(&g, &cfg);
        let ct = (64usize + 15) / 16;
        let reads = fs.dram_read_bytes();
        // refetched once per column tile
        assert!(reads >= g.ifmap_unique * ct as u64, "{} vs {}", reads, g.ifmap_unique * ct as u64);
    }

    #[test]
    fn ws_streams_m_per_fold() {
        let g = pointwise_gemm();
        let cfg = SimConfig::default();
        let fs = ws_schedule(&g, &cfg);
        // kt = 6, ct = 12 folds
        assert_eq!(fs.num_folds(), 6 * 12);
        // each fold's duration dominated by m = 784
        assert!(fs.folds[0].duration >= 784);
    }
}
