//! Folds: the simulator's unit of array occupancy.
//!
//! A *fold* is one mapping of work onto the PE array (SCALE-Sim's term):
//! the array computes with a fixed operand tiling for `duration` cycles,
//! then the next fold is scheduled. Dataflow schedulers emit folds with
//! per-fold SRAM demand and DRAM prefetch requirements; the memory model
//! then turns demand into stalls and bandwidth.
//!
//! Identical folds are run-length compressed (`count`) — a depthwise layer
//! on a 16-row array emits tens of thousands of *identical* folds, and the
//! whole-network simulation stays O(distinct folds).

/// One fold (or `count` identical repetitions of it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fold {
    /// Compute cycles this fold occupies the array (excluding memory stalls).
    pub duration: u64,
    /// Σ over cycles of active PEs (= MACs executed, 1 MAC/PE/cycle).
    pub pe_cycles: u64,
    /// SRAM word reads during the fold.
    pub ifmap_reads: u64,
    pub weight_reads: u64,
    /// SRAM word writes of outputs.
    pub ofmap_writes: u64,
    /// DRAM traffic attributable to this fold (bytes): prefetch of its
    /// working set (reads) and writeback of produced outputs (writes).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// Repetitions of this exact fold.
    pub count: u64,
}

impl Fold {
    pub fn once(duration: u64) -> Fold {
        Fold {
            duration,
            pe_cycles: 0,
            ifmap_reads: 0,
            weight_reads: 0,
            ofmap_writes: 0,
            dram_read_bytes: 0,
            dram_write_bytes: 0,
            count: 1,
        }
    }

    pub fn total_duration(&self) -> u64 {
        self.duration * self.count
    }

    pub fn total_pe_cycles(&self) -> u64 {
        self.pe_cycles * self.count
    }
}

/// A layer's fold schedule.
#[derive(Debug, Clone, Default)]
pub struct FoldSet {
    pub folds: Vec<Fold>,
}

impl FoldSet {
    pub fn new() -> FoldSet {
        FoldSet { folds: Vec::new() }
    }

    /// Push a fold, merging with the previous entry when identical
    /// (keeps the run-length compression automatic for schedulers that
    /// emit folds one by one).
    pub fn push(&mut self, f: Fold) {
        if let Some(last) = self.folds.last_mut() {
            if last.duration == f.duration
                && last.pe_cycles == f.pe_cycles
                && last.ifmap_reads == f.ifmap_reads
                && last.weight_reads == f.weight_reads
                && last.ofmap_writes == f.ofmap_writes
                && last.dram_read_bytes == f.dram_read_bytes
                && last.dram_write_bytes == f.dram_write_bytes
            {
                last.count += f.count;
                return;
            }
        }
        self.folds.push(f);
    }

    pub fn num_folds(&self) -> u64 {
        self.folds.iter().map(|f| f.count).sum()
    }

    pub fn compute_cycles(&self) -> u64 {
        self.folds.iter().map(|f| f.total_duration()).sum()
    }

    pub fn pe_cycles(&self) -> u64 {
        self.folds.iter().map(|f| f.total_pe_cycles()).sum()
    }

    pub fn sram_reads(&self) -> u64 {
        self.folds
            .iter()
            .map(|f| (f.ifmap_reads + f.weight_reads) * f.count)
            .sum()
    }

    pub fn ofmap_writes(&self) -> u64 {
        self.folds.iter().map(|f| f.ofmap_writes * f.count).sum()
    }

    pub fn dram_read_bytes(&self) -> u64 {
        self.folds.iter().map(|f| f.dram_read_bytes * f.count).sum()
    }

    pub fn dram_write_bytes(&self) -> u64 {
        self.folds.iter().map(|f| f.dram_write_bytes * f.count).sum()
    }

    /// Rescale the schedule's active-PE cycles to `target` without touching
    /// durations or memory traffic. Used when a scheduler's array residency
    /// covers *more* slots than there are useful MACs — a transposed conv's
    /// zero-inserted inputs or a dilated conv's zero kernel taps under the
    /// GEMM dataflows (EcoFlow's pathology): the array cycles are real, the
    /// arithmetic mostly isn't. Per-fold shares round down; the exact
    /// remainder lands in a zero-duration accounting fold so
    /// `pe_cycles() == target` holds exactly and utilization reports the
    /// *useful* fraction.
    pub fn rescale_pe_cycles(&mut self, target: u64) {
        let current = self.pe_cycles();
        if current == 0 || current == target {
            return;
        }
        let mut assigned = 0u64;
        for f in &mut self.folds {
            let scaled = ((f.pe_cycles as u128 * target as u128) / current as u128) as u64;
            f.pe_cycles = scaled;
            assigned += scaled * f.count;
        }
        let remainder = target.saturating_sub(assigned);
        if remainder > 0 {
            let mut f = Fold::once(0);
            f.pe_cycles = remainder;
            self.push(f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(duration: u64, pe: u64) -> Fold {
        Fold { duration, pe_cycles: pe, ..Fold::once(duration) }
    }

    #[test]
    fn push_merges_identical() {
        let mut fs = FoldSet::new();
        for _ in 0..1000 {
            fs.push(f(10, 100));
        }
        assert_eq!(fs.folds.len(), 1);
        assert_eq!(fs.num_folds(), 1000);
        assert_eq!(fs.compute_cycles(), 10_000);
        assert_eq!(fs.pe_cycles(), 100_000);
    }

    #[test]
    fn push_keeps_distinct() {
        let mut fs = FoldSet::new();
        fs.push(f(10, 100));
        fs.push(f(12, 90));
        fs.push(f(10, 100)); // not adjacent to the first — kept separate
        assert_eq!(fs.folds.len(), 3);
        assert_eq!(fs.num_folds(), 3);
    }

    #[test]
    fn rescale_pe_cycles_is_exact_and_leaves_durations_alone() {
        let mut fs = FoldSet::new();
        let mut a = f(7, 123);
        a.count = 13;
        fs.push(a);
        fs.push(f(11, 77));
        let cycles = fs.compute_cycles();
        // down-scale to an awkward target: exact despite per-fold rounding
        fs.rescale_pe_cycles(419);
        assert_eq!(fs.pe_cycles(), 419);
        assert_eq!(fs.compute_cycles(), cycles); // durations untouched
        // no-op cases
        let before = fs.folds.len();
        fs.rescale_pe_cycles(419);
        assert_eq!(fs.folds.len(), before);
    }

    #[test]
    fn accounting_sums() {
        let mut fs = FoldSet::new();
        let mut a = f(5, 50);
        a.ifmap_reads = 7;
        a.weight_reads = 3;
        a.ofmap_writes = 2;
        a.dram_read_bytes = 11;
        a.dram_write_bytes = 4;
        a.count = 3;
        fs.push(a);
        assert_eq!(fs.sram_reads(), 30);
        assert_eq!(fs.ofmap_writes(), 6);
        assert_eq!(fs.dram_read_bytes(), 33);
        assert_eq!(fs.dram_write_bytes(), 12);
    }
}
