//! Cross-request global result cache with single-flight dedup.
//!
//! The [`LayerCache`] (in [`sweep`](super::sweep)) splits
//! schedule-once/price-many *within* the process, but every request
//! still re-assembles whole-network results from per-layer lookups.
//! Production traffic is heavily repetitive — the same zoo models ×
//! popular configs — so this cache memoizes the *finished*
//! [`NetworkSim`] per (network identity, [`SimConfig::price_key`],
//! frequency) and serves repeats without touching the simulator at all.
//!
//! Two properties distinguish it from a plain memo table:
//!
//! * **Size-bounded LRU.** Entries are sharded by key hash; each shard
//!   holds a bounded number of completed results and evicts the least
//!   recently used one when full, so the cache's residency is capped
//!   regardless of traffic shape. Eviction and invalidation retract an
//!   entry atomically — a retracted entry is never served again.
//! * **Single-flight coalescing.** The first request for a missing key
//!   becomes the *leader*: it simulates once and publishes the result.
//!   Concurrent identical requests become *followers*: they block on
//!   the leader's in-flight slot (bounded by their own deadline) and
//!   receive the shared result, so N identical cells cost one
//!   simulation. Followers never feed the leader's output stream —
//!   each one re-emits frames through its own sink under its own
//!   backpressure bound. A leader that unwinds (panicking scenario)
//!   retracts its in-flight slot and wakes every follower, one of which
//!   retries as the new leader — an abandoned flight can neither stall
//!   followers nor leak its table slot.
//!
//! Keying: the network identity is a structural fingerprint (name +
//! per-layer operator/geometry), not just the model name, so inline
//! models that happen to share a name with a zoo entry can never alias.
//! `price_key` already folds in every simulation-relevant config field
//! except frequency; `freq_mhz` rides alongside because the cached
//! value carries `latency_ms`.

use super::config::SimConfig;
use super::engine::{LayerSim, NetworkSim};
use super::sweep::{simulate_network_cached, LayerCache};
use crate::nn::Network;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Cache key: structural network fingerprint × priced-config identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ResultKey {
    net: u64,
    price: u64,
    freq_mhz: u64,
}

impl ResultKey {
    fn of(net: &Network, cfg: &SimConfig) -> ResultKey {
        let mut h = DefaultHasher::new();
        net.name.hash(&mut h);
        net.layers.len().hash(&mut h);
        for l in &net.layers {
            l.op.hash(&mut h);
            l.h.hash(&mut h);
            l.w.hash(&mut h);
        }
        ResultKey { net: h.finish(), price: cfg.price_key(), freq_mhz: cfg.freq_mhz }
    }
}

/// Counters and gauges of a [`ResultCache`] at a point in time. Counters
/// (`hits`/`misses`/`coalesced`/`evicted`) are monotone; `entries` and
/// `bytes` are gauges of current residency.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResultCacheStats {
    /// Requests served from a completed entry.
    pub hits: u64,
    /// Requests that became a leader and simulated.
    pub misses: u64,
    /// Requests that joined a leader's in-flight simulation.
    pub coalesced: u64,
    /// Entries retired by the LRU bound.
    pub evicted: u64,
    /// Completed entries currently resident.
    pub entries: u64,
    /// Estimated bytes of the resident entries.
    pub bytes: u64,
}

impl ResultCacheStats {
    /// Fraction of lookups that avoided a simulation (hit or coalesced).
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits + self.coalesced;
        let total = served + self.misses;
        if total == 0 {
            0.0
        } else {
            served as f64 / total as f64
        }
    }
}

/// The leader's publication slot: followers block here until the result
/// lands (or the leader abandons the flight).
#[derive(Debug)]
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

#[derive(Debug)]
enum FlightState {
    Pending,
    Done(Arc<NetworkSim>),
    /// The leader unwound without publishing; a follower must retry.
    Abandoned,
}

/// Outcome of waiting on a [`Flight`].
enum Joined {
    Done(Arc<NetworkSim>),
    Abandoned,
    Expired,
}

impl Flight {
    fn new() -> Flight {
        Flight { state: Mutex::new(FlightState::Pending), cv: Condvar::new() }
    }

    /// Block until the leader resolves the flight, bounded by `deadline`.
    fn wait(&self, deadline: Option<Instant>) -> Joined {
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                FlightState::Done(sim) => return Joined::Done(Arc::clone(sim)),
                FlightState::Abandoned => return Joined::Abandoned,
                FlightState::Pending => {}
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let Some(left) = d.checked_duration_since(Instant::now()) else {
                        return Joined::Expired;
                    };
                    st = self.cv.wait_timeout(st, left).unwrap().0;
                }
            }
        }
    }

    /// Resolve the flight (first resolution wins) and wake all waiters.
    fn resolve(&self, terminal: FlightState) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, FlightState::Pending) {
            *st = terminal;
        }
        self.cv.notify_all();
    }
}

/// One table slot: a completed result, or the leader currently
/// producing one. In-flight slots do not count toward the LRU bound and
/// are never evicted — they retire through publish or abandonment.
enum Slot {
    Ready { sim: Arc<NetworkSim>, bytes: u64, used: u64 },
    InFlight(Arc<Flight>),
}

struct Shard {
    map: HashMap<ResultKey, Slot>,
    /// Per-shard LRU clock: bumped on every lookup, stamped into the
    /// touched entry; eviction retires the minimum stamp.
    clock: u64,
}

impl Shard {
    fn ready_count(&self) -> usize {
        self.map.values().filter(|s| matches!(s, Slot::Ready { .. })).count()
    }
}

/// RAII claim on a missing key: the holder is the single flight's
/// leader. [`LeaderGuard::publish`] installs the result; dropping the
/// guard without publishing (unwind path) retracts the in-flight slot
/// and wakes followers so one of them can retry.
struct LeaderGuard<'a> {
    cache: &'a ResultCache,
    key: ResultKey,
    flight: Arc<Flight>,
    published: bool,
}

impl LeaderGuard<'_> {
    fn publish(mut self, sim: Arc<NetworkSim>) {
        self.published = true;
        self.cache.install(self.key, &self.flight, Arc::clone(&sim));
        self.flight.resolve(FlightState::Done(sim));
    }
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        if self.published {
            return;
        }
        self.cache.retract(self.key, &self.flight);
        self.flight.resolve(FlightState::Abandoned);
    }
}

/// What a lookup found.
enum Lookup<'a> {
    Ready(Arc<NetworkSim>),
    Lead(LeaderGuard<'a>),
    Join(Arc<Flight>),
}

/// Sharded, size-bounded, single-flight global result cache. See the
/// module docs for semantics; [`ResultCache::simulate`] is the one
/// entry point the serving layer uses.
pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    /// Completed-entry bound per shard (in-flight slots excluded).
    per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    evicted: AtomicU64,
    entries: AtomicU64,
    bytes: AtomicU64,
}

/// Default shard count for [`ResultCache::new`].
pub const DEFAULT_SHARDS: usize = 16;

impl ResultCache {
    /// A cache bounded to (at most) `capacity` completed entries,
    /// spread over up to [`DEFAULT_SHARDS`] shards. `capacity` is
    /// clamped to ≥ 1 — an unbounded or zero-sized cache is not a
    /// configuration; callers gate "off" by not constructing one.
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache::with_shards(capacity, DEFAULT_SHARDS)
    }

    /// Explicit shard count (tests pin `shards == 1` to observe exact
    /// global LRU order). The per-shard bound is `capacity / shards`
    /// (floored, ≥ 1, shards clamped to ≤ capacity), so total residency
    /// never exceeds `capacity`.
    pub fn with_shards(capacity: usize, shards: usize) -> ResultCache {
        let capacity = capacity.max(1);
        let shards = shards.clamp(1, capacity);
        ResultCache {
            shards: (0..shards)
                .map(|_| Mutex::new(Shard { map: HashMap::new(), clock: 0 }))
                .collect(),
            per_shard: (capacity / shards).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            entries: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: ResultKey) -> &Mutex<Shard> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Simulate `net` under `cfg` through the cache: a hit returns the
    /// shared result, a miss simulates (through the shared layer cache)
    /// and publishes, and a concurrent identical request coalesces onto
    /// the in-flight leader. Returns `None` only when `deadline`
    /// expired while waiting on another request's in-flight simulation
    /// (never when this caller is the leader).
    pub fn simulate(
        &self,
        net: &Network,
        cfg: &SimConfig,
        layers: &LayerCache,
        deadline: Option<Instant>,
    ) -> Option<Arc<NetworkSim>> {
        let key = ResultKey::of(net, cfg);
        loop {
            match self.begin(key) {
                Lookup::Ready(sim) => return Some(sim),
                Lookup::Lead(guard) => {
                    let sim = Arc::new(simulate_network_cached(net, cfg, layers));
                    guard.publish(Arc::clone(&sim));
                    return Some(sim);
                }
                Lookup::Join(flight) => match flight.wait(deadline) {
                    Joined::Done(sim) => {
                        self.coalesced.fetch_add(1, Ordering::Relaxed);
                        return Some(sim);
                    }
                    // Leader unwound: loop back and retry (likely as
                    // the new leader).
                    Joined::Abandoned => continue,
                    Joined::Expired => return None,
                },
            }
        }
    }

    /// One lookup step: hit, lead, or join.
    fn begin(&self, key: ResultKey) -> Lookup<'_> {
        let mut s = self.shard_of(key).lock().unwrap();
        s.clock += 1;
        let now = s.clock;
        match s.map.get_mut(&key) {
            Some(Slot::Ready { sim, used, .. }) => {
                *used = now;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Lookup::Ready(Arc::clone(sim))
            }
            Some(Slot::InFlight(f)) => Lookup::Join(Arc::clone(f)),
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let flight = Arc::new(Flight::new());
                s.map.insert(key, Slot::InFlight(Arc::clone(&flight)));
                Lookup::Lead(LeaderGuard { cache: self, key, flight, published: false })
            }
        }
    }

    /// Install a published result over its in-flight slot, then enforce
    /// the shard's LRU bound. No-op if the slot was invalidated while
    /// the flight ran (the waiting followers still get the result
    /// through the flight itself — they asked before the invalidation —
    /// but later lookups must re-simulate).
    fn install(&self, key: ResultKey, flight: &Arc<Flight>, sim: Arc<NetworkSim>) {
        let mut s = self.shard_of(key).lock().unwrap();
        match s.map.get(&key) {
            Some(Slot::InFlight(f)) if Arc::ptr_eq(f, flight) => {}
            _ => return,
        }
        s.clock += 1;
        let bytes = cost_of(&sim);
        let used = s.clock;
        s.map.insert(key, Slot::Ready { sim, bytes, used });
        self.entries.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        while s.ready_count() > self.per_shard {
            let oldest = s
                .map
                .iter()
                .filter_map(|(k, slot)| match slot {
                    Slot::Ready { used, .. } => Some((*used, *k)),
                    Slot::InFlight(_) => None,
                })
                .min_by_key(|&(used, _)| used)
                .map(|(_, k)| k)
                .expect("ready_count > 0");
            if let Some(Slot::Ready { bytes, .. }) = s.map.remove(&oldest) {
                self.evicted.fetch_add(1, Ordering::Relaxed);
                self.entries.fetch_sub(1, Ordering::Relaxed);
                self.bytes.fetch_sub(bytes, Ordering::Relaxed);
            }
        }
    }

    /// Remove the in-flight slot of an abandoned flight (only if it is
    /// still *that* flight — an invalidation may already have cleared
    /// it, or a later leader may occupy the key).
    fn retract(&self, key: ResultKey, flight: &Arc<Flight>) {
        let mut s = self.shard_of(key).lock().unwrap();
        if matches!(s.map.get(&key), Some(Slot::InFlight(f)) if Arc::ptr_eq(f, flight)) {
            s.map.remove(&key);
        }
    }

    /// Drop the entry for (`net`, `cfg`), completed or in flight. A
    /// retracted entry is never served to a later lookup.
    pub fn invalidate(&self, net: &Network, cfg: &SimConfig) {
        let key = ResultKey::of(net, cfg);
        let mut s = self.shard_of(key).lock().unwrap();
        if let Some(Slot::Ready { bytes, .. }) = s.map.remove(&key) {
            self.entries.fetch_sub(1, Ordering::Relaxed);
            self.bytes.fetch_sub(bytes, Ordering::Relaxed);
        }
    }

    /// Drop every completed entry (in-flight leaders still publish to
    /// their followers, but nothing re-enters the table for them).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            s.map.retain(|_, slot| matches!(slot, Slot::InFlight(_)));
        }
        // Gauges rebuilt from scratch: everything Ready is gone.
        self.entries.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }

    /// Counter/gauge snapshot.
    pub fn stats(&self) -> ResultCacheStats {
        ResultCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            entries: self.entries.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

/// Estimated heap residency of one cached result.
fn cost_of(sim: &NetworkSim) -> u64 {
    let layers: usize = sim
        .layers
        .iter()
        .map(|l| std::mem::size_of::<LayerSim>() + l.name.len())
        .sum();
    (std::mem::size_of::<NetworkSim>() + sim.network.len() + sim.config_label.len() + layers)
        as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;
    use crate::sim::simulate_network;
    use std::thread;
    use std::time::Duration;

    fn net(name: &str) -> Network {
        models::by_name(name).unwrap()
    }

    #[test]
    fn hit_returns_identical_result_without_resimulating() {
        let rc = ResultCache::new(8);
        let layers = LayerCache::new();
        let n = net("mobilenet-v2");
        let cfg = SimConfig::default();
        let a = rc.simulate(&n, &cfg, &layers, None).unwrap();
        let lc_before = layers.stats();
        let b = rc.simulate(&n, &cfg, &layers, None).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must serve the resident result");
        assert_eq!(layers.stats().hits, lc_before.hits, "hit must not touch the layer cache");
        let direct = simulate_network(&n, &cfg);
        assert_eq!(a.total_cycles, direct.total_cycles);
        assert_eq!(a.latency_ms, direct.latency_ms);
        let s = rc.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
    }

    #[test]
    fn frequency_and_structure_are_part_of_the_key() {
        let rc = ResultCache::new(8);
        let layers = LayerCache::new();
        let n = net("mobilenet-v2");
        let base = SimConfig::default();
        let slow = SimConfig { freq_mhz: 500, ..SimConfig::default() };
        let a = rc.simulate(&n, &base, &layers, None).unwrap();
        let b = rc.simulate(&n, &slow, &layers, None).unwrap();
        assert_ne!(a.latency_ms, b.latency_ms, "freq-distinct configs must not alias");
        // same name, different structure (inline-model aliasing guard)
        let mut other = net("mobilenet-v3-small");
        other.name = n.name.clone();
        let c = rc.simulate(&other, &base, &layers, None).unwrap();
        assert_ne!(a.total_cycles, c.total_cycles);
        assert_eq!(rc.stats().misses, 3);
    }

    #[test]
    fn new_operators_and_dataflows_never_alias_cache_entries() {
        use crate::nn::graph::NetBuilder;
        use crate::nn::ops::Act;
        use crate::sim::config::ALL_DATAFLOWS;

        // Twin networks: same name, same geometry, same MAC count —
        // only the dilation field of the op distinguishes them. The
        // structural fingerprint must still tell them apart.
        let twin = |dilation: usize| {
            let mut b = NetBuilder::new("twin", 32, 8);
            b.dilated("ctx", 3, 1, dilation, 16, Act::Relu);
            b.build()
        };
        let (d1, d2) = (twin(1), twin(2));
        assert_eq!(d1.total_macs(), d2.total_macs(), "twins must agree on MACs");
        let cfg = SimConfig::default();
        assert_ne!(
            ResultKey::of(&d1, &cfg),
            ResultKey::of(&d2, &cfg),
            "dilation must be part of the structural fingerprint"
        );
        let rc = ResultCache::new(8);
        let layers = LayerCache::new();
        rc.simulate(&d1, &cfg, &layers, None).unwrap();
        rc.simulate(&d2, &cfg, &layers, None).unwrap();
        let s = rc.stats();
        assert_eq!((s.misses, s.entries), (2, 2), "twins must occupy two entries");

        // Every dataflow pair (os/ws/is) keys a distinct entry for the
        // same network — `is` can never serve an os-priced result.
        let keys: Vec<ResultKey> = ALL_DATAFLOWS
            .iter()
            .map(|&df| ResultKey::of(&d2, &SimConfig { dataflow: df, ..SimConfig::default() }))
            .collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j], "dataflows {i} and {j} alias");
            }
        }
    }

    #[test]
    fn lru_eviction_under_pressure_retires_oldest_first() {
        // One shard: exact global LRU order is observable.
        let rc = ResultCache::with_shards(2, 1);
        let layers = LayerCache::new();
        let n = net("mobilenet-v2");
        let cfgs: Vec<SimConfig> =
            [8, 16, 32].iter().map(|&s| SimConfig::with_size(s)).collect();
        rc.simulate(&n, &cfgs[0], &layers, None).unwrap(); // A
        rc.simulate(&n, &cfgs[1], &layers, None).unwrap(); // B
        rc.simulate(&n, &cfgs[0], &layers, None).unwrap(); // touch A → B is LRU
        rc.simulate(&n, &cfgs[2], &layers, None).unwrap(); // C evicts B
        let s = rc.stats();
        assert_eq!((s.entries, s.evicted), (2, 1));
        let before = rc.stats();
        rc.simulate(&n, &cfgs[0], &layers, None).unwrap(); // A survived
        assert_eq!(rc.stats().hits, before.hits + 1);
        rc.simulate(&n, &cfgs[1], &layers, None).unwrap(); // B was evicted
        assert_eq!(rc.stats().misses, before.misses + 1);
        // the bound held throughout
        assert!(rc.stats().entries <= 2);
    }

    #[test]
    fn invalidated_entry_is_never_served_again() {
        let rc = ResultCache::new(8);
        let layers = LayerCache::new();
        let n = net("mobilenet-v3-small");
        let cfg = SimConfig::default();
        rc.simulate(&n, &cfg, &layers, None).unwrap();
        assert_eq!(rc.stats().entries, 1);
        rc.invalidate(&n, &cfg);
        let s = rc.stats();
        assert_eq!((s.entries, s.bytes), (0, 0));
        rc.simulate(&n, &cfg, &layers, None).unwrap();
        assert_eq!(rc.stats().misses, 2, "post-invalidation lookup must re-simulate");
        rc.clear();
        assert_eq!(rc.stats().entries, 0);
        rc.simulate(&n, &cfg, &layers, None).unwrap();
        assert_eq!(rc.stats().misses, 3);
    }

    #[test]
    fn single_flight_coalesces_concurrent_identical_requests() {
        // Drive the leader/follower protocol deterministically: take the
        // leader guard by hand, park followers, then publish.
        let rc = Arc::new(ResultCache::new(8));
        let layers = Arc::new(LayerCache::new());
        let n = Arc::new(net("mobilenet-v3-small"));
        let cfg = SimConfig::default();
        let key = ResultKey::of(&n, &cfg);
        let Lookup::Lead(guard) = rc.begin(key) else { panic!("first lookup must lead") };
        let followers: Vec<_> = (0..4)
            .map(|_| {
                let (rc, layers, n) = (Arc::clone(&rc), Arc::clone(&layers), Arc::clone(&n));
                let cfg = cfg.clone();
                thread::spawn(move || rc.simulate(&n, &cfg, &layers, None).unwrap())
            })
            .collect();
        // Followers are blocked on the flight; nobody simulates.
        thread::sleep(Duration::from_millis(50));
        assert_eq!(layers.stats().misses, 0, "a follower simulated past the leader");
        let sim = Arc::new(simulate_network_cached(&n, &cfg, &layers));
        guard.publish(Arc::clone(&sim));
        for f in followers {
            let got = f.join().unwrap();
            assert!(Arc::ptr_eq(&got, &sim), "follower must get the leader's result");
        }
        let s = rc.stats();
        assert_eq!(s.misses, 1, "exactly one leader");
        assert_eq!(s.hits + s.coalesced, 4, "every follower served without simulating");
    }

    #[test]
    fn abandoned_leader_wakes_followers_and_a_retry_succeeds() {
        let rc = Arc::new(ResultCache::new(8));
        let layers = Arc::new(LayerCache::new());
        let n = Arc::new(net("mobilenet-v3-small"));
        let cfg = SimConfig::default();
        let key = ResultKey::of(&n, &cfg);
        let guard = match rc.begin(key) {
            Lookup::Lead(g) => g,
            _ => panic!("first lookup must lead"),
        };
        let follower = {
            let (rc, layers, n) = (Arc::clone(&rc), Arc::clone(&layers), Arc::clone(&n));
            let cfg = cfg.clone();
            thread::spawn(move || rc.simulate(&n, &cfg, &layers, None).unwrap())
        };
        thread::sleep(Duration::from_millis(30));
        drop(guard); // leader dies without publishing
        let got = follower.join().unwrap();
        let direct = simulate_network(&n, &cfg);
        assert_eq!(got.total_cycles, direct.total_cycles);
        // the follower retried as the new leader — no leaked flight
        assert_eq!(rc.stats().misses, 2);
        assert_eq!(rc.stats().entries, 1);
    }

    #[test]
    fn follower_deadline_expires_without_stalling() {
        let rc = Arc::new(ResultCache::new(8));
        let n = net("mobilenet-v3-small");
        let cfg = SimConfig::default();
        let key = ResultKey::of(&n, &cfg);
        let guard = match rc.begin(key) {
            Lookup::Lead(g) => g,
            _ => panic!("lead"),
        };
        let layers = LayerCache::new();
        let deadline = Some(Instant::now() + Duration::from_millis(40));
        assert!(
            rc.simulate(&n, &cfg, &layers, deadline).is_none(),
            "an expired follower must report the deadline, not block"
        );
        drop(guard);
    }
}
