//! Cycle-granular trace expansion.
//!
//! SCALE-Sim can emit cycle-by-cycle SRAM traces; we reproduce that as an
//! *expansion* of the fold schedule (folds are exact run-length-compressed
//! cycle behaviour, so expansion is lossless for the quantities we model).
//! Used by the `fuseconv trace` CLI subcommand and by tests that want to
//! cross-check fold accounting against a flat cycle walk.

use super::fold::FoldSet;

/// One traced cycle window (all cycles of a fold share the same rates).
#[derive(Debug, Clone, PartialEq)]
pub struct CycleRecord {
    pub cycle_start: u64,
    pub cycles: u64,
    /// Active PEs during this window (average).
    pub active_pes: f64,
    /// SRAM words touched per cycle.
    pub ifmap_rate: f64,
    pub weight_rate: f64,
    pub ofmap_rate: f64,
    /// DRAM bytes per cycle.
    pub dram_rate: f64,
}

/// Expand a fold schedule into trace windows (one per fold occurrence,
/// capped at `max_windows` to bound output size; repeated folds collapse
/// into a single window covering all repetitions).
pub fn expand(fs: &FoldSet, max_windows: usize) -> Vec<CycleRecord> {
    let mut out = Vec::new();
    let mut t = 0u64;
    for f in &fs.folds {
        if out.len() >= max_windows {
            break;
        }
        let cycles = f.duration * f.count;
        if f.duration == 0 || cycles == 0 {
            continue;
        }
        let d = f.duration as f64;
        out.push(CycleRecord {
            cycle_start: t,
            cycles,
            active_pes: f.pe_cycles as f64 / d,
            ifmap_rate: f.ifmap_reads as f64 / d,
            weight_rate: f.weight_reads as f64 / d,
            ofmap_rate: f.ofmap_writes as f64 / d,
            dram_rate: (f.dram_read_bytes + f.dram_write_bytes) as f64 / d,
        });
        t += cycles;
    }
    out
}

/// Render a trace as CSV (header + rows).
pub fn to_csv(records: &[CycleRecord]) -> String {
    let mut s = String::from("cycle_start,cycles,active_pes,ifmap_rate,weight_rate,ofmap_rate,dram_bytes_per_cycle\n");
    for r in records {
        s.push_str(&format!(
            "{},{},{:.2},{:.2},{:.2},{:.2},{:.2}\n",
            r.cycle_start, r.cycles, r.active_pes, r.ifmap_rate, r.weight_rate, r.ofmap_rate, r.dram_rate
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, OpKind};
    use crate::sim::engine::schedule_layer;
    use crate::sim::SimConfig;

    #[test]
    fn expansion_covers_all_cycles() {
        let cfg = SimConfig::default();
        let l = Layer::new("pw", OpKind::Pointwise { cin: 32, cout: 64 }, 28, 28);
        let fs = schedule_layer(&l, &cfg);
        let trace = expand(&fs, usize::MAX);
        let covered: u64 = trace.iter().map(|r| r.cycles).sum();
        assert_eq!(covered, fs.compute_cycles());
        // windows are contiguous
        let mut t = 0;
        for r in &trace {
            assert_eq!(r.cycle_start, t);
            t += r.cycles;
        }
    }

    #[test]
    fn pe_cycles_reconstructable_from_trace() {
        let cfg = SimConfig::default();
        let l = Layer::new("dw", OpKind::Depthwise { k: 3, stride: 1, c: 16 }, 28, 28);
        let fs = schedule_layer(&l, &cfg);
        let trace = expand(&fs, usize::MAX);
        let pe: f64 = trace.iter().map(|r| r.active_pes * r.cycles as f64).sum();
        assert!((pe - fs.pe_cycles() as f64).abs() < 1.0);
    }

    #[test]
    fn cap_respected() {
        let cfg = SimConfig::default();
        let l = Layer::new("c", OpKind::Conv2d { k: 3, stride: 1, cin: 64, cout: 128 }, 56, 56);
        let fs = schedule_layer(&l, &cfg);
        let trace = expand(&fs, 3);
        assert!(trace.len() <= 3);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let cfg = SimConfig::default();
        let l = Layer::new("pw", OpKind::Pointwise { cin: 8, cout: 8 }, 8, 8);
        let fs = schedule_layer(&l, &cfg);
        let csv = to_csv(&expand(&fs, 10));
        assert!(csv.starts_with("cycle_start,"));
        assert!(csv.lines().count() >= 2);
    }
}
