//! ST-OS: the paper's Spatial-Tiled Output-Stationary dataflow (§3.3–3.4).
//!
//! A FuSe layer is a set of *independent 1D convolutions* (one per spatial
//! slice per channel). Each 1D conv maps to ONE ROW of the array: the row's
//! `cols` PEs each hold one adjacent output (output-stationary) while the
//! per-row broadcast link feeds one filter tap per cycle — so a work unit
//! (one row × one tile of `cols` outputs) takes exactly `k` compute cycles
//! and keeps every PE of the row busy. Because weights broadcast rather
//! than skew through the array, consecutive units pipeline back-to-back:
//! the only skew cost is a single array fill at layer start. This is the
//! co-design win over plain OS, where every fold pays the skew.

use super::config::{MappingPolicy, SimConfig};
use super::fold::{Fold, FoldSet};

/// A set of independent 1D convolutions with identical geometry.
#[derive(Debug, Clone, Copy)]
pub struct Conv1dSet {
    /// Distinct filters (channels); each has `slices_per_channel` slices.
    pub channels: usize,
    /// 1D input slices per channel (= output rows for a row-FuSe op).
    pub slices_per_channel: usize,
    /// Output length of each 1D conv.
    pub out_len: usize,
    /// Filter taps.
    pub k: usize,
    /// Convolution stride along the slice.
    pub stride: usize,
    /// Unique input elements (whole ifmap half) for DRAM accounting.
    pub ifmap_unique: u64,
}

/// Distinct filters resident across `r_used` concurrently-scheduled rows,
/// under the given mapping policy (paper §3.4). Spatial-first groups rows
/// by channel so one broadcast serves the group; channels-first gives each
/// row its own filter (more SRAM reads, no extra broadcast circuitry);
/// hybrid = channels-first until channels run out, then spill spatially.
fn distinct_filters(policy: MappingPolicy, r_used: usize, set: &Conv1dSet) -> usize {
    match policy {
        MappingPolicy::SpatialFirst => r_used.div_ceil(set.slices_per_channel.max(1)),
        MappingPolicy::ChannelsFirst | MappingPolicy::Hybrid => r_used.min(set.channels),
    }
}

/// Schedule a FuSe layer's 1D convolutions under ST-OS.
pub fn stos_schedule(set: &Conv1dSet, cfg: &SimConfig) -> FoldSet {
    assert!(cfg.stos, "ST-OS schedule requested on an array without broadcast links");
    let (r, c) = (cfg.rows, cfg.cols);
    let bpe = cfg.bytes_per_elem as u64;
    let num_slices = set.channels * set.slices_per_channel;
    let col_tiles = set.out_len.div_ceil(c);
    let total_out = (num_slices * set.out_len) as u64;
    // Ifmap DRAM: each slice streams once; adjacent col tiles share a
    // (k - stride) halo, refetched per extra tile.
    let halo = (set.k.saturating_sub(set.stride)) as u64;
    let ifmap_dram_total =
        set.ifmap_unique * bpe + (col_tiles as u64 - 1) * num_slices as u64 * halo * bpe;

    let mut fs = FoldSet::new();
    // One-time array fill: inputs skew into rows at layer start.
    let mut fill = Fold::once((r + c - 2) as u64);
    // First working set arrives during fill.
    fill.dram_read_bytes = (set.channels * set.k) as u64 * bpe; // all filters (tiny)
    fs.push(fill);

    for tile in 0..col_tiles {
        let c_used = if tile == col_tiles - 1 { set.out_len - tile * c } else { c };
        // All slices need this tile; slices are laid across rows in
        // mapping-policy order, `r` per round.
        let rounds = num_slices.div_ceil(r);
        for round in 0..rounds {
            let r_used = if round == rounds - 1 { num_slices - round * r } else { r };
            let filters = distinct_filters(cfg.mapping, r_used, set);
            // `k` broadcast cycles; rounds pipeline back-to-back because
            // the next round's inputs stream in behind the current one.
            let mut f = Fold::once(set.k as u64);
            f.pe_cycles = (r_used * c_used * set.k) as u64;
            // Each row consumes the input span behind c_used outputs.
            let span = ((c_used - 1) * set.stride + set.k) as u64;
            f.ifmap_reads = r_used as u64 * span;
            f.weight_reads = (filters * set.k) as u64;
            f.ofmap_writes = (r_used * c_used) as u64;
            // DRAM amortized evenly over rounds: steady streaming is the
            // ST-OS signature Fig 11 shows (high average, similar max).
            let total_rounds = (col_tiles * rounds).max(1) as u64;
            f.dram_read_bytes = ifmap_dram_total / total_rounds;
            f.dram_write_bytes = total_out * bpe / total_rounds;
            fs.push(f);
        }
    }
    fs
}

/// Fallback when the array lacks ST-OS support: each 1D conv is a tiny
/// single-column GEMM (m = out_len, n = 1, k = taps) — the §2.3 pathology.
pub fn no_stos_schedule(set: &Conv1dSet, cfg: &SimConfig) -> FoldSet {
    use super::gemm::{os_schedule, Gemm};
    let per_slice = Gemm {
        m: set.out_len,
        n: 1,
        k: set.k,
        ifmap_unique: set.ifmap_unique / (set.channels * set.slices_per_channel).max(1) as u64,
        weight_unique: set.k as u64,
    };
    let one = os_schedule(&per_slice, cfg);
    let mut fs = FoldSet::new();
    let n = (set.channels * set.slices_per_channel) as u64;
    for f in one.folds {
        let mut f = f;
        f.count *= n;
        fs.push(f);
    }
    fs
}

#[cfg(test)]
mod tests {
    use super::*;

    /// MobileNetV2-ish FuSe-Half row op: 56×56, 96 channels half = 48.
    fn example() -> Conv1dSet {
        Conv1dSet {
            channels: 48,
            slices_per_channel: 56,
            out_len: 56,
            k: 3,
            stride: 1,
            ifmap_unique: 56 * 56 * 48,
        }
    }

    #[test]
    fn mac_conservation() {
        let set = example();
        let cfg = SimConfig::default();
        let fs = stos_schedule(&set, &cfg);
        let macs = (set.channels * set.slices_per_channel * set.out_len * set.k) as u64;
        assert_eq!(fs.pe_cycles(), macs);
    }

    #[test]
    fn high_utilization_vs_plain_os() {
        let set = example();
        let cfg = SimConfig::default();
        let st = stos_schedule(&set, &cfg);
        let st_util = st.pe_cycles() as f64 / (st.compute_cycles() * 256) as f64;
        assert!(st_util > 0.5, "ST-OS util {st_util}");

        let fallback = no_stos_schedule(&set, &cfg);
        let fb_util =
            fallback.pe_cycles() as f64 / (fallback.compute_cycles() * 256) as f64;
        assert!(fb_util < 0.02, "fallback util {fb_util}");
        // the speedup of the co-design on this layer
        assert!(fallback.compute_cycles() > 20 * st.compute_cycles());
    }

    #[test]
    fn small_layer_lower_utilization() {
        // 7×7 late layer: too little parallelism to fill 16 columns
        let set = Conv1dSet {
            channels: 80,
            slices_per_channel: 7,
            out_len: 7,
            k: 3,
            stride: 1,
            ifmap_unique: 7 * 7 * 80,
        };
        let cfg = SimConfig::default();
        let fs = stos_schedule(&set, &cfg);
        let util = fs.pe_cycles() as f64 / (fs.compute_cycles() * 256) as f64;
        // Fig 10: final bottlenecks ~50-60%
        assert!(util < 0.7, "util {util}");
        assert!(util > 0.2, "util {util}");
    }

    #[test]
    fn mapping_policy_changes_weight_reads() {
        let set = example();
        let mut cfg = SimConfig::default();
        cfg.mapping = MappingPolicy::ChannelsFirst;
        let cf: u64 = stos_schedule(&set, &cfg)
            .folds
            .iter()
            .map(|f| f.weight_reads * f.count)
            .sum();
        cfg.mapping = MappingPolicy::SpatialFirst;
        let sf: u64 = stos_schedule(&set, &cfg)
            .folds
            .iter()
            .map(|f| f.weight_reads * f.count)
            .sum();
        // spatial-first shares one broadcast across rows of a channel
        assert!(sf < cf, "spatial {sf} !< channels {cf}");
        // identical compute cycles either way
        cfg.mapping = MappingPolicy::ChannelsFirst;
        let a = stos_schedule(&set, &cfg).compute_cycles();
        cfg.mapping = MappingPolicy::SpatialFirst;
        let b = stos_schedule(&set, &cfg).compute_cycles();
        assert_eq!(a, b);
    }

    #[test]
    fn stride_two_consumes_wider_span() {
        let s1 = Conv1dSet { stride: 1, ..example() };
        let s2 = Conv1dSet { stride: 2, out_len: 28, ..example() };
        let cfg = SimConfig::default();
        let r1 = stos_schedule(&s1, &cfg);
        let r2 = stos_schedule(&s2, &cfg);
        // stride 2 halves outputs => fewer cycles
        assert!(r2.compute_cycles() < r1.compute_cycles());
    }

    #[test]
    #[should_panic(expected = "without broadcast links")]
    fn stos_requires_hardware_support() {
        let cfg = SimConfig::default().without_stos();
        stos_schedule(&example(), &cfg);
    }

    #[test]
    fn dram_reads_cover_ifmap_once() {
        let set = example();
        let cfg = SimConfig::default();
        let fs = stos_schedule(&set, &cfg);
        assert!(fs.dram_read_bytes() >= set.ifmap_unique);
        // and not wildly more (halo only)
        assert!(fs.dram_read_bytes() < set.ifmap_unique * 3);
    }
}
