//! Layer lowering + the per-layer / whole-network simulation drivers.
//!
//! This is the SCALE-Sim-FuSe equivalent: every operator in the IR lowers
//! to a fold schedule under the configured dataflow (OS/WS for GEMM-shaped
//! ops; ST-OS for FuSe ops when the hardware supports it), then the memory
//! model prices stalls and bandwidth.

use super::config::{Dataflow, SimConfig};
use super::fold::{Fold, FoldSet};
use super::gemm::{is_schedule, os_schedule, ws_schedule, Gemm};
use super::memory::{apply as apply_memory, MemResult};
use super::stos::{no_stos_schedule, stos_schedule, Conv1dSet};
use crate::nn::{Layer, Network, OpClass, OpKind};

/// Simulation result for one layer.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub name: String,
    pub class: OpClass,
    pub block: Option<usize>,
    pub macs: u64,
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub total_cycles: u64,
    /// Σ active-PE cycles (= MACs executed on the array).
    pub pe_cycles: u64,
    /// PE-array utilization over the layer's residency.
    pub utilization: f64,
    pub mem: MemResult,
}

/// Whole-network simulation result.
#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub network: String,
    pub config_label: String,
    pub layers: Vec<LayerSim>,
    pub total_cycles: u64,
    pub latency_ms: f64,
    /// PE-array size of the config this was simulated under (carried at
    /// construction; utilization denominators must not be reverse-
    /// engineered from per-layer utilization, which is wrong for arrays
    /// whose layers all have zero utilization).
    pub num_pes: usize,
}

/// Lower one layer to its fold schedule.
pub fn schedule_layer(layer: &Layer, cfg: &SimConfig) -> FoldSet {
    let (oh, ow) = (layer.out_h(), layer.out_w());
    let gemm_sched = |g: &Gemm| match cfg.dataflow {
        Dataflow::OutputStationary => os_schedule(g, cfg),
        Dataflow::WeightStationary => ws_schedule(g, cfg),
        Dataflow::InputStationary => is_schedule(g, cfg),
    };
    match layer.op {
        OpKind::Conv2d { k, cin, cout, .. } => gemm_sched(&Gemm {
            m: oh * ow,
            n: cout,
            k: k * k * cin,
            ifmap_unique: (layer.h * layer.w * cin) as u64,
            weight_unique: (k * k * cin * cout) as u64,
        }),
        OpKind::Pointwise { cin, cout } => gemm_sched(&Gemm {
            m: oh * ow,
            n: cout,
            k: cin,
            ifmap_unique: (layer.h * layer.w * cin) as u64,
            weight_unique: (cin * cout) as u64,
        }),
        OpKind::Fc { cin, cout } => gemm_sched(&Gemm {
            m: 1,
            n: cout,
            k: cin,
            ifmap_unique: cin as u64,
            weight_unique: (cin * cout) as u64,
        }),
        OpKind::Depthwise { k, c, .. } => {
            // §2.3: no cross-channel reuse — each channel is an independent
            // single-column GEMM; the array repeats it `c` times.
            let per_channel = Gemm {
                m: oh * ow,
                n: 1,
                k: k * k,
                ifmap_unique: (layer.h * layer.w) as u64,
                weight_unique: (k * k) as u64,
            };
            let one = gemm_sched(&per_channel);
            let mut fs = FoldSet::new();
            for f in one.folds {
                let mut f = f;
                f.count *= c as u64;
                fs.push(f);
            }
            fs
        }
        OpKind::FuseRow { k, stride, c } => {
            let set = Conv1dSet {
                channels: c,
                slices_per_channel: oh, // output rows (vertical subsample)
                out_len: ow,
                k,
                stride,
                ifmap_unique: (layer.h * layer.w * c) as u64,
            };
            if cfg.stos {
                stos_schedule(&set, cfg)
            } else {
                no_stos_schedule(&set, cfg)
            }
        }
        OpKind::FuseCol { k, stride, c } => {
            let set = Conv1dSet {
                channels: c,
                slices_per_channel: ow, // output columns
                out_len: oh,
                k,
                stride,
                ifmap_unique: (layer.h * layer.w * c) as u64,
            };
            if cfg.stos {
                stos_schedule(&set, cfg)
            } else {
                no_stos_schedule(&set, cfg)
            }
        }
        OpKind::SqueezeExcite { c, reduced } => {
            // pool (adder tree) + 2 tiny GEMVs + scale
            let mut fs = FoldSet::new();
            fs.push(Fold::once((layer.h * layer.w * c).div_ceil(cfg.cols) as u64));
            for g in [
                Gemm { m: 1, n: reduced, k: c, ifmap_unique: c as u64, weight_unique: (c * reduced) as u64 },
                Gemm { m: 1, n: c, k: reduced, ifmap_unique: reduced as u64, weight_unique: (c * reduced) as u64 },
            ] {
                for f in gemm_sched(&g).folds {
                    fs.push(f);
                }
            }
            fs.push(Fold::once((layer.h * layer.w * c).div_ceil(cfg.cols) as u64));
            fs
        }
        OpKind::GlobalPool { c } => {
            let mut f = Fold::once((layer.h * layer.w * c).div_ceil(cfg.cols) as u64);
            f.dram_read_bytes = (layer.h * layer.w * c * cfg.bytes_per_elem) as u64;
            f.dram_write_bytes = (c * cfg.bytes_per_elem) as u64;
            let mut fs = FoldSet::new();
            fs.push(f);
            fs
        }
        OpKind::Add { c } => {
            let elems = layer.h * layer.w * c;
            let mut f = Fold::once(elems.div_ceil(cfg.cols) as u64);
            f.dram_read_bytes = (2 * elems * cfg.bytes_per_elem) as u64;
            f.dram_write_bytes = (elems * cfg.bytes_per_elem) as u64;
            let mut fs = FoldSet::new();
            fs.push(f);
            fs
        }
        OpKind::Dilated { k, dilation, cin, cout, .. } => {
            // The k-dim the array actually streams depends on the dataflow.
            // os/ws im2col walks the *effective* window — every tap slot of
            // the `k_eff × k_eff` receptive field occupies a reduction beat
            // even though only `k²` of them hold real weights (EcoFlow's
            // dilated-conv pathology). Input-stationary streams only the
            // compressed real taps: the pinned inputs are addressed
            // directly, no window walk to pad.
            let taps = match cfg.dataflow {
                Dataflow::InputStationary => k * k,
                _ => {
                    let keff = OpKind::effective_k(k, dilation);
                    keff * keff
                }
            };
            let mut fs = gemm_sched(&Gemm {
                m: oh * ow,
                n: cout,
                k: taps * cin,
                ifmap_unique: (layer.h * layer.w * cin) as u64,
                weight_unique: (k * k * cin * cout) as u64,
            });
            // Array residency covers the padded taps; arithmetic is only
            // the dense-kernel share.
            fs.rescale_pe_cycles(layer.macs());
            fs
        }
        OpKind::Transposed { k, stride, cin, cout } => match cfg.dataflow {
            // Input-stationary computes the compact scatter GEMM: every
            // *input* pixel is pinned once and its k²·cout contributions
            // stream out — no zeros enter the array.
            Dataflow::InputStationary => gemm_sched(&Gemm {
                m: layer.h * layer.w,
                n: k * k * cout,
                k: cin,
                ifmap_unique: (layer.h * layer.w * cin) as u64,
                weight_unique: (k * k * cin * cout) as u64,
            }),
            // os/ws lower via zero-insertion: conv over the s×-upsampled
            // ifmap, so the GEMM is stride² larger than the useful work.
            // Only 1/stride² of the streamed input slots are real; the
            // rescale books the array-residency waste as utilization loss
            // (EcoFlow's transposed-conv pathology).
            _ => {
                let mut fs = gemm_sched(&Gemm {
                    m: oh * ow,
                    n: cout,
                    k: k * k * cin,
                    // DRAM holds only the real (pre-insertion) inputs.
                    ifmap_unique: (layer.h * layer.w * cin) as u64,
                    weight_unique: (k * k * cin * cout) as u64,
                });
                debug_assert!(stride >= 1);
                fs.rescale_pe_cycles(layer.macs());
                fs
            }
        },
        OpKind::Grouped { k, groups, cin, cout, .. } => {
            // Like depthwise (§2.3) generalized: `groups` independent
            // GEMMs over cin/g → cout/g channel slices. No cross-group
            // reuse — when cout/g underfills the columns (os) or
            // k²·cin/g underfills the rows (ws), the idle PEs are the
            // grouped-conv utilization loss DRACO co-optimizes against.
            let g = groups.max(1);
            let (cing, coutg) = (cin / g, cout / g);
            let per_group = Gemm {
                m: oh * ow,
                n: coutg.max(1),
                k: (k * k * cing).max(1),
                ifmap_unique: (layer.h * layer.w * cing.max(1)) as u64,
                weight_unique: (k * k * cing.max(1) * coutg.max(1)) as u64,
            };
            let one = gemm_sched(&per_group);
            let mut fs = FoldSet::new();
            for f in one.folds {
                let mut f = f;
                f.count *= g as u64;
                fs.push(f);
            }
            fs.rescale_pe_cycles(layer.macs());
            fs
        }
    }
}

/// Price an already-lowered schedule: memory model + utilization. The
/// schedule-once/price-many split lets callers (the sweep engine) reuse one
/// `FoldSet` across configs that differ only in memory-model fields — see
/// [`SimConfig::schedule_key`] vs [`SimConfig::price_key`].
pub fn price_layer(layer: &Layer, fs: &FoldSet, cfg: &SimConfig) -> LayerSim {
    let mem = apply_memory(fs, cfg);
    let pe_cycles = fs.pe_cycles();
    let denom = (mem.total_cycles as f64) * cfg.num_pes() as f64;
    LayerSim {
        name: layer.name.clone(),
        class: layer.class(),
        block: layer.block,
        macs: layer.macs(),
        compute_cycles: mem.compute_cycles,
        stall_cycles: mem.stall_cycles,
        total_cycles: mem.total_cycles,
        pe_cycles,
        utilization: if denom > 0.0 { pe_cycles as f64 / denom } else { 0.0 },
        mem,
    }
}

/// Simulate one layer: schedule + memory model + utilization.
pub fn simulate_layer(layer: &Layer, cfg: &SimConfig) -> LayerSim {
    price_layer(layer, &schedule_layer(layer, cfg), cfg)
}

/// Simulate a whole network (layers execute back-to-back, as in SCALE-Sim).
pub fn simulate_network(net: &Network, cfg: &SimConfig) -> NetworkSim {
    let layers: Vec<LayerSim> = net.layers.iter().map(|l| simulate_layer(l, cfg)).collect();
    NetworkSim::assemble(net.name.clone(), layers, cfg)
}

impl NetworkSim {
    /// Assemble a network result from per-layer simulations (used by both
    /// the serial driver above and the sweep engine's cached path).
    pub fn assemble(network: String, layers: Vec<LayerSim>, cfg: &SimConfig) -> NetworkSim {
        let total_cycles = layers.iter().map(|l| l.total_cycles).sum();
        NetworkSim {
            network,
            config_label: cfg.label(),
            layers,
            total_cycles,
            latency_ms: cfg.cycles_to_ms(total_cycles),
            num_pes: cfg.num_pes(),
        }
    }

    /// Blended utilization of one bottleneck block (Fig 10).
    pub fn block_utilization(&self, block: usize) -> f64 {
        let ls: Vec<&LayerSim> = self.layers.iter().filter(|l| l.block == Some(block)).collect();
        let cycles: u64 = ls.iter().map(|l| l.total_cycles).sum();
        let pe: u64 = ls.iter().map(|l| l.pe_cycles).sum();
        if cycles == 0 {
            return 0.0;
        }
        // denominator uses full-array residency
        pe as f64 / (cycles as f64 * self.num_pes as f64)
    }

    /// Cycles of one block.
    pub fn block_cycles(&self, block: usize) -> u64 {
        self.layers.iter().filter(|l| l.block == Some(block)).map(|l| l.total_cycles).sum()
    }

    /// Total cycles attributed per operator class (Fig 9a).
    pub fn cycles_by_class(&self) -> std::collections::BTreeMap<OpClass, u64> {
        let mut m = std::collections::BTreeMap::new();
        for l in &self.layers {
            *m.entry(l.class).or_insert(0) += l.total_cycles;
        }
        m
    }

    /// Whole-network average utilization.
    pub fn overall_utilization(&self) -> f64 {
        let pe: u64 = self.layers.iter().map(|l| l.pe_cycles).sum();
        pe as f64 / (self.total_cycles as f64 * self.num_pes as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::mobilenet_v2;
    use crate::nn::{fuse_all, Variant};

    #[test]
    fn layer_sim_conserves_macs_for_gemm_ops() {
        let cfg = SimConfig::default();
        let l = Layer::new("pw", OpKind::Pointwise { cin: 96, cout: 192 }, 28, 28);
        let s = simulate_layer(&l, &cfg);
        assert_eq!(s.pe_cycles, l.macs());
        assert!(s.utilization > 0.0 && s.utilization <= 1.0);
    }

    #[test]
    fn every_op_kind_schedules() {
        let cfg = SimConfig::default();
        let ops: Vec<Layer> = vec![
            Layer::new("c", OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 }, 224, 224),
            Layer::new("d", OpKind::Depthwise { k: 3, stride: 1, c: 32 }, 112, 112),
            Layer::new("p", OpKind::Pointwise { cin: 32, cout: 16 }, 112, 112),
            Layer::new("fr", OpKind::FuseRow { k: 3, stride: 1, c: 16 }, 112, 112),
            Layer::new("fc2", OpKind::FuseCol { k: 3, stride: 1, c: 16 }, 112, 112),
            Layer::new("f", OpKind::Fc { cin: 1280, cout: 1000 }, 1, 1),
            Layer::new("g", OpKind::GlobalPool { c: 1280 }, 7, 7),
            Layer::new("s", OpKind::SqueezeExcite { c: 64, reduced: 16 }, 28, 28),
            Layer::new("a", OpKind::Add { c: 24 }, 56, 56),
        ];
        for l in &ops {
            let s = simulate_layer(l, &cfg);
            assert!(s.total_cycles > 0, "{} zero cycles", l.name);
            assert!(s.utilization <= 1.0 + 1e-9, "{} util {}", l.name, s.utilization);
            if l.macs() > 0 {
                assert_eq!(s.pe_cycles, l.macs(), "{} MAC mismatch", l.name);
            }
        }
    }

    #[test]
    fn new_conv_variants_schedule_under_every_dataflow() {
        // Exact MAC conservation (pe_cycles == analytical MACs) for every
        // (new op) × (dataflow) cell — the rescale bookkeeping must never
        // leak or double-count arithmetic.
        let ops: Vec<Layer> = vec![
            Layer::new("dil", OpKind::Dilated { k: 3, stride: 1, dilation: 2, cin: 32, cout: 64 }, 33, 33),
            Layer::new("tc", OpKind::Transposed { k: 4, stride: 2, cin: 64, cout: 32 }, 16, 16),
            Layer::new("gc", OpKind::Grouped { k: 3, stride: 1, groups: 4, cin: 64, cout: 64 }, 28, 28),
        ];
        for df in crate::sim::config::ALL_DATAFLOWS {
            let cfg = SimConfig::default().with_dataflow(df);
            for l in &ops {
                let s = simulate_layer(l, &cfg);
                assert!(s.total_cycles > 0, "{} zero cycles under {df:?}", l.name);
                assert_eq!(s.pe_cycles, l.macs(), "{} MAC mismatch under {df:?}", l.name);
                assert!(
                    s.utilization > 0.0 && s.utilization <= 1.0 + 1e-9,
                    "{} util {} under {df:?}",
                    l.name,
                    s.utilization
                );
            }
        }
    }

    #[test]
    fn transposed_utilization_collapses_under_os_but_not_is() {
        // EcoFlow's headline: zero-insertion makes a stride-2 transposed
        // conv waste ~3/4 of its array residency under the GEMM dataflows,
        // while input-stationary keeps the compact GEMM's utilization.
        let l = Layer::new("up", OpKind::Transposed { k: 4, stride: 2, cin: 64, cout: 64 }, 16, 16);
        let os = simulate_layer(&l, &SimConfig::default());
        let is = simulate_layer(
            &l,
            &SimConfig::default().with_dataflow(Dataflow::InputStationary),
        );
        assert!(
            os.utilization < is.utilization / 2.0,
            "os util {} should collapse vs is util {}",
            os.utilization,
            is.utilization
        );
        // and the dense-conv twin does NOT collapse under os: the gap is
        // the operator, not the dataflow being generally bad.
        let conv = Layer::new("c", OpKind::Conv2d { k: 4, stride: 1, cin: 64, cout: 64 }, 16, 16);
        let conv_os = simulate_layer(&conv, &SimConfig::default());
        assert!(conv_os.utilization > 2.0 * os.utilization);
    }

    #[test]
    fn dilated_utilization_degrades_with_dilation_under_os() {
        // The im2col window inflates k→k_eff; the real-tap fraction
        // (k/k_eff)² bounds utilization under os/ws but not under is.
        let mk = |dilation| {
            Layer::new("d", OpKind::Dilated { k: 3, stride: 1, dilation, cin: 64, cout: 64 }, 33, 33)
        };
        let cfg = SimConfig::default();
        let u1 = simulate_layer(&mk(1), &cfg).utilization;
        let u4 = simulate_layer(&mk(4), &cfg).utilization;
        assert!(u4 < u1 * 0.25, "d=4 util {u4} vs d=1 util {u1}");
        let icfg = SimConfig::default().with_dataflow(Dataflow::InputStationary);
        let i4 = simulate_layer(&mk(4), &icfg).utilization;
        assert!(i4 > 2.0 * u4, "is util {i4} should beat os util {u4} at d=4");
    }

    #[test]
    fn grouped_underfill_pathology_when_group_slice_below_rows() {
        // k²·cin/g = 9·4 = 36 ≥ 16 rows is fine, but cout/g = 4 columns on
        // a 16-wide array idles 3/4 of them under ws — and narrow groups
        // also serialize os. Compare against the dense conv with identical
        // arithmetic cost.
        let cfg = SimConfig::default().with_dataflow(Dataflow::WeightStationary);
        let g = Layer::new(
            "g",
            OpKind::Grouped { k: 3, stride: 1, groups: 16, cin: 64, cout: 64 },
            28,
            28,
        );
        let dense_eq = Layer::new(
            // same MACs as the grouped op: cin/16 input channels
            "c",
            OpKind::Conv2d { k: 3, stride: 1, cin: 4, cout: 64 },
            28,
            28,
        );
        let sg = simulate_layer(&g, &cfg);
        let sd = simulate_layer(&dense_eq, &cfg);
        assert_eq!(g.macs(), dense_eq.macs() * 16);
        // per-MAC, the grouped op is slower: no cross-group reuse
        let per_mac_g = sg.total_cycles as f64 / g.macs() as f64;
        let per_mac_d = sd.total_cycles as f64 / dense_eq.macs() as f64;
        assert!(
            per_mac_g > per_mac_d,
            "grouped {per_mac_g} cyc/MAC should exceed dense {per_mac_d}"
        );
        assert!(sg.utilization < 0.30, "grouped ws util {}", sg.utilization);
    }

    #[test]
    fn depthwise_single_column_pathology() {
        let cfg = SimConfig::default();
        let dw = Layer::new("dw", OpKind::Depthwise { k: 3, stride: 1, c: 96 }, 56, 56);
        let s = simulate_layer(&dw, &cfg);
        assert!(s.utilization < 0.03, "dw util {}", s.utilization);
    }

    #[test]
    fn fuse_beats_depthwise_cycles() {
        let cfg = SimConfig::default();
        let dw = Layer::new("dw", OpKind::Depthwise { k: 3, stride: 1, c: 96 }, 56, 56);
        let row = Layer::new("r", OpKind::FuseRow { k: 3, stride: 1, c: 48 }, 56, 56);
        let col = Layer::new("c", OpKind::FuseCol { k: 3, stride: 1, c: 48 }, 56, 56);
        let dw_cycles = simulate_layer(&dw, &cfg).total_cycles;
        let fuse_cycles =
            simulate_layer(&row, &cfg).total_cycles + simulate_layer(&col, &cfg).total_cycles;
        let speedup = dw_cycles as f64 / fuse_cycles as f64;
        assert!(speedup > 10.0, "per-op speedup {speedup}");
    }

    #[test]
    fn whole_network_simulates_and_speedup_in_paper_band() {
        let cfg = SimConfig::default();
        let base = mobilenet_v2::build();
        let half = fuse_all(&base, Variant::Half);
        let sb = simulate_network(&base, &cfg);
        let sh = simulate_network(&half, &cfg);
        assert!(sb.total_cycles > 0 && sh.total_cycles > 0);
        let speedup = sb.total_cycles as f64 / sh.total_cycles as f64;
        // Fig 8a: FuSe-Half speedups 7.01–9.36×; accept a band around it.
        assert!(speedup > 3.0, "speedup {speedup} too low");
        assert!(speedup < 20.0, "speedup {speedup} implausibly high");
    }

    #[test]
    fn network_block_accessors() {
        let cfg = SimConfig::default();
        let net = mobilenet_v2::build();
        let sim = simulate_network(&net, &cfg);
        let b0 = net.bottleneck_blocks()[0];
        assert!(sim.block_cycles(b0) > 0);
        let u = sim.block_utilization(b0);
        assert!(u > 0.0 && u <= 1.0);
        let by_class = sim.cycles_by_class();
        let sum: u64 = by_class.values().sum();
        assert_eq!(sum, sim.total_cycles);
    }

    #[test]
    fn ws_dataflow_also_runs() {
        let cfg = SimConfig::default().with_dataflow(Dataflow::WeightStationary);
        let net = mobilenet_v2::build();
        let sim = simulate_network(&net, &cfg);
        assert!(sim.total_cycles > 0);
    }

    #[test]
    fn is_dataflow_runs_whole_networks() {
        let cfg = SimConfig::default().with_dataflow(Dataflow::InputStationary);
        let net = mobilenet_v2::build();
        let sim = simulate_network(&net, &cfg);
        assert!(sim.total_cycles > 0);
        assert!(sim.overall_utilization() > 0.0);
    }

    #[test]
    fn num_pes_carried_from_config_even_with_zero_util_layers() {
        // A network of MAC-free ops has zero utilization everywhere; the
        // old reverse-engineering fallback reported 256 PEs regardless of
        // the actual array. The field must come from the config.
        let cfg = SimConfig::with_size(32);
        let net = Network {
            name: "pool-only".into(),
            layers: vec![
                Layer::new("g", OpKind::GlobalPool { c: 64 }, 7, 7),
                Layer::new("a", OpKind::Add { c: 64 }, 7, 7),
            ],
            num_blocks: 0,
        };
        let sim = simulate_network(&net, &cfg);
        assert_eq!(sim.num_pes, 1024);
        assert_eq!(sim.overall_utilization(), 0.0);
        // and on a default run it matches the config too
        let sim = simulate_network(&mobilenet_v2::build(), &SimConfig::default());
        assert_eq!(sim.num_pes, 256);
    }

    #[test]
    fn schedule_once_price_many_matches_direct_simulation() {
        let base = SimConfig::default();
        let throttled =
            SimConfig { enforce_dram_bw: true, dram_bw: 4.0, ..SimConfig::default() };
        assert_eq!(base.schedule_key(), throttled.schedule_key());

        let l = Layer::new("pw", OpKind::Pointwise { cin: 96, cout: 192 }, 28, 28);
        // Lower once under the shared schedule, price under both configs.
        let fs = schedule_layer(&l, &base);
        for cfg in [&base, &throttled] {
            let priced = price_layer(&l, &fs, cfg);
            let direct = simulate_layer(&l, cfg);
            assert_eq!(priced.total_cycles, direct.total_cycles);
            assert_eq!(priced.stall_cycles, direct.stall_cycles);
            assert_eq!(priced.pe_cycles, direct.pe_cycles);
        }
    }
}
