//! Simulator configuration — Table 1 of the paper is the default.

/// Dataflow executed by the PE array for *GEMM-shaped* operators
/// (standard conv via im2col, pointwise, FC). FuSe layers additionally
/// use ST-OS when `stos` is enabled, regardless of this baseline choice.
///
/// `InputStationary` pins activation tiles in the PEs and streams weight
/// columns past them (EcoFlow's answer to transposed/dilated convs: a
/// pinned input never multiplies an inserted zero, so those operators
/// keep their utilization — see `sim::gemm::is_schedule`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    OutputStationary,
    WeightStationary,
    InputStationary,
}

/// Every dataflow, in the stable order sweeps enumerate them.
pub const ALL_DATAFLOWS: [Dataflow; 3] =
    [Dataflow::OutputStationary, Dataflow::WeightStationary, Dataflow::InputStationary];

impl Dataflow {
    /// The short CLI/wire form (`os` / `ws` / `is`). [`Dataflow::parse`]
    /// is the inverse; every surface (CLI flags, sweep specs, wire
    /// configs) shares this one vocabulary.
    pub fn short(&self) -> &'static str {
        match self {
            Dataflow::OutputStationary => "os",
            Dataflow::WeightStationary => "ws",
            Dataflow::InputStationary => "is",
        }
    }

    /// Parse the short form; `None` for anything else (callers turn that
    /// into a usage error / `bad_request` — never a silent default).
    pub fn parse(s: &str) -> Option<Dataflow> {
        match s {
            "os" => Some(Dataflow::OutputStationary),
            "ws" => Some(Dataflow::WeightStationary),
            "is" => Some(Dataflow::InputStationary),
            _ => None,
        }
    }
}

/// ST-OS slice-to-row mapping policy (paper §3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingPolicy {
    /// Rows that share a channel get the same filter: one broadcast serves
    /// many rows → fewest weight-SRAM reads, needs multi-row broadcast.
    SpatialFirst,
    /// Rows carry distinct channels: max distinct filters in flight →
    /// `rows` weight reads per round, no extra broadcast circuitry.
    ChannelsFirst,
    /// Channels-first until channels run out, then spill spatial slices of
    /// the same channels across remaining rows (paper's default).
    Hybrid,
}

impl MappingPolicy {
    /// Stable CLI/wire label. [`MappingPolicy::parse`] is the inverse.
    pub fn label(&self) -> &'static str {
        match self {
            MappingPolicy::SpatialFirst => "spatial-first",
            MappingPolicy::ChannelsFirst => "channels-first",
            MappingPolicy::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> Option<MappingPolicy> {
        match s {
            "spatial-first" => Some(MappingPolicy::SpatialFirst),
            "channels-first" => Some(MappingPolicy::ChannelsFirst),
            "hybrid" => Some(MappingPolicy::Hybrid),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// PE array dimensions (systolic rows × cols).
    pub rows: usize,
    pub cols: usize,
    /// Operating frequency (Table 1: 1 GHz).
    pub freq_mhz: u64,
    /// SRAM sizes in KiB (Table 1: 64 KiB each).
    pub ifmap_sram_kb: usize,
    pub weight_sram_kb: usize,
    pub ofmap_sram_kb: usize,
    /// Main-memory bandwidth in bytes/cycle (edge LPDDR4-class default).
    pub dram_bw: f64,
    /// If true, the array stalls when a fold's working set exceeds
    /// `dram_bw × duration`. SCALE-Sim (and hence the paper's latencies)
    /// reports *required* bandwidth without throttling — that is the
    /// default; enable this for the bandwidth-constrained ablation.
    pub enforce_dram_bw: bool,
    /// Bytes per tensor element (int8 inference = 1, as SCALE-Sim assumes).
    pub bytes_per_elem: usize,
    /// Baseline dataflow for GEMM-shaped ops.
    pub dataflow: Dataflow,
    /// Whether the array has the per-row weight-broadcast links (ST-OS).
    pub stos: bool,
    pub mapping: MappingPolicy,
}

impl Default for SimConfig {
    /// Paper Table 1: 1 GHz, 16×16, OS + ST-OS, 64 KiB × 3.
    fn default() -> SimConfig {
        SimConfig {
            rows: 16,
            cols: 16,
            freq_mhz: 1000,
            ifmap_sram_kb: 64,
            weight_sram_kb: 64,
            ofmap_sram_kb: 64,
            dram_bw: 16.0,
            enforce_dram_bw: false,
            bytes_per_elem: 1,
            dataflow: Dataflow::OutputStationary,
            stos: true,
            mapping: MappingPolicy::Hybrid,
        }
    }
}

impl SimConfig {
    pub fn with_size(size: usize) -> SimConfig {
        SimConfig { rows: size, cols: size, ..SimConfig::default() }
    }

    pub fn with_dataflow(mut self, df: Dataflow) -> SimConfig {
        self.dataflow = df;
        self
    }

    pub fn without_stos(mut self) -> SimConfig {
        self.stos = false;
        self
    }

    pub fn num_pes(&self) -> usize {
        self.rows * self.cols
    }

    pub fn ifmap_sram_bytes(&self) -> usize {
        self.ifmap_sram_kb * 1024
    }

    pub fn weight_sram_bytes(&self) -> usize {
        self.weight_sram_kb * 1024
    }

    pub fn ofmap_sram_bytes(&self) -> usize {
        self.ofmap_sram_kb * 1024
    }

    /// Cycles → milliseconds at the configured frequency.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz as f64 * 1e3)
    }

    /// Human-readable config label, e.g. `16x16 OutputStationary+ST-OS`.
    pub fn label(&self) -> String {
        format!(
            "{}x{} {:?}{}",
            self.rows,
            self.cols,
            self.dataflow,
            if self.stos { "+ST-OS" } else { "" }
        )
    }

    /// Hash of every field that affects layer *lowering* (the fold
    /// schedule): array geometry, SRAM sizes, element width, dataflow,
    /// ST-OS support, and the mapping policy. Two configs with equal
    /// schedule keys produce identical `FoldSet`s for every layer, so the
    /// sweep engine lowers once and re-prices per memory model.
    pub fn schedule_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.rows.hash(&mut h);
        self.cols.hash(&mut h);
        self.ifmap_sram_kb.hash(&mut h);
        self.weight_sram_kb.hash(&mut h);
        self.ofmap_sram_kb.hash(&mut h);
        self.bytes_per_elem.hash(&mut h);
        self.dataflow.hash(&mut h);
        self.stos.hash(&mut h);
        self.mapping.hash(&mut h);
        h.finish()
    }

    /// Hash of every field that affects a layer's *simulation result*
    /// (schedule fields plus the memory model). Frequency is deliberately
    /// excluded: it only scales cycles into milliseconds at the network
    /// level, so configs differing only in `freq_mhz` share cache entries.
    pub fn price_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.schedule_key().hash(&mut h);
        self.dram_bw.to_bits().hash(&mut h);
        self.enforce_dram_bw.hash(&mut h);
        h.finish()
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table1() {
        let c = SimConfig::default();
        assert_eq!((c.rows, c.cols), (16, 16));
        assert_eq!(c.freq_mhz, 1000);
        assert_eq!(c.ifmap_sram_kb, 64);
        assert_eq!(c.weight_sram_kb, 64);
        assert_eq!(c.ofmap_sram_kb, 64);
        assert!(c.stos);
        assert_eq!(c.dataflow, Dataflow::OutputStationary);
    }

    #[test]
    fn cycles_to_ms_at_1ghz() {
        let c = SimConfig::default();
        assert!((c.cycles_to_ms(1_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn with_size_square() {
        let c = SimConfig::with_size(64);
        assert_eq!(c.num_pes(), 4096);
    }

    #[test]
    fn schedule_key_ignores_memory_model_fields() {
        let a = SimConfig::default();
        let b = SimConfig {
            dram_bw: 64.0,
            enforce_dram_bw: true,
            freq_mhz: 500,
            ..SimConfig::default()
        };
        assert_eq!(a.schedule_key(), b.schedule_key());
        assert_ne!(a.price_key(), b.price_key());
        // but geometry changes both
        let c = SimConfig::with_size(32);
        assert_ne!(a.schedule_key(), c.schedule_key());
        assert_ne!(a.price_key(), c.price_key());
    }

    #[test]
    fn price_key_ignores_frequency_only() {
        let a = SimConfig::default();
        let b = SimConfig { freq_mhz: 500, ..SimConfig::default() };
        assert_eq!(a.price_key(), b.price_key());
    }

    #[test]
    fn dataflow_and_mapping_strings_round_trip() {
        for df in ALL_DATAFLOWS {
            assert_eq!(Dataflow::parse(df.short()), Some(df));
        }
        assert_eq!(Dataflow::parse("systolic"), None);
        assert_eq!(Dataflow::parse("IS"), None); // vocabulary is exact, not fuzzy
        for m in [MappingPolicy::SpatialFirst, MappingPolicy::ChannelsFirst, MappingPolicy::Hybrid]
        {
            assert_eq!(MappingPolicy::parse(m.label()), Some(m));
        }
        assert_eq!(MappingPolicy::parse("rows-first"), None);
    }

    #[test]
    fn every_dataflow_pair_gets_disjoint_cache_keys() {
        // `is` must never alias an `os`/`ws` cache entry (and vice versa):
        // both key tiers hash the dataflow.
        let keys: Vec<(u64, u64)> = ALL_DATAFLOWS
            .iter()
            .map(|&df| {
                let c = SimConfig::default().with_dataflow(df);
                (c.schedule_key(), c.price_key())
            })
            .collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i].0, keys[j].0, "schedule_key collision {i} vs {j}");
                assert_ne!(keys[i].1, keys[j].1, "price_key collision {i} vs {j}");
            }
        }
    }

    #[test]
    fn label_mentions_geometry_and_stos() {
        let l = SimConfig::default().label();
        assert!(l.contains("16x16"));
        assert!(l.contains("ST-OS"));
        let rect = SimConfig { rows: 8, cols: 32, ..SimConfig::default() };
        let l = rect.without_stos().label();
        assert!(l.contains("8x32"));
        assert!(!l.contains("ST-OS"));
    }
}
