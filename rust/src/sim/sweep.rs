//! Parallel sweep engine: networks × FuSe variants × `SimConfig` grids
//! fanned out across the [`Pool`](crate::exec::Pool), with a thread-shared
//! sharded layer cache so identical layers are priced once across the
//! whole zoo.
//!
//! Every headline number in the paper (Figs 8–10, Table 3) is a sweep of
//! many networks through many simulator configurations, and the layer
//! population is massively redundant: the FuSe transform leaves pointwise/
//! stem/head layers untouched, the zoo shares bottleneck geometries, and a
//! config grid re-simulates every network per point. The cache is two
//! level, mirroring the schedule-once/price-many split in
//! [`engine`](super::engine):
//!
//! * **schedule cache** — (op, h, w, [`SimConfig::schedule_key`]) →
//!   [`FoldSet`]: configs that differ only in memory-model fields (DRAM
//!   bandwidth, throttling) share one lowering;
//! * **layer cache** — (op, h, w, [`SimConfig::price_key`]) →
//!   [`LayerSim`]: the fully priced result, shared across networks,
//!   variants, and frequency-only config changes.
//!
//! Determinism: a sweep's records are indexed by (network, variant,
//! config) plan position, every layer simulation is a pure function of
//! (layer, config), and [`Pool::scope_map`] preserves submission order —
//! so results are bit-identical to the serial path for any worker count.
//! The serving layer leans on exactly this: `fuseconv sweep --verify`,
//! the TCP `--remote` path, and the HTTP/SSE frontend all cross-check
//! their streamed rows against [`run_sweep_serial`].
//!
//! ```
//! use fuseconv::nn::models;
//! use fuseconv::sim::{run_sweep_serial, FuseVariant, SimConfig, SweepPlan};
//! let plan = SweepPlan::new(
//!     vec![models::by_name("mobilenet-v3-small").unwrap()],
//!     vec![FuseVariant::Base, FuseVariant::Half],
//!     vec![SimConfig::with_size(8)],
//! );
//! let out = run_sweep_serial(&plan);
//! assert_eq!(out.records().len(), 2);
//! assert!(out.records().iter().all(|r| r.total_cycles() > 0));
//! ```

use super::config::{Dataflow, SimConfig};
use super::engine::{price_layer, schedule_layer, simulate_network, LayerSim, NetworkSim};
use super::fold::FoldSet;
use super::global_cache::ResultCache;
use crate::exec::{CancelToken, Pool};
use crate::nn::{fuse_all, Layer, Network, OpKind, Variant};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which form of each network a sweep simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuseVariant {
    /// The depthwise-separable baseline, unmodified.
    Base,
    /// FuSe-Half: every bottleneck's depthwise replaced, C/2 + C/2.
    Half,
    /// FuSe-Full: both orientations over all channels (widens SE/project).
    Full,
}

impl FuseVariant {
    pub const ALL: [FuseVariant; 3] = [FuseVariant::Base, FuseVariant::Half, FuseVariant::Full];

    pub fn label(&self) -> &'static str {
        match self {
            FuseVariant::Base => "base",
            FuseVariant::Half => "fuse-half",
            FuseVariant::Full => "fuse-full",
        }
    }

    /// Parse a CLI/wire variant name; accepts both the short forms
    /// (`half`) and the canonical labels (`fuse-half`). `None` for
    /// unknown names — callers report, never default.
    pub fn parse(s: &str) -> Option<FuseVariant> {
        match s {
            "base" => Some(FuseVariant::Base),
            "half" | "fuse-half" => Some(FuseVariant::Half),
            "full" | "fuse-full" => Some(FuseVariant::Full),
            _ => None,
        }
    }

    /// Realize the variant (Base is a clone; Half/Full apply the transform).
    pub fn apply(&self, net: &Network) -> Network {
        match self {
            FuseVariant::Base => net.clone(),
            FuseVariant::Half => fuse_all(net, Variant::Half),
            FuseVariant::Full => fuse_all(net, Variant::Full),
        }
    }
}

/// Cache key: the layer's hardware-relevant identity plus a config hash.
/// `name`, `block`, and `act` are excluded — they do not affect cycles —
/// and are re-attached on retrieval.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Key {
    op: OpKind,
    h: usize,
    w: usize,
    cfg: u64,
}

impl Key {
    fn shard(&self) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut h);
        (h.finish() as usize) % SHARDS
    }
}

const SHARDS: usize = 64;

/// Cache counters at a point in time.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Priced-layer cache hits/misses.
    pub hits: u64,
    pub misses: u64,
    /// Schedule (lowering) cache hits/misses.
    pub sched_hits: u64,
    pub sched_misses: u64,
    /// Distinct priced layers resident.
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-shared, sharded layer-simulation cache. Generalizes the memo in
/// `coordinator::evaluator` to span multiple configs (the key carries the
/// config hash), so one cache serves a whole sweep grid, every search
/// worker, and the sim server at once. Sharding keeps lock contention
/// negligible under pool fan-out.
pub struct LayerCache {
    sims: Vec<Mutex<HashMap<Key, Arc<LayerSim>>>>,
    scheds: Vec<Mutex<HashMap<Key, Arc<FoldSet>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    sched_hits: AtomicU64,
    sched_misses: AtomicU64,
}

impl Default for LayerCache {
    fn default() -> LayerCache {
        LayerCache::new()
    }
}

impl LayerCache {
    pub fn new() -> LayerCache {
        LayerCache {
            sims: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            scheds: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            sched_hits: AtomicU64::new(0),
            sched_misses: AtomicU64::new(0),
        }
    }

    /// The layer's fold schedule under `cfg`, cached per schedule key.
    pub fn schedule(&self, layer: &Layer, cfg: &SimConfig) -> Arc<FoldSet> {
        let key = Key { op: layer.op, h: layer.h, w: layer.w, cfg: cfg.schedule_key() };
        let shard = &self.scheds[key.shard()];
        if let Some(fs) = shard.lock().unwrap().get(&key) {
            self.sched_hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(fs);
        }
        self.sched_misses.fetch_add(1, Ordering::Relaxed);
        let fs = Arc::new(schedule_layer(layer, cfg));
        shard.lock().unwrap().entry(key).or_insert_with(|| Arc::clone(&fs));
        fs
    }

    /// Simulate one layer through the cache. Identity fields (`name`,
    /// `block`) are patched from the concrete layer so callers see exactly
    /// what `simulate_layer` would have returned.
    pub fn simulate(&self, layer: &Layer, cfg: &SimConfig) -> LayerSim {
        let cached = self.simulate_shared(layer, cfg);
        let mut sim = (*cached).clone();
        sim.name = layer.name.clone();
        sim.block = layer.block;
        sim
    }

    /// The canonical cached result (name stripped, block `None`) as a
    /// cheap `Arc` — the hot path for callers that only read cycle
    /// counts (search loops) and must not pay a per-hit clone.
    pub fn simulate_shared(&self, layer: &Layer, cfg: &SimConfig) -> Arc<LayerSim> {
        let key = Key { op: layer.op, h: layer.h, w: layer.w, cfg: cfg.price_key() };
        {
            let shard = &self.sims[key.shard()];
            let found = shard.lock().unwrap().get(&key).map(Arc::clone);
            match found {
                Some(sim) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    sim
                }
                None => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    let fs = self.schedule(layer, cfg);
                    let mut sim = price_layer(layer, &fs, cfg);
                    sim.name = String::new();
                    sim.block = None;
                    let sim = Arc::new(sim);
                    shard.lock().unwrap().entry(key).or_insert_with(|| Arc::clone(&sim));
                    sim
                }
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            sched_hits: self.sched_hits.load(Ordering::Relaxed),
            sched_misses: self.sched_misses.load(Ordering::Relaxed),
            entries: self.sims.iter().map(|s| s.lock().unwrap().len()).sum(),
        }
    }
}

/// [`simulate_network`] through a shared cache — same result, priced once
/// per distinct (layer shape, config) anywhere in the process.
pub fn simulate_network_cached(net: &Network, cfg: &SimConfig, cache: &LayerCache) -> NetworkSim {
    let layers: Vec<LayerSim> = net.layers.iter().map(|l| cache.simulate(l, cfg)).collect();
    NetworkSim::assemble(net.name.clone(), layers, cfg)
}

/// A sweep: the cross product of networks × variants × configs.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub networks: Vec<Network>,
    pub variants: Vec<FuseVariant>,
    pub configs: Vec<SimConfig>,
}

impl SweepPlan {
    pub fn new(
        networks: Vec<Network>,
        variants: Vec<FuseVariant>,
        configs: Vec<SimConfig>,
    ) -> SweepPlan {
        SweepPlan { networks, variants, configs }
    }

    /// Number of (network, variant, config) simulation jobs.
    pub fn len(&self) -> usize {
        self.networks.len() * self.variants.len() * self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Plan position of the (n-th network, v-th variant, c-th config)
    /// cell. Plan order is network-major, then variant, then config —
    /// the one ordering every sweep consumer (serial path, pool path,
    /// wire streams, the shard front tier's sub-grid merge) agrees on.
    pub fn index_of(&self, n: usize, v: usize, c: usize) -> usize {
        (n * self.variants.len() + v) * self.configs.len() + c
    }

    /// Inverse of [`SweepPlan::index_of`]: the (network, variant,
    /// config) indices of plan position `index`.
    pub fn cell_at(&self, index: usize) -> (usize, usize, usize) {
        let c = index % self.configs.len();
        let nv = index / self.configs.len();
        (nv / self.variants.len(), nv % self.variants.len(), c)
    }
}

/// The standard config grid: sizes × dataflows × ST-OS modes, everything
/// else at the paper's Table 1 defaults.
///
/// ```
/// use fuseconv::sim::{grid_configs, Dataflow};
/// let grid = grid_configs(&[8, 16], &[Dataflow::OutputStationary], &[true, false]);
/// assert_eq!(grid.len(), 4);
/// ```
pub fn grid_configs(
    sizes: &[usize],
    dataflows: &[Dataflow],
    stos_modes: &[bool],
) -> Vec<SimConfig> {
    let mut out = Vec::with_capacity(sizes.len() * dataflows.len() * stos_modes.len());
    for &s in sizes {
        for &df in dataflows {
            for &stos in stos_modes {
                let mut cfg = SimConfig::with_size(s).with_dataflow(df);
                cfg.stos = stos;
                out.push(cfg);
            }
        }
    }
    out
}

/// One completed (network, variant, config) cell.
#[derive(Debug, Clone)]
pub struct SweepRecord {
    /// Base network name (before the variant transform).
    pub network: String,
    pub variant: FuseVariant,
    pub cfg: SimConfig,
    /// Full simulation result (the transformed network's name is in here).
    pub sim: NetworkSim,
}

impl SweepRecord {
    pub fn total_cycles(&self) -> u64 {
        self.sim.total_cycles
    }

    pub fn latency_ms(&self) -> f64 {
        self.sim.latency_ms
    }
}

/// Sweep results in plan order (network-major, then variant, then config),
/// plus the shared cache's counters after the run.
#[derive(Debug)]
pub struct SweepOutcome {
    records: Vec<SweepRecord>,
    variants: usize,
    configs: usize,
    pub cache_stats: CacheStats,
}

impl SweepOutcome {
    /// The cell for the n-th network, v-th variant, c-th config of the plan.
    pub fn record(&self, n: usize, v: usize, c: usize) -> &SweepRecord {
        &self.records[(n * self.variants + v) * self.configs + c]
    }

    pub fn records(&self) -> &[SweepRecord] {
        &self.records
    }

    /// Per-cell cycle counts as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "network,variant,rows,cols,dataflow,stos,total_cycles,latency_ms,utilization,macs_m\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{:.6},{:.4},{:.1}\n",
                r.network,
                r.variant.label(),
                r.cfg.rows,
                r.cfg.cols,
                r.cfg.dataflow.short(),
                r.cfg.stos,
                r.sim.total_cycles,
                r.sim.latency_ms,
                r.sim.overall_utilization(),
                r.sim.layers.iter().map(|l| l.macs).sum::<u64>() as f64 / 1e6,
            ));
        }
        s
    }

    /// Per-cell cycle counts as a JSON array (no serde offline; names in
    /// the zoo are plain ASCII, so escaping quotes/backslashes suffices).
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut s = String::from("[\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "  {{\"network\":\"{}\",\"variant\":\"{}\",\"rows\":{},\"cols\":{},\
                 \"dataflow\":\"{}\",\"stos\":{},\"total_cycles\":{},\"latency_ms\":{:.6},\
                 \"utilization\":{:.4}}}{}\n",
                esc(&r.network),
                r.variant.label(),
                r.cfg.rows,
                r.cfg.cols,
                r.cfg.dataflow.short(),
                r.cfg.stos,
                r.sim.total_cycles,
                r.sim.latency_ms,
                r.sim.overall_utilization(),
                if i + 1 < self.records.len() { "," } else { "" },
            ));
        }
        s.push(']');
        s
    }
}

fn assemble_outcome(
    plan: &SweepPlan,
    sims: Vec<NetworkSim>,
    cache_stats: CacheStats,
) -> SweepOutcome {
    let mut records = Vec::with_capacity(sims.len());
    let mut it = sims.into_iter();
    for net in &plan.networks {
        for &variant in &plan.variants {
            for cfg in &plan.configs {
                records.push(SweepRecord {
                    network: net.name.clone(),
                    variant,
                    cfg: cfg.clone(),
                    sim: it.next().expect("one sim per plan cell"),
                });
            }
        }
    }
    SweepOutcome {
        records,
        variants: plan.variants.len(),
        configs: plan.configs.len(),
        cache_stats,
    }
}

/// One observable moment of an in-flight sweep, for incremental
/// consumers (the serving layer streams these to wire clients as
/// `Progress`/`Row` frames).
#[derive(Debug)]
pub enum SweepEvent<'a> {
    /// A grid cell finished simulating (completion order, which is
    /// nondeterministic under the pool).
    Progress { done: usize, total: usize },
    /// The next *plan-order* record is ready: rows are held back until
    /// every earlier cell has completed, so consumers see exactly the
    /// serial order — `index` is the record's plan position.
    Row { index: usize, record: &'a SweepRecord },
}

/// Run the sweep across the pool, sharing `cache` between all workers,
/// invoking `on_event` on the coordinating thread as cells complete.
/// Row events fire in plan order (a reorder buffer holds out-of-order
/// completions), so the record sequence — and the returned outcome — is
/// bit-identical to [`run_sweep_serial`] for any thread count.
pub fn run_sweep_with<F>(
    plan: &SweepPlan,
    pool: &Pool,
    cache: &Arc<LayerCache>,
    on_event: F,
) -> SweepOutcome
where
    F: FnMut(SweepEvent<'_>),
{
    run_sweep_coalesced(plan, pool, cache, None, &CancelToken::new(), on_event)
}

/// [`run_sweep_with`], with each cell additionally routed through an
/// optional cross-request [`ResultCache`]: a cell whose (network,
/// priced-config) result is already resident costs a lookup instead of
/// a simulation, and a cell identical to one *currently simulating*
/// anywhere in the process coalesces onto that single flight. Rows
/// still stream in plan order through this sweep's own reorder buffer
/// and sink — a coalesced cell re-emits under this caller's
/// backpressure bound, never the leader's.
///
/// `cancel` is polled by each worker before it prices its cell: once
/// tripped (disconnect, explicit `cancel` frame), remaining cells skip
/// simulation entirely — no layer-cache or result-cache traffic — and
/// the outcome comes back with only the plan-order prefix of records
/// that completed. Callers that can't be cancelled pass a fresh token.
pub fn run_sweep_coalesced<F>(
    plan: &SweepPlan,
    pool: &Pool,
    cache: &Arc<LayerCache>,
    results: Option<&Arc<ResultCache>>,
    cancel: &CancelToken,
    mut on_event: F,
) -> SweepOutcome
where
    F: FnMut(SweepEvent<'_>),
{
    // Realize each (network, variant) once — the transform is pure CPU work
    // that every config cell would otherwise repeat.
    let realized: Vec<Arc<Network>> = plan
        .networks
        .iter()
        .flat_map(|n| plan.variants.iter().map(|v| Arc::new(v.apply(n))))
        .collect();
    let total = realized.len() * plan.configs.len();

    let realized = Arc::new(realized);
    let configs = Arc::new(plan.configs.clone());
    let (rtx, rrx) = std::sync::mpsc::channel::<(usize, Option<NetworkSim>)>();
    let results = results.map(Arc::clone);
    for i in 0..total {
        let realized = Arc::clone(&realized);
        let configs = Arc::clone(&configs);
        let cache_ref = Arc::clone(cache);
        let results = results.clone();
        let cancel = cancel.clone();
        let rtx = rtx.clone();
        pool.spawn(move || {
            // A cancelled cell still reports in (None) so the
            // coordinator's recv-count bookkeeping stays exact, but it
            // skips pricing — no cache traffic, no pool cycles burned.
            let sim = if cancel.is_cancelled() {
                None
            } else {
                let (nv, c) = (i / configs.len(), i % configs.len());
                Some(match &results {
                    // No per-cell deadline: an admitted grid runs to
                    // completion, so a follower waits out its leader and
                    // the expiry path is unreachable.
                    Some(rc) => (*rc
                        .simulate(&realized[nv], &configs[c], &cache_ref, None)
                        .expect("deadline-free single-flight wait cannot expire"))
                    .clone(),
                    None => simulate_network_cached(&realized[nv], &configs[c], &cache_ref),
                })
            };
            // Receiver outlives all jobs within this call; a send failure
            // would mean the coordinator returned early (it can't).
            let _ = rtx.send((i, sim));
        });
    }
    drop(rtx);

    let mut slots: Vec<Option<NetworkSim>> = (0..total).map(|_| None).collect();
    let mut records: Vec<SweepRecord> = Vec::with_capacity(total);
    let mut next = 0usize;
    for done in 1..=total {
        let (i, sim) = rrx.recv().expect("worker result");
        let Some(sim) = sim else { continue }; // cancelled cell: hole stays
        slots[i] = Some(sim);
        on_event(SweepEvent::Progress { done, total });
        // Flush the ready plan-order prefix.
        while next < total && slots[next].is_some() {
            let sim = slots[next].take().expect("checked above");
            let (n, v, c) = plan.cell_at(next);
            let record = SweepRecord {
                network: plan.networks[n].name.clone(),
                variant: plan.variants[v],
                cfg: plan.configs[c].clone(),
                sim,
            };
            on_event(SweepEvent::Row { index: next, record: &record });
            records.push(record);
            next += 1;
        }
    }
    SweepOutcome {
        records,
        variants: plan.variants.len(),
        configs: plan.configs.len(),
        cache_stats: cache.stats(),
    }
}

/// Run the sweep across the pool, sharing `cache` between all workers.
/// Results are bit-identical to [`run_sweep_serial`] for any thread count.
pub fn run_sweep(plan: &SweepPlan, pool: &Pool, cache: &Arc<LayerCache>) -> SweepOutcome {
    run_sweep_with(plan, pool, cache, |_| {})
}

/// Serial reference path: plain [`simulate_network`], no cache, no pool.
/// The determinism tests (and `fuseconv sweep --verify`) compare against
/// this bit-for-bit.
pub fn run_sweep_serial(plan: &SweepPlan) -> SweepOutcome {
    let mut sims = Vec::with_capacity(plan.len());
    for net in &plan.networks {
        for variant in &plan.variants {
            let realized = variant.apply(net);
            for cfg in &plan.configs {
                sims.push(simulate_network(&realized, cfg));
            }
        }
    }
    assemble_outcome(plan, sims, CacheStats::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;

    #[test]
    fn tripped_cancel_token_skips_all_pricing() {
        let cache = Arc::new(LayerCache::new());
        let pool = Pool::new(2);
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v2").unwrap()],
            vec![FuseVariant::Base, FuseVariant::Half],
            vec![SimConfig::with_size(8), SimConfig::with_size(16)],
        );
        let rc = Arc::new(ResultCache::new(64));
        let token = CancelToken::new();
        token.cancel();
        let out = run_sweep_coalesced(&plan, &pool, &cache, Some(&rc), &token, |_| {
            panic!("no events once every cell is cancelled")
        });
        assert!(out.records().is_empty());
        assert_eq!(rc.stats().misses, 0, "cancelled cells must not simulate");
        assert_eq!(cache.stats().misses, 0, "cancelled cells must not touch the layer cache");
    }

    #[test]
    fn cached_simulation_matches_uncached() {
        let cache = LayerCache::new();
        let net = models::by_name("mobilenet-v2").unwrap();
        for cfg in [SimConfig::default(), SimConfig::with_size(32)] {
            let a = simulate_network_cached(&net, &cfg, &cache);
            let b = simulate_network(&net, &cfg);
            assert_eq!(a.total_cycles, b.total_cycles);
            assert_eq!(a.num_pes, b.num_pes);
            assert_eq!(a.layers.len(), b.layers.len());
            for (x, y) in a.layers.iter().zip(&b.layers) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.block, y.block);
                assert_eq!(x.total_cycles, y.total_cycles);
                assert_eq!(x.pe_cycles, y.pe_cycles);
            }
        }
        // repeat: all hits
        let before = cache.stats();
        simulate_network_cached(&net, &SimConfig::default(), &cache);
        let after = cache.stats();
        assert_eq!(after.misses, before.misses);
        assert_eq!(after.hits, before.hits + net.layers.len() as u64);
    }

    #[test]
    fn schedule_cache_shared_across_memory_models() {
        let cache = LayerCache::new();
        let net = models::by_name("mobilenet-v3-small").unwrap();
        let base = SimConfig::default();
        let throttled =
            SimConfig { enforce_dram_bw: true, dram_bw: 2.0, ..SimConfig::default() };

        simulate_network_cached(&net, &base, &cache);
        let s1 = cache.stats();
        simulate_network_cached(&net, &throttled, &cache);
        let s2 = cache.stats();
        // every layer re-priced (different price key) but never re-lowered
        assert!(s2.misses > s1.misses);
        assert_eq!(s2.sched_misses, s1.sched_misses, "re-lowered despite shared schedule key");
        assert!(s2.sched_hits > s1.sched_hits);
    }

    #[test]
    fn variant_reuse_produces_cross_network_hits() {
        // FuSe-Half keeps every pointwise/stem/head layer of the base net,
        // so sweeping both variants must hit the cache across networks.
        let cache = Arc::new(LayerCache::new());
        let pool = Pool::new(2);
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v2").unwrap()],
            vec![FuseVariant::Base, FuseVariant::Half],
            vec![SimConfig::default()],
        );
        let out = run_sweep(&plan, &pool, &cache);
        assert!(out.cache_stats.hits > 0, "no cross-variant cache hits: {:?}", out.cache_stats);
    }

    #[test]
    fn parallel_matches_serial_and_order_is_plan_major() {
        let plan = SweepPlan::new(
            vec![
                models::by_name("mobilenet-v2").unwrap(),
                models::by_name("mobilenet-v3-small").unwrap(),
            ],
            vec![FuseVariant::Base, FuseVariant::Half],
            grid_configs(&[8, 16], &[Dataflow::OutputStationary], &[true]),
        );
        let serial = run_sweep_serial(&plan);
        let pool = Pool::new(3);
        let cache = Arc::new(LayerCache::new());
        let par = run_sweep(&plan, &pool, &cache);
        assert_eq!(serial.records().len(), plan.len());
        for (a, b) in serial.records().iter().zip(par.records()) {
            assert_eq!(a.network, b.network);
            assert_eq!(a.variant, b.variant);
            assert_eq!(a.cfg.rows, b.cfg.rows);
            assert_eq!(a.total_cycles(), b.total_cycles());
        }
        // indexed lookup agrees with flat order
        let r = par.record(1, 1, 0);
        assert_eq!(r.network, "MobileNet-V3-Small");
        assert_eq!(r.variant, FuseVariant::Half);
        assert_eq!(r.cfg.rows, 8);
    }

    #[test]
    fn new_operator_grid_is_deterministic_across_all_dataflows() {
        // The segmentation models carry dilated + transposed + grouped
        // layers; sweeping them over the full os/ws/is grid in parallel
        // must stay bit-identical to the serial reference.
        let plan = SweepPlan::new(
            vec![
                models::by_name("espnet-c").unwrap(),
                models::by_name("deeplab-mbv2").unwrap(),
            ],
            vec![FuseVariant::Base, FuseVariant::Half],
            grid_configs(&[8, 16], &crate::sim::config::ALL_DATAFLOWS, &[true]),
        );
        assert_eq!(plan.len(), 2 * 2 * 2 * 3);
        let serial = run_sweep_serial(&plan);
        let pool = Pool::new(3);
        let cache = Arc::new(LayerCache::new());
        let par = run_sweep(&plan, &pool, &cache);
        for (a, b) in serial.records().iter().zip(par.records()) {
            assert_eq!(a.network, b.network);
            assert_eq!(a.cfg.dataflow, b.cfg.dataflow);
            assert_eq!(
                a.total_cycles(),
                b.total_cycles(),
                "{} {} {} diverged",
                a.network,
                a.variant.label(),
                a.cfg.label()
            );
            assert!(a.total_cycles() > 0);
        }
    }

    #[test]
    fn run_sweep_with_streams_rows_in_plan_order() {
        let plan = SweepPlan::new(
            vec![
                models::by_name("mobilenet-v2").unwrap(),
                models::by_name("mobilenet-v3-small").unwrap(),
            ],
            vec![FuseVariant::Base, FuseVariant::Half],
            grid_configs(&[8, 16], &[Dataflow::OutputStationary], &[true]),
        );
        let pool = Pool::new(3);
        let cache = Arc::new(LayerCache::new());
        let mut indices = Vec::new();
        let mut cycles = Vec::new();
        let mut last_done = 0usize;
        let out = run_sweep_with(&plan, &pool, &cache, |e| match e {
            SweepEvent::Progress { done, total } => {
                assert_eq!(total, plan.len());
                assert!(done > last_done && done <= total, "monotonic progress");
                last_done = done;
            }
            SweepEvent::Row { index, record } => {
                indices.push(index);
                cycles.push(record.total_cycles());
            }
        });
        assert_eq!(last_done, plan.len(), "one progress event per completed cell");
        // rows fired for every cell, in plan order, despite pool reordering
        assert_eq!(indices, (0..plan.len()).collect::<Vec<_>>());
        let serial = run_sweep_serial(&plan);
        assert_eq!(out.records().len(), serial.records().len());
        for ((streamed, r), s) in cycles.iter().zip(out.records()).zip(serial.records()) {
            assert_eq!(r.total_cycles(), s.total_cycles());
            assert_eq!(*streamed, s.total_cycles(), "streamed rows must match serial");
        }
    }

    #[test]
    fn csv_and_json_have_one_row_per_cell() {
        let plan = SweepPlan::new(
            vec![models::by_name("mobilenet-v3-small").unwrap()],
            vec![FuseVariant::Base],
            grid_configs(&[16], &[Dataflow::OutputStationary, Dataflow::WeightStationary], &[true]),
        );
        let out = run_sweep_serial(&plan);
        let csv = out.to_csv();
        assert_eq!(csv.lines().count(), 1 + plan.len());
        assert!(csv.starts_with("network,variant,rows"));
        assert!(csv.contains(",os,"));
        assert!(csv.contains(",ws,"));
        let json = out.to_json();
        assert_eq!(json.matches("\"network\"").count(), plan.len());
        assert!(json.starts_with('[') && json.ends_with(']'));
    }

    #[test]
    fn plan_indexing_round_trips_every_cell() {
        let plan = SweepPlan::new(
            vec![
                models::by_name("mobilenet-v2").unwrap(),
                models::by_name("mobilenet-v3-small").unwrap(),
            ],
            vec![FuseVariant::Base, FuseVariant::Half, FuseVariant::Full],
            grid_configs(&[8, 16], &[Dataflow::OutputStationary], &[true, false]),
        );
        // index_of and cell_at are inverses over the whole grid, and the
        // flat order is network-major, then variant, then config.
        let mut seen = 0usize;
        for n in 0..plan.networks.len() {
            for v in 0..plan.variants.len() {
                for c in 0..plan.configs.len() {
                    let i = plan.index_of(n, v, c);
                    assert_eq!(i, seen, "plan order must be n-major, then v, then c");
                    assert_eq!(plan.cell_at(i), (n, v, c));
                    seen += 1;
                }
            }
        }
        assert_eq!(seen, plan.len());
    }

    #[test]
    fn grid_configs_cross_product() {
        let grid = grid_configs(
            &[8, 16, 32],
            &[Dataflow::OutputStationary, Dataflow::WeightStationary],
            &[true, false],
        );
        assert_eq!(grid.len(), 12);
        assert!(grid.iter().any(|c| c.rows == 32 && !c.stos));
    }
}
